"""CLI driver smoke tests: train.py / serve.py / examples run end-to-end on
CPU (reduced configs, few rounds)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=600):
    return subprocess.run([sys.executable] + args, cwd=ROOT, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli(tmp_path):
    log = os.path.join(tmp_path, "log.json")
    r = _run(["-m", "repro.launch.train", "--arch", "gemma3-1b",
              "--method", "tad", "--rounds", "3", "--local-steps", "1",
              "--clients", "4", "--batch", "2", "--seq", "32",
              "--log", log])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "T=" in r.stdout and os.path.exists(log)


def test_train_cli_tstar_selection():
    r = _run(["-m", "repro.launch.train", "--arch", "xlstm-1.3b",
              "--method", "rolora", "--rounds", "2", "--local-steps", "1",
              "--clients", "4", "--batch", "2", "--seq", "16", "--p", "0.05"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "T*-selected" in r.stdout


def test_serve_cli():
    r = _run(["-m", "repro.launch.serve", "--arch", "deepseek-moe-16b",
              "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded" in r.stdout


def test_quickstart_example():
    r = _run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "T*=" in r.stdout and "done" in r.stdout


def test_dfl_finetune_example_small():
    r = _run(["examples/dfl_finetune.py", "--small"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "perplexity after merge" in r.stdout
