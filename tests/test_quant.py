"""Compressed-gossip (mix_quant) units.

Covers the quantization core (`repro.core.mixing.quantize_rows` /
`dequantize_rows`), the fused `gossip_mix_quant` kernel against its ref
oracle, error-feedback threading through `mix_tree_sparse` (single-process
degenerate path; real grids live in `-m multihost`), and the config /
session surface: the `mix_quant` knob's validation, build-key separation,
the quant round signature, and checkpoint roundtrip of the EF buffer.
The Lemma A.10 contraction-budget predicate lives in `-m conformance`.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import DFLConfig, Session
from repro.core import mixing
from repro.core.topology import metropolis_weights, ring_graph
from repro.dist import comm
from repro.kernels import ops, ref

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _cfg(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=8,
                rounds=3, local_steps=2, batch_size=8, topology="ring",
                scenario="static", p=0.5, T=2, lr=1e-3, seed=0,
                mix_comm="sparse_overlap")
    base.update(kw)
    return DFLConfig(**base)


def _tree(key, m=8, d=16, r=4):
    ks = jax.random.split(key, 4)
    return {"q": {"a": jax.random.normal(ks[0], (m, d, r)),
                  "b": jax.random.normal(ks[1], (m, r, d))},
            "v": {"a": jax.random.normal(ks[2], (m, d, r)),
                  "b": jax.random.normal(ks[3], (m, r, d))}}


# ---------------------------------------------------------------------------
# quantization core
# ---------------------------------------------------------------------------

def test_quantize_rows_int8_roundtrip_error_bound(key):
    x = jax.random.normal(key, (6, 200)) * jnp.asarray(
        [[0.01], [1.0], [100.0], [1e-4], [3.0], [7.0]])
    q, scale = mixing.quantize_rows(x, "int8")
    assert q.dtype == jnp.int8 and scale.shape == (6, 1)
    err = np.abs(np.asarray(mixing.dequantize_rows(q, scale)) -
                 np.asarray(x, np.float32))
    # round-to-nearest: per-element error <= scale/2 for every row
    assert (err <= 0.5 * np.asarray(scale) + 1e-12).all()
    # the row max maps to the top of the range
    assert (np.abs(np.asarray(q)).max(axis=1) == 127).all()


def test_quantize_rows_fp8_roundtrip(key):
    x = jax.random.normal(key, (4, 128))
    q, scale = mixing.quantize_rows(x, "fp8")
    assert q.dtype == jnp.float8_e4m3fn
    deq = np.asarray(mixing.dequantize_rows(q, scale))
    # e4m3 keeps ~2 decimal digits: relative row error well under 10%
    np.testing.assert_allclose(deq, np.asarray(x), atol=float(
        np.abs(np.asarray(x)).max()) * 0.1)


def test_quantize_rows_zero_row_is_exact():
    x = jnp.stack([jnp.zeros(64), jnp.ones(64)])
    for mode in ("int8", "fp8"):
        q, scale = mixing.quantize_rows(x, mode)
        deq = np.asarray(mixing.dequantize_rows(q, scale))
        np.testing.assert_array_equal(deq[0], np.zeros(64))   # no 0/0
        np.testing.assert_allclose(deq[1], np.ones(64), rtol=1e-2)


def test_quantize_rows_unknown_mode_raises(key):
    with pytest.raises(ValueError):
        mixing.quantize_rows(jnp.ones((2, 8)), "int4")


# ---------------------------------------------------------------------------
# the fused quant kernel vs its oracle
# ---------------------------------------------------------------------------

def test_gossip_mix_quant_kernel_interpret_vs_ref(key):
    from repro.kernels.gossip_mix import gossip_mix_quant
    m, P = 8, 1024
    ks = jax.random.split(key, 4)
    W = jax.random.uniform(ks[0], (m, m))
    W = W / W.sum(1, keepdims=True)
    w_off = W - jnp.diag(jnp.diag(W))
    w_diag = jnp.diag(W)[:, None]
    x = jax.random.normal(ks[1], (m, P))
    q, scale = mixing.quantize_rows(
        jax.random.normal(ks[2], (m, P)), "int8")
    seg = (jax.random.uniform(ks[3], (1, P)) > 0.5).astype(jnp.float32)
    y = gossip_mix_quant(w_off, q, scale, x, w_diag, seg, interpret=True)
    yr = ref.gossip_mix_quant_ref(w_off, q, scale, x, w_diag, seg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_ops_quant_dispatch_pads_non_multiple_P(key):
    """The ops wrapper pads q/x/seg to the kernel stripe and slices back;
    zero int8 pad columns dequantize to exact zeros, so padded-and-sliced
    equals the unpadded oracle."""
    m, P = 6, 700          # not a multiple of 512
    ks = jax.random.split(key, 3)
    w_off = jax.random.uniform(ks[0], (m, m)) * (1 - jnp.eye(m))
    w_diag = jax.random.uniform(ks[1], (m, 1))
    x = jax.random.normal(ks[2], (m, P))
    q, scale = mixing.quantize_rows(x, "int8")
    seg = jnp.ones((1, P), jnp.float32)
    expect = ref.gossip_mix_quant_ref(w_off, q, scale, x, w_diag, seg)
    prev = ops._FORCE
    ops.set_backend("pallas_interpret")
    try:
        got = ops.gossip_mix_quant(w_off, q, scale, x, w_diag, seg)
    finally:
        ops.set_backend(prev)
    assert got.shape == (m, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mix_tree_sparse quant semantics (degenerate single-process path)
# ---------------------------------------------------------------------------

def test_quant_mix_close_to_exact_and_updates_ef(key):
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    lora = _tree(key)
    plan = mixing.get_mix_plan(lora)
    ef0 = jnp.zeros((8, plan.cols), jnp.float32)
    exact = mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=None)
    for lowering in ("flat", "per_segment"):
        mixed, ef1 = mixing.mix_tree_sparse(
            W, lora, 1.0, 1.0, comm_plan=None, flat_lowering=lowering,
            quant="int8", ef=ef0)
        # int8 off-diagonal noise stays ~1% of the signal
        for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(mixed)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=0.05)
        assert ef1.shape == (8, plan.cols)
        assert float(jnp.abs(ef1).max()) > 0          # residual captured
        # EF is exactly the quantization residual of the source rows
        flat = jnp.concatenate(
            [jnp.moveaxis(x, -3, 0).reshape(8, -1)
             for x in jax.tree.leaves(lora)], axis=1)
        q, scale = mixing.quantize_rows(flat, "int8")
        np.testing.assert_allclose(
            np.asarray(ef1),
            np.asarray(flat - mixing.dequantize_rows(q, scale)),
            rtol=1e-5, atol=1e-7)


def test_quant_overlap_reads_prev_round_sources(key):
    """Under overlap the quantized off-diagonal terms read the PREVIOUS
    state: y = diag(W)·post + offdiag(W)·deq(Q(pre + ef))."""
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    post, pre = _tree(key), _tree(jax.random.fold_in(key, 1))
    plan = mixing.get_mix_plan(post)
    ef0 = jnp.zeros((8, plan.cols), jnp.float32)
    got, _ = mixing.mix_tree_sparse(W, post, 1.0, 1.0, comm_plan=None,
                                    lora_prev=pre, quant="int8", ef=ef0)
    pre_flat = np.concatenate(
        [np.moveaxis(np.asarray(x), -3, 0).reshape(8, -1)
         for x in jax.tree.leaves(pre)], axis=1)
    q, scale = mixing.quantize_rows(jnp.asarray(pre_flat), "int8")
    deq = np.asarray(mixing.dequantize_rows(q, scale))
    Wn = np.asarray(W)
    Wd, Wo = np.diag(np.diag(Wn)), Wn - np.diag(np.diag(Wn))
    post_flat = np.concatenate(
        [np.moveaxis(np.asarray(x), -3, 0).reshape(8, -1)
         for x in jax.tree.leaves(post)], axis=1)
    expect = Wd @ post_flat + Wo @ deq
    got_flat = np.concatenate(
        [np.moveaxis(np.asarray(x), -3, 0).reshape(8, -1)
         for x in jax.tree.leaves(got)], axis=1)
    np.testing.assert_allclose(got_flat, expect, rtol=1e-4, atol=1e-5)


def test_quant_requires_ef_and_known_mode(key):
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    lora = _tree(key)
    with pytest.raises(ValueError, match="error-feedback"):
        mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=None,
                               quant="int8")
    with pytest.raises(ValueError, match="quant mode"):
        mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=None,
                               quant="int4")


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------

def test_sparse_recv_bytes_quant_accounting():
    cp = comm.build_comm_plan(ring_graph(8), n_shards=2)
    cols = 512
    fp32 = cp.sparse_recv_bytes(cols)
    q = cp.sparse_recv_bytes_quant(cols)
    assert q == (1 * cols + 4) * cp.k * (cp.n_shards - 1)
    # the acceptance ratio: int8+scale <= 0.3x the fp32 sparse bytes
    assert q <= 0.3 * fp32
    assert comm.build_comm_plan(ring_graph(8),
                                n_shards=1).sparse_recv_bytes_quant(cols) == 0


# ---------------------------------------------------------------------------
# config / session surface
# ---------------------------------------------------------------------------

def test_mix_quant_config_validation_and_cache_key():
    assert _cfg().mix_quant == "off" or True     # default checked below
    assert DFLConfig(model="encoder", task="sst2",
                     model_kw=ENC_KW).mix_quant == "off"
    with pytest.raises(ValueError):
        _cfg(mix_quant="int4")
    with pytest.raises(ValueError):
        _cfg(mix_comm="dense", mix_quant="int8")   # quant needs sparse
    keys = {_cfg(mix_quant=m).cache_key() for m in ("off", "int8", "fp8")}
    assert len(keys) == 3, "mix_quant must enter the cache key"


def test_quant_round_signature_and_off_unchanged():
    """mix_quant='off' keeps the exact 6-arg round; quant rounds take the
    EF buffer and return it — the 'off' path is never re-traced or
    re-shaped by the feature existing."""
    off = Session(_cfg(mix_quant="off"))
    assert off.ef is None
    q = Session(_cfg(mix_quant="int8"))
    plan = mixing.get_mix_plan(q.lora)
    assert q.ef is not None and q.ef.shape == (8, plan.cols)
    assert off.round_fn is not q.round_fn
    res = q.run()
    assert np.isfinite(res.final_loss)
    assert float(jnp.abs(q.ef).max()) > 0


def test_quant_session_checkpoint_roundtrip(tmp_path):
    """save/restore carries the EF buffer: a restored quant session
    continues bit-for-bit with the original."""
    a = Session(_cfg(mix_quant="int8", rounds=4))
    a.run(2)
    ckpt = str(tmp_path / "q.npz")
    a.save(ckpt)
    b = Session(_cfg(mix_quant="int8", rounds=4))
    assert b.restore(ckpt) == 2
    np.testing.assert_array_equal(np.asarray(a.ef), np.asarray(b.ef))
    a.run(2)
    b.run(2)
    for x, y in zip(jax.tree.leaves(a.lora), jax.tree.leaves(b.lora)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(a.ef), np.asarray(b.ef))


def test_fp8_session_runs():
    res = Session(_cfg(mix_comm="sparse", mix_quant="fp8")).run()
    assert np.isfinite(res.final_loss)
