"""Serving engine + attribution + MoE dispatch equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serving import ServeEngine
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def served():
    cfg = get_config("gemma3-1b").reduced()
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


def _reference_generate(params, cfg, prompt, max_new):
    """Single-sequence greedy reference using a fresh cache."""
    cache = tf.init_cache(cfg, 1, 64)
    toks = list(prompt)
    logits = None
    for t in toks:
        logits, cache = tf.decode_step(params, cfg,
                                       jnp.asarray([[t]], jnp.int32), cache)
    out = []
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        out.append(nxt)
        logits, cache = tf.decode_step(params, cfg,
                                       jnp.asarray([[nxt]], jnp.int32),
                                       cache)
    return out


def test_engine_matches_single_sequence_reference(served):
    cfg, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 3, 7)]
    refs = [_reference_generate(params, cfg, p, 6) for p in prompts]

    eng = ServeEngine(params, cfg, n_slots=2, max_len=64)
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    # 3 requests on 2 slots forces continuous-batching turnover
    done = {}
    for _ in range(500):
        eng.tick()
        if not eng.queue and all(s.req is None for s in eng.slots):
            break
    # collect via the Request objects we submitted
    # (engine mutates them in place)
    # re-run to fetch: easier — engine stores reqs only in slots/queue;
    # hold our own handles:
    eng2 = ServeEngine(params, cfg, n_slots=2, max_len=64)
    handles = []
    for p in prompts:
        import repro.launch.serving as S
        r = S.Request(rid=len(handles), prompt=p, max_new=6)
        eng2.queue.append(r)
        handles.append(r)
    eng2.run()
    for r, ref in zip(handles, refs):
        assert r.done
        assert r.tokens_out == ref, (r.tokens_out, ref)


def test_slot_reuse_isolated(served):
    """A slot reused for a second request must give the same output as a
    fresh engine (per-slot t reset + validity masking isolate requests)."""
    cfg, params = served
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)

    import repro.launch.serving as S
    eng = ServeEngine(params, cfg, n_slots=1, max_len=64)
    r1 = S.Request(rid=0, prompt=p1, max_new=4)
    r2 = S.Request(rid=1, prompt=p2, max_new=4)
    eng.queue.extend([r1, r2])
    eng.run()
    ref2 = _reference_generate(params, cfg, p2, 4)
    assert r2.tokens_out == ref2


def test_moe_dispatch_equivalence(key):
    """dense and fused MoE dispatches are numerically identical."""
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek-moe-16b").reduced()
    params = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y1, a1 = moe_mod.moe_ffn(params, cfg, x, dispatch="dense")
    y2, a2 = moe_mod.moe_ffn(params, cfg, x, dispatch="fused")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_collective_attribution_parses():
    from repro.roofline.attribution import attribute_collectives, format_table
    hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8]{1,0} all-gather(%x), dimensions={0}, metadata={op_name="jit(f)/while/dot_general"}
  ROOT %t = tuple(...)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar = f32[4,4]{1,0} all-reduce(%a), metadata={op_name="jit(f)/loss"}
  %w = (s32[], f32[8,8]) while(%init), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    rows = attribute_collectives(hlo)
    assert rows[0].kind == "all-gather"
    assert rows[0].bytes_total == 5 * 256.0
    assert rows[0].occurrences == 5
    assert "dot_general" in rows[0].op_name
    assert "GB" in format_table(rows)
