"""Serving engine (single- and multi-adapter) + attribution + MoE dispatch
equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.serving import AdapterPool, ServingSession
from repro.configs import get_config
from repro.core.lora import build_lora_tree, client_slice, merge_lora
from repro.launch.serving import ServeEngine
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def served():
    cfg = get_config("gemma3-1b").reduced()
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def adapter_bank(served):
    """8 distinct nonzero adapters stacked on the client axis."""
    cfg, params = served
    tree = build_lora_tree(jax.random.key(3), params, cfg, n_clients=8)
    c = [0]

    def fill(x):
        c[0] += 1
        return 0.3 * jax.random.normal(jax.random.key(100 + c[0]), x.shape)
    return jax.tree.map(fill, tree)


def _reference_generate(params, cfg, prompt, max_new, lora=None):
    """Single-sequence greedy reference using a fresh cache."""
    cache = tf.init_cache(cfg, 1, 64)
    toks = list(prompt)
    logits = None
    for t in toks:
        logits, cache = tf.decode_step(params, cfg,
                                       jnp.asarray([[t]], jnp.int32), cache,
                                       lora=lora)
    out = []
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        out.append(nxt)
        logits, cache = tf.decode_step(params, cfg,
                                       jnp.asarray([[nxt]], jnp.int32),
                                       cache, lora=lora)
    return out


def test_engine_matches_single_sequence_reference(served):
    cfg, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 3, 7)]
    refs = [_reference_generate(params, cfg, p, 6) for p in prompts]

    eng = ServeEngine(params, cfg, n_slots=2, max_len=64)
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    # 3 requests on 2 slots forces continuous-batching turnover
    done = {}
    for _ in range(500):
        eng.tick()
        if not eng.queue and all(s.req is None for s in eng.slots):
            break
    # collect via the Request objects we submitted
    # (engine mutates them in place)
    # re-run to fetch: easier — engine stores reqs only in slots/queue;
    # hold our own handles:
    eng2 = ServeEngine(params, cfg, n_slots=2, max_len=64)
    handles = []
    for p in prompts:
        import repro.launch.serving as S
        r = S.Request(rid=len(handles), prompt=p, max_new=6)
        eng2.queue.append(r)
        handles.append(r)
    eng2.run()
    for r, ref in zip(handles, refs):
        assert r.done
        assert r.tokens_out == ref, (r.tokens_out, ref)


def test_slot_reuse_isolated(served):
    """A slot reused for a second request must give the same output as a
    fresh engine (per-slot t reset + validity masking isolate requests)."""
    cfg, params = served
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)

    import repro.launch.serving as S
    eng = ServeEngine(params, cfg, n_slots=1, max_len=64)
    r1 = S.Request(rid=0, prompt=p1, max_new=4)
    r2 = S.Request(rid=1, prompt=p2, max_new=4)
    eng.queue.extend([r1, r2])
    eng.run()
    ref2 = _reference_generate(params, cfg, p2, 4)
    assert r2.tokens_out == ref2


# ---------------------------------------------------------------------------
# multi-adapter serving (ServingSession / AdapterPool)
# ---------------------------------------------------------------------------

def test_multi_adapter_matches_per_adapter_decode(served, adapter_bank):
    """4 slots on 4 distinct adapters decode exactly what each adapter's
    own single-adapter decode produces (the slot gather is bit-for-bit the
    plain lora path), in one compiled step."""
    cfg, params = served
    pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=4, max_len=64)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(4)]
    names = ["client_1", "client_3", "client_5", "client_7"]
    rids = [serving.submit(p, adapter=nm, max_new=6)
            for p, nm in zip(prompts, names)]
    serving.run()
    for rid, p, nm in zip(rids, prompts, names):
        i = int(nm.split("_")[1])
        ref = _reference_generate(params, cfg, p, 6,
                                  lora=client_slice(adapter_bank, i))
        assert serving.result(rid) == ref, (nm, serving.result(rid), ref)
    assert serving.compile_count == 1


def test_multi_adapter_matches_merged_decode(served, adapter_bank):
    """Slot-served adapters reproduce the merged-weights model (ΔW folded
    into W) token-for-token for every slot."""
    cfg, params = served
    pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=2, max_len=64)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
               for _ in range(2)]
    rids = [serving.submit(p, adapter=f"client_{i}", max_new=5)
            for i, p in enumerate(prompts)]
    serving.run()
    for rid, p, i in zip(rids, prompts, range(2)):
        merged = merge_lora(params, client_slice(adapter_bank, i), cfg)
        ref = _reference_generate(merged, cfg, p, 5)
        assert serving.result(rid) == ref


def test_base_adapter_is_base_model(served, adapter_bank):
    """adapter=None (pool row 0, all zeros) decodes exactly the raw base
    model."""
    cfg, params = served
    pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=1, max_len=64)
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    toks = serving.generate(p, max_new=5)
    assert toks == _reference_generate(params, cfg, p, 5)
    with pytest.raises(KeyError):      # bad names rejected at submit,
        serving.submit(p, adapter="client_99")   # never mid-admission


def test_hot_swap_mid_stream_changes_only_swapped_slot(served, adapter_bank):
    """pool.update between ticks redirects ONLY the swapped slot's
    continuation; the other slot's stream is untouched."""
    cfg, params = served

    def fresh():
        pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
        s = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                           n_slots=2, max_len=64)
        rng = np.random.default_rng(5)
        pr = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
              for _ in range(2)]
        rids = [s.submit(pr[0], adapter="client_0", max_new=10),
                s.submit(pr[1], adapter="client_1", max_new=10)]
        return s, rids

    base_s, base_rids = fresh()
    base_s.run()
    base_out = [base_s.result(r) for r in base_rids]

    swap_s, swap_rids = fresh()
    for _ in range(7):          # 4 prompt ticks + 3 generated tokens
        swap_s.tick()
    pre = [list(swap_s.result(r)) for r in swap_rids]
    assert len(pre[1]) >= 2     # mid-stream, not pre-prefill
    big = jax.tree.map(lambda x: 5.0 * jnp.ones_like(x[..., 0, :, :]),
                       adapter_bank)
    swap_s.update_adapter("client_1", big)
    swap_s.run()
    out = [swap_s.result(r) for r in swap_rids]

    assert out[0] == base_out[0]                       # untouched slot
    assert out[1][:len(pre[1])] == base_out[1][:len(pre[1])]
    assert out[1] != base_out[1]                       # continuation moved
    assert swap_s.compile_count == 1                   # swap never retraced


def test_one_compile_across_adapter_counts(served, adapter_bank):
    """n_adapters ∈ {1, 4, 8} through one fixed-capacity pool = exactly
    one decode_step trace (adapter selection is data, not shape)."""
    cfg, params = served
    pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=4, max_len=64)
    rng = np.random.default_rng(6)
    for n_adapters in (1, 4, 8):
        for i in range(4):
            p = rng.integers(0, cfg.vocab_size, size=3).astype(np.int32)
            serving.submit(p, adapter=f"client_{i % n_adapters}", max_new=2)
        serving.run()
    assert serving.compile_count == 1


def test_adapter_pool_bookkeeping(served, adapter_bank):
    cfg, params = served
    pool = AdapterPool.from_stacked(adapter_bank, capacity=12)
    assert pool.row(None) == 0 and pool.row("base") == 0
    assert pool.row("client_2") == 3 and pool.row(5) == 5
    assert pool.ids[-1] == "consensus" and pool.capacity == 12
    with pytest.raises(KeyError):
        pool.row("nope")
    with pytest.raises(ValueError):
        pool.update("base", client_slice(adapter_bank, 0))
    # zero row: base adapter contributes nothing
    base = pool.adapter(None)
    assert all(float(jnp.abs(x).max()) == 0.0
               for x in jax.tree.leaves(base))
    # add into free rows until full
    free = pool.capacity - pool.n_adapters
    for j in range(free):
        pool.add(f"extra_{j}", client_slice(adapter_bank, 0))
    with pytest.raises(ValueError):
        pool.add("overflow", client_slice(adapter_bank, 0))


def test_serve_sync_tracks_training(served):
    """ServeSync pushes per-client + consensus adapters into a live
    ServingSession every round; pool rows equal the session's lora."""
    from repro.api import DFLConfig, ServeSync, Session
    from repro.core.lora import client_mean

    cfg = DFLConfig(model="gemma3-1b", task="lm", n_clients=4, rounds=2,
                    local_steps=1, batch_size=2, seq_len=16, T=1)
    sess = Session(cfg)
    serving = ServingSession.from_session(sess, n_slots=2, max_len=32)
    sess.callbacks.append(ServeSync(serving, every=1))
    sess.run()
    for i in range(4):
        want = sess.client_lora(i)
        got = serving.pool.adapter(f"client_{i}")
        for wl, gl in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(wl), np.asarray(gl))
    cons = serving.pool.adapter("consensus")
    for wl, gl in zip(jax.tree.leaves(client_mean(sess.lora)),
                      jax.tree.leaves(cons)):
        np.testing.assert_allclose(np.asarray(wl), np.asarray(gl),
                                   rtol=1e-6, atol=1e-7)


def test_moe_dispatch_equivalence(key):
    """dense and fused MoE dispatches are numerically identical."""
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek-moe-16b").reduced()
    params = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y1, a1 = moe_mod.moe_ffn(params, cfg, x, dispatch="dense")
    y2, a2 = moe_mod.moe_ffn(params, cfg, x, dispatch="fused")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_collective_attribution_parses():
    from repro.roofline.attribution import attribute_collectives, format_table
    hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8]{1,0} all-gather(%x), dimensions={0}, metadata={op_name="jit(f)/while/dot_general"}
  ROOT %t = tuple(...)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar = f32[4,4]{1,0} all-reduce(%a), metadata={op_name="jit(f)/loss"}
  %w = (s32[], f32[8,8]) while(%init), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    rows = attribute_collectives(hlo)
    assert rows[0].kind == "all-gather"
    assert rows[0].bytes_total == 5 * 256.0
    assert rows[0].occurrences == 5
    assert "dot_general" in rows[0].op_name
    assert "GB" in format_table(rows)


# ---------------------------------------------------------------------------
# request-lifecycle edge cases (scheduler-backed engine)
# ---------------------------------------------------------------------------

def test_submit_past_capacity_queues_then_drains(served):
    """More requests than slots: the excess queues (visible via the
    scheduler), admission backfills as slots free, everything completes
    in submission order for a single queue."""
    cfg, params = served
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=3).astype(np.int32)
               for _ in range(5)]
    eng = ServeEngine(params, cfg, n_slots=2, max_len=32)
    rids = [eng.submit(p, max_new=3) for p in prompts]
    assert eng.scheduler.n_queued == 5
    eng.tick()
    assert eng.scheduler.n_queued == 3          # 2 admitted, 3 waiting
    assert len(eng.queue) == 3                  # the queue view agrees
    eng.run()
    assert eng.scheduler.n_queued == 0
    assert all(eng.requests[r].done for r in rids)
    admits = [eng.requests[r].admit_tick for r in rids]
    assert admits == sorted(admits)             # FIFO admission order


def test_eos_recycles_slot_mid_stream(served):
    """A request hitting its eos_id mid-stream frees the slot THAT tick;
    the next queued request is admitted on the following tick and decodes
    as if it had a fresh engine."""
    cfg, params = served
    rng = np.random.default_rng(12)
    p1 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    # find the token p1 actually emits first, use it as the eos
    probe = ServeEngine(params, cfg, n_slots=1, max_len=64)
    r = probe.submit(p1, max_new=1)
    probe.run()
    eos = probe.requests[r].tokens_out[0]

    eng = ServeEngine(params, cfg, n_slots=1, max_len=64)
    r1 = eng.submit(p1, max_new=10, eos_id=eos)
    r2 = eng.submit(p2, max_new=4)
    while not eng.requests[r1].done:
        eng.tick()
    assert eng.requests[r1].tokens_out == [eos]     # stopped at eos, not 10
    assert eng.slots[0].req is None                 # freed immediately
    eng.run()
    assert eng.requests[r2].tokens_out == _reference_generate(
        params, cfg, p2, 4)


def test_hot_swap_applies_to_still_queued_requests(served, adapter_bank):
    """update_adapter while requests for that adapter are still QUEUED:
    they decode with the new weights once admitted (the pool is read per
    tick, never snapshotted at submit)."""
    cfg, params = served
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    new_w = jax.tree.map(lambda x: 3.0 * jnp.ones_like(x[..., 0, :, :]),
                         adapter_bank)

    # reference: engine whose pool ALREADY holds the new weights
    pool_ref = AdapterPool.from_stacked(adapter_bank, consensus=False)
    pool_ref.update("client_1", new_w)
    s_ref = ServingSession(model_cfg=cfg, params=params, adapters=pool_ref,
                           n_slots=1, max_len=64)
    want = s_ref.generate(prompt, adapter="client_1", max_new=4)

    pool = AdapterPool.from_stacked(adapter_bank, consensus=False)
    s = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                       n_slots=1, max_len=64)
    blocker = s.submit(prompt, adapter="client_0", max_new=2)
    queued = s.submit(prompt, adapter="client_1", max_new=4)
    s.tick()                                       # blocker holds the slot
    assert s.engine.scheduler.n_queued == 1
    s.update_adapter("client_1", new_w)            # swap while queued
    s.run()
    assert s.result(queued) == want
    assert s.result(blocker) != want               # old weights elsewhere
    assert s.compile_count == 1
