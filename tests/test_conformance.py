"""Theory-conformance tier (marker `conformance`, run via
`pytest -m conformance`): the paper's lemmas as executable assertions over
every communication condition in `repro.scenarios.SCENARIO_MATRIX`.

Per scenario:
  * W_t is doubly stochastic and non-negative every round; symmetric for
    every Metropolis-based schedule (Appendix A-A's mixing assumption);
  * the measured contraction respects Lemma A.10's functional form:
    1 − ρ̂ ≥ c_mix·p_eff·λ2(L) with a conservative empirical c_mix
    (calibrated ≥2x below the observed minimum across the matrix);
  * consensus distance under pure gossip is monotonically non-increasing
    (doubly-stochastic W never expands the consensus seminorm) and decays
    below a per-scenario target (Lemma A.4's frozen-block contraction);
  * the client mean is an exact invariant of mixing;
plus the overlapped-gossip staleness predicate (the one-round-delayed
mixing of `mix_comm="sparse_overlap"` contracts with a spectral gap no
worse than a constant fraction of Lemma A.10's dense bound, measured
through the real `mix_tree_sparse` path and cross-checked against its
companion-matrix spectrum), and two cross-scenario checks:
  * cross-term-vs-T monotonicity (Prop. A.5 / main theorem): under weak
    connectivity the tail-averaged ‖C‖ shrinks as T grows, and the larger
    topology-aware T is no worse in tail loss (T* ≍ 1/√(1−ρ) grows as the
    gap closes — Fig. 3's empirical direction);
  * the "W_t is data, not code" invariant: all scenarios run through one
    `Session`-compiled round — exactly one jit compilation at fixed shapes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import DFLConfig, HistoryRecorder, Session
from repro.control import FMMCWeightPolicy, weight_conformance
from repro.core import mixing
from repro.core.topology import (fastest_mixing_weights, lambda2,
                                 lemma_a10_gap_bound, metropolis_weights,
                                 underlying_graph)
from repro.scenarios import SCENARIO_MATRIX, estimate_rho_sq

pytestmark = pytest.mark.conformance

M = 8          # matrix-wide client count (torus 2x4, exponential = 3 hops)
C_MIX = 1 / 16  # conservative empirical Lemma A.10 constant (see docstring)

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _ids(matrix):
    return [s.name for s in matrix]


# ---------------------------------------------------------------------------
# W_t structure: doubly stochastic, non-negative, symmetric where declared
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", SCENARIO_MATRIX, ids=_ids(SCENARIO_MATRIX))
def test_w_doubly_stochastic_and_symmetric(scenario):
    sched = scenario.build(M, seed=0)
    mean = None
    for t in range(40):
        W = sched.next_w(t)
        assert W.shape == (M, M)
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-9,
                                   err_msg=f"{scenario.name} round {t}: "
                                           f"columns not stochastic")
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
        assert (W >= -1e-12).all(), f"{scenario.name}: negative weight"
        if sched.symmetric:
            np.testing.assert_allclose(W, W.T, atol=1e-12,
                                       err_msg=f"{scenario.name}: W_t not "
                                               f"symmetric")
        mean = W if mean is None else mean + W
    # sanity: the schedule communicates at all (mean W is not identity)
    assert np.abs(mean / 40 - np.eye(M)).max() > 1e-3


# ---------------------------------------------------------------------------
# Lemma A.10: 1 − ρ ≥ c_mix · p_eff · λ2(L), per phase
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", SCENARIO_MATRIX, ids=_ids(SCENARIO_MATRIX))
def test_contraction_respects_lemma_a10_bound(scenario):
    for label, adj, p_eff, factory in scenario.probes(M, seed=0):
        rho_sq = estimate_rho_sq(factory(), rounds=200,
                                 burn_in=scenario.burn_in)
        gap = 1.0 - float(np.sqrt(rho_sq))
        bound = lemma_a10_gap_bound(adj, p_eff, c_mix=C_MIX)
        tag = f"{scenario.name}{':' + label if label else ''}"
        assert gap >= bound, (
            f"{tag}: measured spectral gap {gap:.4f} below Lemma A.10 "
            f"bound c_mix*p_eff*lambda2 = {C_MIX:.4g}*{p_eff:.3g}*"
            f"{lambda2(adj):.3g} = {bound:.4f}")
        # the condition must actually contract (rho < 1) when connected
        assert rho_sq < 1.0 - 1e-6, f"{tag}: no contraction"


# ---------------------------------------------------------------------------
# pure-gossip consensus decay (Lemma A.4) + mean invariance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", SCENARIO_MATRIX, ids=_ids(SCENARIO_MATRIX))
def test_consensus_decay_under_pure_gossip(scenario):
    rng = np.random.default_rng(100)
    x = rng.normal(size=(M, 16))
    mean0 = x.mean(0).copy()
    sched = scenario.build(M, seed=0)
    d = d0 = float(np.sum((x - x.mean(0)) ** 2))
    for t in range(30):
        x = sched.next_w(t) @ x
        dn = float(np.sum((x - x.mean(0)) ** 2))
        # 1e-24 floor: once consensus is numerically exact (d ~ 1e-32 on
        # strong graphs) float noise may tick upward
        assert dn <= d * (1 + 1e-9) + 1e-24, (
            f"{scenario.name} round {t}: consensus distance expanded "
            f"{d:.3e} -> {dn:.3e}")
        d = dn
    assert d <= scenario.decay_target * d0, (
        f"{scenario.name}: decay {d / d0:.2e} above target "
        f"{scenario.decay_target}")
    np.testing.assert_allclose(x.mean(0), mean0, atol=1e-9,
                               err_msg=f"{scenario.name}: client mean not "
                                       f"preserved")


# ---------------------------------------------------------------------------
# heterogeneous clients: persistent stragglers + cold joiners (§VI-A)
# ---------------------------------------------------------------------------

def test_persistent_straggler_peff_is_minimum_edge_rate():
    """The p_eff fed to Lemma A.10 for persistent stragglers must be the
    MINIMUM per-edge activation rate p/period, not mean availability: the
    worst-mixed direction concentrates on the slow clients, whose edges
    fire only on wake rounds. Checks (a) the slow set is persistent and
    wakes synchronized, (b) empirical per-edge firing rates: slow-touching
    edges sit at p/period, fast-fast edges at p, (c) the measured
    contraction gap still clears c_mix·p_eff·λ2 at that conservative
    p_eff."""
    from repro.scenarios.schedule import PersistentStraggler
    p, period = 0.4, 3
    adj = underlying_graph("complete", M, seed=0)

    def fresh():
        return PersistentStraggler(adj, p, seed=0, frac=0.3, period=period)

    sched = fresh()
    slow = np.flatnonzero(sched.slow)
    assert 0 < len(slow) < M
    assert np.array_equal(np.flatnonzero(fresh().slow), slow)  # persistent
    p_eff = sched.p_eff()
    assert p_eff == pytest.approx(p / period)

    rounds = 4000
    fired = np.zeros((M, M))
    for t in range(rounds):
        W = sched.next_w(t)
        off = np.abs(W - np.diag(np.diag(W))) > 1e-12
        if t % period != 0:        # (a) off-wake rounds: slow edges silent
            assert not off[slow].any()
        fired += off
    rate = fired / rounds
    is_slow = sched.slow
    for i, j in np.argwhere(np.triu(adj, 1)):
        expect = p / period if (is_slow[i] or is_slow[j]) else p
        assert rate[i, j] == pytest.approx(expect, abs=0.04), (
            f"edge ({i},{j}) fired at {rate[i, j]:.3f}, expected "
            f"{expect:.3f}")
        assert rate[i, j] >= p_eff - 0.04     # p_eff IS the minimum

    rho_sq = estimate_rho_sq(fresh(), rounds=200)
    gap = 1.0 - float(np.sqrt(rho_sq))
    bound = lemma_a10_gap_bound(adj, p_eff, c_mix=C_MIX)
    assert gap >= bound, (
        f"persistent straggler: gap {gap:.4f} below Lemma A.10 bound "
        f"{bound:.4f} at p_eff = p/period")


def test_cold_join_consensus_within_staleness_budget():
    """Cold joiners hold identity rows (frozen state) until join_round;
    afterwards the consensus contraction must retain at least C_STALE of
    the Lemma A.10 gap at the stationary p_eff = p — joining late dilates
    the mixing time by a bounded factor instead of destroying the
    contraction. Also pins the join mechanics the Session warm-start hook
    relies on: join_events fires exactly once, and joiner state is
    bitwise frozen pre-join."""
    from repro.scenarios.schedule import ColdJoin
    p, join_round = 0.6, 6
    adj = underlying_graph("hierarchical", M, seed=0, hier_silos=3)

    def fresh():
        return ColdJoin(adj, p, seed=0, joiners=2, join_round=join_round)

    sched = fresh()
    joiners = list(sched.joiners)
    assert sched.join_events(join_round) == sched.joiners
    assert all(sched.join_events(t) == ()
               for t in range(20) if t != join_round)

    rng = np.random.default_rng(5)
    x = rng.normal(size=(M, 16))
    x0 = x.copy()
    eye = np.eye(M)
    for t in range(join_round):
        W = sched.next_w(t)
        for j in joiners:
            np.testing.assert_array_equal(W[j], eye[j])
            np.testing.assert_array_equal(W[:, j], eye[:, j])
        x = W @ x
    np.testing.assert_array_equal(x[joiners], x0[joiners])  # frozen

    # post-join: measure the per-round contraction over a 40-round window
    d_join = float(np.sum((x - x.mean(0)) ** 2))
    post = 40
    for t in range(join_round, join_round + post):
        x = sched.next_w(t) @ x
    d_end = float(np.sum((x - x.mean(0)) ** 2))
    rho_post = (d_end / d_join) ** (0.5 / post)
    bound = lemma_a10_gap_bound(adj, p, c_mix=C_MIX)
    assert 1.0 - rho_post >= C_STALE * bound, (
        f"cold join: post-join gap {1.0 - rho_post:.4f} below "
        f"{C_STALE} * Lemma A.10 bound {bound:.4f}")


# ---------------------------------------------------------------------------
# cross-term vs T (Prop. A.5 / main theorem) under weak connectivity
# ---------------------------------------------------------------------------

def _weak_run(T: int, seed: int):
    cfg = DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                    n_clients=6, rounds=24, local_steps=2, batch_size=8,
                    topology="complete", scenario="edge_activation", p=0.1,
                    method="tad", T=T, lr=1e-3, seed=seed, init_seed=42)
    rec = HistoryRecorder(consensus=True)
    Session(cfg, callbacks=[rec]).run()
    tail = rec.history[12:]
    return (float(np.mean([h["cross_norm"] for h in tail])),
            float(np.mean([h["loss"] for h in tail])))


def test_cross_term_decreases_with_T_weak_connectivity():
    """Prop. A.5: cycle-averaged ‖C‖ ~ η²/(T(1−ρ)) — at fixed seed budget
    under weak connectivity the tail cross-term at T=8 must sit well below
    T=1, and the larger (topology-aware) T must not lose on tail loss
    (Fig. 3: T* grows as connectivity weakens)."""
    seeds = (0, 1, 2)
    runs1 = [_weak_run(1, s) for s in seeds]
    runs8 = [_weak_run(8, s) for s in seeds]
    cross1 = float(np.mean([c for c, _ in runs1]))
    cross8 = float(np.mean([c for c, _ in runs8]))
    loss1 = float(np.mean([l for _, l in runs1]))
    loss8 = float(np.mean([l for _, l in runs8]))
    assert cross8 <= 0.8 * cross1, (
        f"cross-term did not shrink with T: T=1 {cross1:.3e} vs "
        f"T=8 {cross8:.3e}")
    assert loss8 <= loss1 + 5e-4, (
        f"topology-aware larger T lost on tail loss under weak "
        f"connectivity: T=8 {loss8:.5f} vs T=1 {loss1:.5f}")


# ---------------------------------------------------------------------------
# overlapped (one-round-delayed) gossip: staleness within Lemma A.10's gap
# ---------------------------------------------------------------------------

C_STALE = 0.5   # fraction of the dense Lemma A.10 gap the delayed
                # iteration must retain (measured ~3-5x above this floor)


def _overlap_rates(W_np: np.ndarray, rounds: int = 40, burn: int = 10):
    """Consensus contraction rates (fresh, delayed) measured through the
    REAL `mix_tree_sparse` code path — the delayed iteration is exactly
    what `mix_comm="sparse_overlap"` executes every round:
    x_{t+1} = diag(W)·x_t + offdiag(W)·x_{t-1}."""
    m = W_np.shape[0]
    W = jnp.asarray(W_np, jnp.float32)
    x0 = {"q": {"a": jax.random.normal(jax.random.PRNGKey(7), (m, 16, 4))}}

    def dist(tree):
        x = np.asarray(jax.tree.leaves(tree)[0], np.float64).reshape(m, -1)
        return float(np.sum((x - x.mean(0)) ** 2))

    fresh = jax.jit(lambda w, x: mixing.mix_tree_sparse(
        w, x, 1.0, 1.0, comm_plan=None))
    delayed = jax.jit(lambda w, x, xp: mixing.mix_tree_sparse(
        w, x, 1.0, 1.0, comm_plan=None, lora_prev=xp))

    rates = []
    for step in ("fresh", "delayed"):
        prev = cur = x0
        d_burn = None
        for t in range(rounds):
            nxt = fresh(W, cur) if step == "fresh" else delayed(W, cur, prev)
            prev, cur = cur, nxt
            if t == burn - 1:
                d_burn = dist(cur)
        d_end = dist(cur)
        assert d_end < d_burn, f"{step}: no contraction after burn-in"
        # distances are squared norms: per-round factor on d is rho^2
        rates.append((d_end / d_burn) ** (0.5 / (rounds - burn)))
    return rates[0], rates[1]


def _companion_rate(W_np: np.ndarray) -> float:
    """Asymptotic consensus-contraction rate of the delayed iteration:
    spectral radius of the companion system [[diag(W), offdiag(W)],
    [I, 0]] over the modes VISIBLE to consensus distance — eigenvectors
    whose state part lies in span(1) (the fixed point mu=1 AND the
    mu=-(1-d) consensus oscillation) never move x - x̄ and are excluded."""
    m = W_np.shape[0]
    D = np.diag(np.diag(W_np))
    comp = np.block([[D, W_np - D],
                     [np.eye(m), np.zeros((m, m))]])
    mu, vec = np.linalg.eig(comp)
    P = np.eye(m) - np.ones((m, m)) / m
    rates = []
    for i in range(2 * m):
        vx = vec[:m, i]
        dev = np.linalg.norm(P @ vx) / max(np.linalg.norm(vx), 1e-30)
        if dev > 1e-8:
            rates.append(abs(mu[i]))
    return float(max(rates))


@pytest.mark.parametrize("graph", ("ring", "torus", "exponential"))
def test_sparse_overlap_staleness_within_lemma_a10_bound(graph):
    """The one-round-delayed gossip of `mix_comm="sparse_overlap"` pays a
    bounded staleness penalty: it still contracts, never FASTER than
    fresh gossip (delay cannot speed mixing), its measured rate matches
    the companion-matrix prediction, and the surviving spectral gap stays
    above a constant fraction of Lemma A.10's dense lower bound
    c_mix·p_eff·λ2 — the delay dilates the mixing time by a bounded
    factor instead of destroying the contraction."""
    adj = underlying_graph(graph, M, seed=0)
    W_np = metropolis_weights(adj)
    rho_fresh, rho_delay = _overlap_rates(W_np)
    assert rho_delay < 1.0, f"{graph}: delayed gossip does not contract"
    assert rho_delay >= rho_fresh - 1e-3, (
        f"{graph}: staleness measured FASTER than fresh gossip "
        f"({rho_delay:.4f} < {rho_fresh:.4f}) — measurement broken")
    pred = _companion_rate(W_np)
    # finite horizon + transients: measured sits at or slightly below the
    # asymptotic companion rate (never meaningfully above)
    assert rho_delay <= pred + 0.02 and rho_delay >= pred - 0.08, (
        f"{graph}: measured delayed rate {rho_delay:.4f} far from "
        f"companion prediction {pred:.4f}")
    # gap check on the conservative (larger) of measured and predicted
    rho_delay = max(rho_delay, pred)
    bound = lemma_a10_gap_bound(adj, 1.0, c_mix=C_MIX)   # static: p_eff=1
    assert 1.0 - rho_delay >= C_STALE * bound, (
        f"{graph}: delayed spectral gap {1.0 - rho_delay:.4f} below "
        f"{C_STALE} * Lemma A.10 bound "
        f"{C_STALE:.2g}*{C_MIX:.4g}*{lambda2(adj):.3g} = "
        f"{C_STALE * bound:.4f} — staleness penalty unbounded")


# ---------------------------------------------------------------------------
# compressed gossip: EF residual within the Lemma A.10 contraction budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph", ("ring", "torus", "exponential"))
def test_quantized_gossip_within_lemma_a10_budget(graph):
    """int8 compressed gossip with error feedback keeps the consensus
    contraction, measured through the REAL `mix_tree_sparse` quant path:

      * the per-round EF residual stays within the Lemma A.10 contraction
        budget — ‖e_t‖ ≤ C_STALE·(c_mix·p_eff·λ2)·‖x_t + e_{t-1}‖, i.e.
        the quantization noise injected per round is a fraction of the
        contraction the mixing provides (int8 sits ~3x under the budget);
      * consensus distance decays monotonically while above the
        quantization-noise floor and lands ≥1e4x below its start —
        compression never destroys the decay Lemma A.4 promises.
    """
    adj = underlying_graph(graph, M, seed=0)
    W = jnp.asarray(metropolis_weights(adj), jnp.float32)
    x0 = {"q": {"a": jax.random.normal(jax.random.PRNGKey(7), (M, 16, 4))}}
    plan = mixing.get_mix_plan(x0)
    ef = jnp.zeros((M, plan.cols), jnp.float32)
    step = jax.jit(lambda w, x, e: mixing.mix_tree_sparse(
        w, x, 1.0, 1.0, comm_plan=None, quant="int8", ef=e))

    def dist(tree):
        x = np.asarray(jax.tree.leaves(tree)[0], np.float64).reshape(M, -1)
        return float(np.sum((x - x.mean(0)) ** 2))

    def flatten(tree):
        return jnp.concatenate(
            [jnp.moveaxis(x, -3, 0).reshape(M, -1)
             for x in jax.tree.leaves(tree)], axis=1)

    budget = C_STALE * lemma_a10_gap_bound(adj, 1.0, c_mix=C_MIX)
    cur = x0
    d = d0 = dist(cur)
    floor = 1e-5 * d0          # int8 noise floor (measured ~1e-6 relative)
    for t in range(40):
        s_norm = float(jnp.linalg.norm(flatten(cur) + ef))
        cur, ef = step(W, cur, ef)
        ef_rel = float(jnp.linalg.norm(ef)) / s_norm
        assert ef_rel <= budget, (
            f"{graph} round {t}: EF residual {ef_rel:.4f} of the signal "
            f"exceeds the Lemma A.10 contraction budget "
            f"{C_STALE}*{C_MIX:.4g}*{lambda2(adj):.3g} = {budget:.4f}")
        dn = dist(cur)
        if d > floor:
            assert dn <= max(d * (1 + 1e-6), floor), (
                f"{graph} round {t}: consensus distance expanded above "
                f"the noise floor ({d:.3e} -> {dn:.3e})")
        d = dn
    assert d <= 1e-4 * d0, (
        f"{graph}: quantized gossip decayed only {d / d0:.2e} of the "
        f"initial consensus distance")


# ---------------------------------------------------------------------------
# one compilation across the whole matrix ("W_t is data, not code")
# ---------------------------------------------------------------------------

def test_single_compilation_across_all_scenarios():
    """Every scenario at fixed shapes must reuse ONE compiled round: the
    build cache hands all sessions the same jitted function and its jit
    cache ends the sweep with exactly one entry."""
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=M,
                rounds=2, local_steps=1, batch_size=4, T=2, seed=0,
                lr=1.317e-3)   # unique lr -> private build-cache entry
    round_fns = set()
    losses = {}
    for sc in SCENARIO_MATRIX:
        session = Session(DFLConfig(**base, **sc.config_kw()))
        session.run()
        round_fns.add(session.round_fn)
        losses[sc.name] = float(session.last_metrics["loss"])
    assert len(round_fns) == 1, "scenarios built distinct round functions"
    (round_fn,) = round_fns
    assert round_fn._cache_size() == 1, (
        f"expected exactly 1 jit compilation across "
        f"{len(SCENARIO_MATRIX)} scenarios, got {round_fn._cache_size()}")
    assert all(np.isfinite(v) for v in losses.values())


# ---------------------------------------------------------------------------
# control plane: FMMC weight-policy predicates (closed-loop conformance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", SCENARIO_MATRIX, ids=_ids(SCENARIO_MATRIX))
def test_fmmc_gap_dominates_metropolis_per_family(scenario):
    """On every graph family of the matrix, the FMMC spectral gap must be
    no worse than Metropolis — structural (the solver initializes at the
    Metropolis edge weights and returns its best iterate), checked here on
    each scenario's per-phase underlying adjacency, alongside the mixing
    assumptions (symmetric, doubly stochastic, non-negative)."""
    for label, adj, _p_eff, _factory in scenario.probes(M, seed=0):
        tag = f"{scenario.name}{':' + label if label else ''}"
        m = adj.shape[0]
        J = np.ones((m, m)) / m
        gap_m = 1.0 - float(np.linalg.norm(metropolis_weights(adj) - J, 2))
        W = fastest_mixing_weights(adj)
        gap_f = 1.0 - float(np.linalg.norm(W - J, 2))
        assert gap_f >= gap_m - 1e-9, (
            f"{tag}: FMMC gap {gap_f:.4f} below Metropolis {gap_m:.4f}")
        np.testing.assert_allclose(W, W.T, atol=1e-12,
                                   err_msg=f"{tag}: FMMC W not symmetric")
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9,
                                   err_msg=f"{tag}: FMMC W not stochastic")
        assert (W >= -1e-12).all(), f"{tag}: negative FMMC weight"


@pytest.mark.parametrize("scenario", SCENARIO_MATRIX, ids=_ids(SCENARIO_MATRIX))
def test_fmmc_schedule_weights_conform(scenario):
    """Install the FMMC weight policy on each matrix schedule that admits
    one and check the realized W_t stream end-to-end: per-round structure
    plus the time-averaged contraction against the Lemma A.10 bound at the
    scenario's p_eff (`repro.control.weight_conformance` — the exact
    predicate the control plane emits)."""
    for label, adj, p_eff, factory in scenario.probes(M, seed=0):
        sched = factory()
        if not hasattr(sched, "set_weights"):
            pytest.skip(f"{scenario.name}: schedule draws its own W")
        sched.set_weights(FMMCWeightPolicy())
        burn = scenario.burn_in
        Ws = [sched.next_w(t) for t in range(burn + 200)][burn:]
        rep = weight_conformance(Ws, adj, p_eff=p_eff, c_mix=C_MIX)
        tag = f"{scenario.name}{':' + label if label else ''}"
        assert rep["ok"], (
            f"{tag}: FMMC stream fails conformance: gap {rep['gap']:.4f} "
            f"vs bound {rep['bound']:.4f}, sym_err {rep['sym_err']:.2e}, "
            f"ds_err {rep['ds_err']:.2e}, min_entry {rep['min_entry']:.2e}")
