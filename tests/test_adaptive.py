"""Adaptive-T controller (beyond-paper, §VII future work) tests."""
import numpy as np
import pytest

from repro.core.adaptive import AdaptiveTController, adaptive_round_masks
from repro.core.topology import make_topology


def test_spectral_estimator_tracks_rho():
    topo = make_topology("complete", 10, p=0.1, seed=0)
    true_rho = topo.rho_estimate(150)
    ctrl = AdaptiveTController(ewma=0.1)
    for _ in range(200):
        ctrl.observe_mixing_matrix(topo.sample())
    assert abs(np.sqrt(ctrl.rho_sq) - true_rho) < 0.05


def test_T_monotone_in_connectivity():
    ts = []
    for p in (0.8, 0.2, 0.05):
        topo = make_topology("complete", 10, p=p, seed=1)
        ctrl = AdaptiveTController(c=0.5, ewma=0.1)
        for _ in range(120):
            ctrl.observe_mixing_matrix(topo.sample())
        ts.append(ctrl.target_T())
    assert ts == sorted(ts), ts


def test_T_changes_only_at_phase_boundaries():
    ctrl = AdaptiveTController(c=1.0, t_max=8)
    ctrl.rho_sq = 0.99  # wants large T
    phases = []
    for _ in range(20):
        is_a, T = ctrl.step()
        phases.append((is_a, T))
    # T is constant within each contiguous phase
    runs = []
    cur = None
    for is_a, T in phases:
        if cur is None or is_a != cur[0]:
            runs.append((is_a, T, 1))
            cur = (is_a, T)
        else:
            assert T == runs[-1][1]   # unchanged mid-phase
            runs[-1] = (runs[-1][0], T, runs[-1][2] + 1)
    assert len(runs) >= 2


def test_frozen_contraction_probe():
    ctrl = AdaptiveTController(ewma=0.3)
    # simulate contraction ratio 0.25 => rho ~ 0.5
    d = 1.0
    for _ in range(60):
        ctrl.observe_frozen_contraction(d, 0.25 * d)
        d *= 0.25
        if d < 1e-10:
            d = 1.0
    assert abs(np.sqrt(ctrl.rho_sq) - 0.5) < 0.1


def test_frozen_probe_ignores_near_zero_prev():
    # a consensus probe at Δ²_prev ≈ 0 carries no contraction signal (the
    # frozen block already agrees); the update must be a no-op, not a 0/0
    ctrl = AdaptiveTController(ewma=0.3)
    before = ctrl.rho_sq
    ctrl.observe_frozen_contraction(0.0, 0.1)
    ctrl.observe_frozen_contraction(1e-13, 0.1)
    assert ctrl.rho_sq == before


def test_target_T_clips_at_bounds():
    ctrl = AdaptiveTController(c=1.0, t_min=2, t_max=6)
    ctrl.rho_sq = 0.0          # perfect mixing wants T < t_min
    assert ctrl.target_T() == 2
    ctrl.rho_sq = (1 - 1e-9) ** 2   # near-disconnected wants T >> t_max
    assert ctrl.target_T() == 6


def test_spectral_ewma_converges_on_fixed_ring():
    # a FIXED graph makes the EWMA fixed point exact: rho_sq -> ||W-J||_2^2
    from repro.core.topology import metropolis_weights, underlying_graph
    adj = underlying_graph("ring", 8)
    W = metropolis_weights(adj)
    J = np.ones((8, 8)) / 8
    true_sq = float(np.linalg.norm(W - J, 2)) ** 2
    ctrl = AdaptiveTController(ewma=0.2)
    for _ in range(120):
        ctrl.observe_mixing_matrix(W)
    assert abs(ctrl.rho_sq - true_sq) < 1e-9


def test_adaptive_masks_alternate():
    ctrl = AdaptiveTController()
    ctrl.rho_sq = 0.0  # T stays 1
    m1 = adaptive_round_masks(ctrl, "tad")
    m2 = adaptive_round_masks(ctrl, "tad")
    assert m1.update_a != m2.update_a
    assert m1.mix_a == m1.mix_b == 1.0  # joint mixing preserved
    with pytest.raises(ValueError):
        adaptive_round_masks(ctrl, "ffa")
