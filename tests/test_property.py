"""Property-based tests (hypothesis) on the system's invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
    # CI sets this so a broken hypothesis install FAILS the suite instead
    # of silently skipping the whole property tier
    import hypothesis
else:
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (optional dep)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import mix_tree, mix_tree_concat, sample_mixing_matrix
from repro.core.diagnostics import consensus_stats
from repro.core.topology import (complete_graph, lambda2, make_topology,
                                 ring_graph)

SETTINGS = dict(max_examples=25, deadline=None)


@given(m=st.integers(3, 12), p=st.floats(0.05, 1.0), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_mixing_matrix_doubly_stochastic(m, p, seed):
    """Lemma A.10: edge-activation pairwise averaging gives doubly-stochastic
    W_t for every sample."""
    rng = np.random.default_rng(seed)
    W = sample_mixing_matrix(complete_graph(m), p, rng)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
    assert (W >= -1e-12).all()


@given(m=st.integers(3, 10), p=st.floats(0.05, 1.0), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_gossip_preserves_mean(m, p, seed):
    """Doubly-stochastic mixing preserves the client average of every leaf
    (the conserved quantity behind the paper's consensus analysis)."""
    rng = np.random.default_rng(seed)
    W = jnp.asarray(sample_mixing_matrix(complete_graph(m), p, rng))
    x = jnp.asarray(rng.normal(size=(m, 4, 3)))
    tree = {"mod": {"a": x, "b": jnp.asarray(rng.normal(size=(m, 3, 5)))}}
    mixed = mix_tree(W, tree, 1.0, 1.0)
    for k in ("a", "b"):
        np.testing.assert_allclose(
            np.asarray(jnp.mean(mixed["mod"][k], 0)),
            np.asarray(jnp.mean(tree["mod"][k], 0)), atol=1e-6)


@given(m=st.integers(3, 8), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_mix_concat_equals_per_leaf(m, seed):
    """The fused single-buffer mixing lowering is numerically identical to
    per-leaf mixing."""
    rng = np.random.default_rng(seed)
    W = jnp.asarray(sample_mixing_matrix(complete_graph(m), 0.5, rng),
                    jnp.float32)
    tree = {"x": {"a": jnp.asarray(rng.normal(size=(m, 6, 2)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(m, 2, 7)), jnp.float32)},
            "y": {"a": jnp.asarray(rng.normal(size=(3, m, 4, 2)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(3, m, 2, 4)),
                                   jnp.float32)}}
    m1 = mix_tree(W, tree, 1.0, 0.3)
    m2 = mix_tree_concat(W, tree, 1.0, 0.3)
    for l1, l2 in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)


@given(m=st.integers(3, 8), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_cross_term_cauchy_schwarz(m, seed):
    """Appendix A-D: ||C|| <= ||Δ_A||·||Δ_B|| for any client states."""
    rng = np.random.default_rng(seed)
    tree = {"mod": {"a": jnp.asarray(rng.normal(size=(m, 8, 3))),
                    "b": jnp.asarray(rng.normal(size=(m, 3, 8)))}}
    s = consensus_stats(tree)
    assert float(s["cross_norm"]) <= float(s["cs_bound"]) + 1e-6


@given(m=st.integers(4, 12))
@settings(**SETTINGS)
def test_ring_worse_connected_than_complete(m):
    """λ2(ring) < λ2(complete) — the spectral ordering the paper's Table V
    stress test relies on."""
    assert lambda2(ring_graph(m)) < lambda2(complete_graph(m))


@given(seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_rho_decreases_with_p(seed):
    """Higher activation probability -> smaller ρ (Lemma A.10 scaling)."""
    t_lo = make_topology("complete", 8, p=0.05, seed=seed)
    t_hi = make_topology("complete", 8, p=0.8, seed=seed)
    assert t_hi.rho_estimate(60) < t_lo.rho_estimate(60)


@given(m=st.integers(3, 10), q=st.floats(0.1, 1.0), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_metropolis_doubly_stochastic_on_random_adjacency(m, q, seed):
    """Metropolis weights are symmetric doubly stochastic for ANY adjacency
    — including disconnected draws and isolated nodes (identity rows)."""
    from repro.core.topology import erdos_renyi_graph, metropolis_weights
    adj = erdos_renyi_graph(m, q, np.random.default_rng(seed))
    W = metropolis_weights(adj)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= 0).all()


@given(m=st.integers(3, 10), q=st.floats(0.2, 1.0), p=st.floats(0.05, 1.0),
       kind=st.sampled_from(["edge_activation", "churn", "straggler"]),
       seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_scenario_schedules_doubly_stochastic_on_random_adjacency(
        m, q, p, kind, seed):
    """Every W_t a scenario schedule emits over a random underlying graph
    is doubly stochastic — the invariant the convergence theory needs, and
    what the churn/straggler identity-row repair must preserve."""
    from repro.core.topology import erdos_renyi_graph
    from repro.scenarios import ClientChurn, EdgeActivation, StragglerDropout
    adj = erdos_renyi_graph(m, q, np.random.default_rng(seed))
    sched = {"edge_activation": lambda: EdgeActivation(adj, p, seed),
             "churn": lambda: ClientChurn(adj, p, seed, leave=0.3,
                                          rejoin=0.4),
             "straggler": lambda: StragglerDropout(adj, p, seed, drop=0.3),
             }[kind]()
    for t in range(5):
        W = sched.next_w(t)
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
        assert (W >= 0).all()
        np.testing.assert_allclose(W, W.T, atol=1e-12)


@given(m=st.integers(3, 10), q=st.floats(0.2, 1.0), p=st.floats(0.05, 1.0),
       seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_mixing_never_expands_consensus_distance(m, q, p, seed):
    """Doubly-stochastic mixing is non-expansive in the consensus seminorm
    Σ_i||x_i − x̄||² — the one-step form of Lemma A.4, for any graph."""
    from repro.core.topology import erdos_renyi_graph
    from repro.scenarios import EdgeActivation
    rng = np.random.default_rng(seed)
    adj = erdos_renyi_graph(m, q, rng)
    sched = EdgeActivation(adj, p, seed)
    x = rng.normal(size=(m, 7))
    d = float(np.sum((x - x.mean(0)) ** 2))
    for t in range(4):
        x = sched.next_w(t) @ x
        dn = float(np.sum((x - x.mean(0)) ** 2))
        # the 1e-24 floor absorbs float noise once consensus is numerically
        # exact (d ~ 1e-32 after a complete-graph round)
        assert dn <= d * (1 + 1e-9) + 1e-24
        d = dn


@given(m=st.integers(3, 10), q=st.floats(0.1, 1.0), p=st.floats(0.0, 1.0),
       seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_lemma_a10_bound_in_unit_interval(m, q, p, seed):
    from repro.core.topology import erdos_renyi_graph, lemma_a10_gap_bound
    adj = erdos_renyi_graph(m, q, np.random.default_rng(seed))
    b = lemma_a10_gap_bound(adj, p)
    assert 0.0 <= b <= 1.0


# ---------------------------------------------------------------------------
# data-layer partitioners (repro.data.partition)
# ---------------------------------------------------------------------------

_PARTITIONER_NAMES = ("iid", "dirichlet", "quantity", "domain", "paper")


def _labels_and_domains(n, n_classes, n_domains, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    # contiguous domain blocks (the layout shard writers produce)
    domains = np.sort(rng.integers(0, n_domains, size=n))
    return labels, domains


@given(name=st.sampled_from(_PARTITIONER_NAMES),
       n=st.integers(40, 400), n_classes=st.integers(2, 5),
       n_clients=st.integers(2, 10), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_partitioners_valid_partition(name, n, n_classes, n_clients, seed):
    """Every partitioner yields disjoint in-range index sets with every
    client owning >= 1 sample, and client label distributions are valid
    probability rows."""
    from repro.data import client_label_distributions, make_partition
    labels, domains = _labels_and_domains(n, n_classes,
                                          max(n_clients, 3), seed)
    parts = make_partition(name, labels, n_clients, seed=seed,
                           domains=domains)
    assert len(parts) == n_clients
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx)          # disjoint
    assert allidx.min() >= 0 and allidx.max() < n          # in range
    assert all(len(p) >= 1 for p in parts)                 # nobody empty
    dist = client_label_distributions(parts, labels, n_classes)
    assert (dist >= 0).all()
    np.testing.assert_allclose(dist.sum(1), 1.0, atol=1e-9)


@given(name=st.sampled_from(_PARTITIONER_NAMES),
       n=st.integers(50, 300), n_classes=st.integers(2, 4),
       n_clients=st.integers(2, 8), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_partitioners_deterministic_per_seed(name, n, n_classes, n_clients,
                                             seed):
    """Same (inputs, seed) -> bitwise identical partition; a different
    seed moves it (except the seed-free paper realization's class pools,
    which may coincide on tiny inputs — only sameness is asserted)."""
    from repro.data import make_partition
    labels, domains = _labels_and_domains(n, n_classes, 4, seed)
    a = make_partition(name, labels, n_clients, seed=seed, domains=domains)
    b = make_partition(name, labels, n_clients, seed=seed, domains=domains)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@given(alpha=st.floats(0.05, 0.3), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_dirichlet_concentration_controls_skew(alpha, seed):
    """Dirichlet label skew is monotone in concentration: a small alpha
    partition is measurably more skewed than the same draw at 100x the
    concentration (which approaches IID)."""
    from repro.data import label_skew, make_partition
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 3, size=600)
    lo = make_partition("dirichlet", labels, 8, seed=seed, alpha=alpha)
    hi = make_partition("dirichlet", labels, 8, seed=seed,
                        alpha=alpha * 100.0)
    assert label_skew(lo, labels, 3) > label_skew(hi, labels, 3)


@given(m=st.integers(2, 6), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_lora_merge_equals_adapter_forward(m, seed):
    """merge_lora(base, lora) forward == base forward with LoRA adapters
    (classifier substrate)."""
    from repro.core import build_lora_tree, client_slice, merge_lora
    from repro.models.classifier import (classifier_forward, encoder_config,
                                         init_classifier)
    cfg = encoder_config(n_layers=1, d_model=32, n_heads=2, d_ff=32,
                         vocab_size=64)
    key = jax.random.key(seed)
    base = init_classifier(key, cfg, n_classes=2)
    lora = build_lora_tree(jax.random.fold_in(key, 1), base, cfg,
                           n_clients=m)
    # give b random values (zero-init would make the test vacuous)
    lora = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(jax.random.fold_in(
            key, x.size % 97), x.shape), lora)
    toks = jax.random.randint(key, (3, 8), 0, cfg.vocab_size)
    li = client_slice(lora, 0)
    merged = merge_lora(base, li, cfg)
    y_adapter = classifier_forward(base, cfg, toks, lora=li)
    y_merged = classifier_forward(merged, cfg, toks)
    np.testing.assert_allclose(np.asarray(y_adapter), np.asarray(y_merged),
                               rtol=2e-4, atol=2e-4)
