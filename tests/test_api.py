"""`repro.api` contract tests: DFLConfig validation/keys, Session parity
against the legacy hand-wired round loop (bit-for-bit at fixed seed),
static-vs-adaptive MaskSchedule parity at T=1, checkpoint/resume replay,
callbacks, and the mix_flat_lowering knob."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AdaptiveSchedule, ConsoleLogger, DFLConfig,
                       HistoryRecorder, Session, StaticSchedule)
from repro.core import (build_lora_tree, make_dfl_round, make_topology,
                        mixing, round_masks)
from repro.data.synthetic import lm_token_stream
from repro.optim import AdamW

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _clf_config(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=4,
                rounds=4, local_steps=2, batch_size=8, p=1.0, T=2,
                lr=1e-3, seed=0)
    base.update(kw)
    return DFLConfig(**base)


# ---------------------------------------------------------------------------
# DFLConfig
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        DFLConfig(method="sgd")                      # unknown method
    with pytest.raises(ValueError):
        DFLConfig(task="imagenet")                   # unknown task
    with pytest.raises(ValueError):
        DFLConfig(task="sst2", model="gemma3-1b")    # classifier != encoder
    with pytest.raises(ValueError):
        DFLConfig(task="lm", model="encoder")        # lm needs an arch
    with pytest.raises(ValueError):
        DFLConfig(mix_impl="magic")
    with pytest.raises(ValueError):
        DFLConfig(mix_flat_lowering="sometimes")
    with pytest.raises(ValueError):
        DFLConfig(rounds=0)
    with pytest.raises(ValueError):
        DFLConfig(adaptive_T=True, method="ffa")     # non-alternating


def test_config_seed_defaults_and_key():
    c = DFLConfig(seed=5)
    assert c.data_seed == 5 and c.init_seed == 5
    # explicit resolution matches defaulted resolution -> same key
    assert c.cache_key() == DFLConfig(seed=5, data_seed=5,
                                      init_seed=5).cache_key()
    assert c.cache_key() != DFLConfig(seed=6).cache_key()
    # model_kw dict vs tuple normalizes identically; json round-trips
    a = _clf_config()
    b = DFLConfig.from_dict(a.to_dict())
    assert a == b and a.cache_key() == b.cache_key()


def test_replace_rederives_dependent_seeds():
    # seed sweeps via replace() must move data/init seeds along
    c1 = DFLConfig(seed=0).replace(seed=1)
    assert c1.data_seed == 1 and c1.init_seed == 1
    assert c1 == DFLConfig(seed=1)
    # explicitly pinned seeds stay pinned across a seed change
    c2 = DFLConfig(seed=0, data_seed=17, init_seed=99).replace(seed=1)
    assert c2.data_seed == 17 and c2.init_seed == 99
    # explicit override together with the seed change wins
    c3 = DFLConfig(seed=0).replace(seed=1, data_seed=5)
    assert c3.data_seed == 5 and c3.init_seed == 1


# ---------------------------------------------------------------------------
# Session vs the legacy hand-wired loop (the quickstart setting, shrunk)
# ---------------------------------------------------------------------------

def test_session_matches_handwired_quickstart_loop():
    """Session must reproduce the hand-wired quickstart loop bit-for-bit
    at fixed seed: same per-round losses, same final lora. The legacy
    loop below is the pre-api quickstart BODY under the api's documented
    seed conventions (base <- key(seed), lora <- key(seed+1); the
    pre-api script drew both from key(0)) — the parity proven is of the
    loop mechanics, not of the init-key convention, which deliberately
    changed in the migration."""
    from repro.configs import get_config
    from repro.models import transformer as tf

    M, ROUNDS, LS, B, S = 4, 4, 2, 2, 16
    config = DFLConfig(model="gemma3-1b", task="lm", n_clients=M,
                       rounds=ROUNDS, local_steps=LS, batch_size=B,
                       seq_len=S, method="tad", p=0.15, T=3, lr=1e-3,
                       seed=0)

    # --- legacy hand-wired loop (pre-api quickstart body) ---
    cfg = get_config("gemma3-1b").reduced()
    base = tf.init_params(jax.random.key(0), cfg)
    lora = build_lora_tree(jax.random.key(1), base, cfg, n_clients=M)
    topo = make_topology("complete", M, p=0.15, seed=0)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(lora)

    def loss_fn(bp, lo, micro):
        # per_client=True mirrors the api's loss_fn: the REPORTED loss is
        # the host-side fixed-order reduction of the per-client vector
        # (grid-invariant), while the in-graph scalar feeds the gradient
        out, per = tf.lm_loss(bp, cfg, micro["tokens"], micro["targets"],
                              frontend=micro.get("frontend"), lora=lo,
                              per_client=True)
        return out[0], per

    from repro.api.session import _metric_loss
    round_fn = jax.jit(make_dfl_round(loss_fn, opt, local_steps=LS))
    stream = lm_token_stream(cfg.vocab_size, B * LS, S, n_clients=M, seed=0)
    legacy_losses = []
    for t in range(ROUNDS):
        raw = next(stream)
        batch = {k: jnp.asarray(v.reshape(M, LS, B, S).swapaxes(0, 1))
                 for k, v in raw.items()}
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks("tad", t, 3).as_array()
        lora, opt_state, metrics = round_fn(base, lora, opt_state, batch,
                                            W, masks)
        legacy_losses.append(_metric_loss(metrics))

    # --- the same experiment through the declarative API ---
    rec = HistoryRecorder()
    session = Session(config, callbacks=[rec])
    session.run()

    assert [h["loss"] for h in rec.history] == legacy_losses
    for a, b in zip(jax.tree.leaves(session.lora), jax.tree.leaves(lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# MaskSchedule: adaptive-vs-static parity at T=1
# ---------------------------------------------------------------------------

def test_adaptive_matches_static_at_T1():
    """With c small the controller pins T=1 for any observed rho, so the
    adaptive schedule must emit exactly the static T=1 mask calendar and
    the two runs must agree bit-for-bit."""
    config = _clf_config(T=1, rounds=6, p=0.5)
    static = Session(config, schedule=StaticSchedule("tad", T=1))
    adaptive_sched = AdaptiveSchedule("tad", c=0.1)
    adaptive = Session(config, schedule=adaptive_sched)
    r_s = static.run()
    r_a = adaptive.run()
    assert adaptive_sched.t_trace == [1] * 6
    assert r_s.final_loss == r_a.final_loss
    for a, b in zip(jax.tree.leaves(static.lora),
                    jax.tree.leaves(adaptive.lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adaptive_schedule_observes_W():
    sched = AdaptiveSchedule("tad", c=1.0, t_max=8)
    rho0 = sched.controller.rho_sq
    topo = make_topology("complete", 6, p=0.1, seed=0)
    for t in range(10):
        sched.next_masks(t, {"W": topo.sample()})
    assert sched.controller.rho_sq != rho0           # estimator engaged
    assert len(sched.t_trace) == 10


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def test_checkpoint_resume_bitwise(tmp_path):
    path = os.path.join(tmp_path, "sess.npz")
    config = _clf_config(rounds=6, p=0.5)
    full = Session(config)
    full.run(3)
    full.save(path)
    full.run(3)

    resumed = Session(config)
    assert resumed.restore(path) == 3
    resumed.run(3)
    assert resumed.t == full.t == 6
    for a, b in zip(jax.tree.leaves(full.lora),
                    jax.tree.leaves(resumed.lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(full.opt_state.mu),
                    jax.tree.leaves(resumed.opt_state.mu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class _WRecorder:
    """Callback capturing every round's realized W_t."""

    def __init__(self):
        self.Ws = []

    def on_round_end(self, ev):
        self.Ws.append(np.asarray(ev.W).copy())

    def on_run_end(self, session, result):
        pass


def test_checkpoint_resume_time_varying_topology_schedule(tmp_path):
    """Resume under a TIME-VARYING TopologySchedule (client churn: a
    stateful per-node Markov chain) must replay the W_t stream bit-for-bit:
    the resumed run's mixing matrices, lora, and opt state all match the
    uninterrupted run exactly."""
    path = os.path.join(tmp_path, "churn.npz")
    config = _clf_config(rounds=6, topology="torus", scenario="churn",
                         p=0.6, scenario_kw={"leave": 0.3, "rejoin": 0.4})
    full_rec = _WRecorder()
    full = Session(config, callbacks=[full_rec])
    full.run(3)
    full.save(path)
    full.run(3)

    res_rec = _WRecorder()
    resumed = Session(config, callbacks=[res_rec])
    assert resumed.restore(path) == 3
    resumed.run(3)
    assert resumed.t == full.t == 6
    # the churn Markov state was replayed: rounds 3..5 produce identical W_t
    assert len(full_rec.Ws) == 6 and len(res_rec.Ws) == 3
    for a, b in zip(full_rec.Ws[3:], res_rec.Ws):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(full.lora),
                    jax.tree.leaves(resumed.lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(full.opt_state.mu),
                    jax.tree.leaves(resumed.opt_state.mu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# callbacks / events
# ---------------------------------------------------------------------------

def test_callbacks_and_events(capsys):
    rec_all = HistoryRecorder(consensus=True)
    rec_sub = HistoryRecorder(every=2)
    session = Session(_clf_config(), callbacks=[
        rec_all, rec_sub, ConsoleLogger(every=2, consensus=True)])
    result = session.run()
    assert [h["round"] for h in rec_all.history] == [0, 1, 2, 3]
    assert {"cross_norm", "delta_a_sq", "delta_b_sq"} <= \
        set(rec_all.history[0])
    # every=2 + the forced final round
    assert [h["round"] for h in rec_sub.history] == [0, 2, 3]
    assert result.final_loss == rec_all.history[-1]["loss"]
    out = capsys.readouterr().out
    assert "round" in out and "‖C‖" in out
    ev = session.step()                              # single-round stepping
    assert ev.t == 4 and session.t == 5
    assert 0.0 <= ev.w_gap() <= 1.0 + 1e-6


def test_evaluate_classifier_only():
    session = Session(_clf_config())
    res = session.evaluate()
    assert set(res) == {"acc", "acc_std_clients", "per_client"}
    assert len(res["per_client"]) == 4
    lm = Session(DFLConfig(model="gemma3-1b", task="lm", n_clients=4,
                           rounds=2, local_steps=1, batch_size=2,
                           seq_len=16, T=1))
    with pytest.raises(ValueError):
        lm.evaluate()


# ---------------------------------------------------------------------------
# mix_flat_lowering knob
# ---------------------------------------------------------------------------

def test_flat_lowering_knob_resolution():
    assert mixing.use_flat_lowering("flat") is True
    assert mixing.use_flat_lowering("per_segment") is False
    on_tpu = jax.default_backend() == "tpu"
    assert mixing.use_flat_lowering("auto") is on_tpu
    with pytest.raises(ValueError):
        mixing.use_flat_lowering("sometimes")
    prev = mixing.set_flat_lowering("per_segment")
    try:
        assert mixing.flat_lowering_mode() == "per_segment"
        assert mixing.use_flat_lowering() is False
    finally:
        mixing.set_flat_lowering(prev)
    with pytest.raises(ValueError):
        mixing.set_flat_lowering("sometimes")


def test_flat_and_per_segment_lowerings_agree(key):
    """Forcing the flat (m, P) buffer off-TPU must stay numerically equal
    to the per-segment dots (the gated path is a lowering, not a math
    change)."""
    m = 4
    tree = {"l": {"a": jax.random.normal(key, (m, 12, 4)),
                  "b": jax.random.normal(jax.random.fold_in(key, 1),
                                         (m, 4, 12))}}
    W = jnp.full((m, m), 1.0 / m, jnp.float32)
    flat = mixing.mix_tree_planned(W, tree, 1.0, 0.3, flat_lowering="flat")
    seg = mixing.mix_tree_planned(W, tree, 1.0, 0.3,
                                  flat_lowering="per_segment")
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(seg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# build cache
# ---------------------------------------------------------------------------

def test_build_cache_shared_across_seeds():
    """Sweeps that vary only data/topology (pinned init_seed, the
    benchmark convention) share one model init and one compiled round."""
    from repro.api.session import _BUILD_CACHE
    s0 = Session(_clf_config(seed=11, init_seed=99))
    n = len(_BUILD_CACHE)
    s1 = Session(_clf_config(seed=12, init_seed=99, p=0.3, T=5))
    assert len(_BUILD_CACHE) == n
    assert s0.round_fn is s1.round_fn
    assert s0.base is s1.base
    Session(_clf_config(seed=11, init_seed=99, lr=2e-3))  # new build: lr
    assert len(_BUILD_CACHE) == n + 1
