"""Tier-1 units for the topology-sparse gossip comm layer.

Covers the pure-data side in-process: `repro.dist.comm.CommPlan`
compilation (peer sets, export tables, byte accounting),
`repro.scenarios.schedule.schedule_support` union supports, the
single-process degenerate numerics of `mix_tree_sparse` (bitwise equal to
the dense planned path; overlap mode well-defined and genuinely delayed),
and the `mix_comm` config surface (validation, cache keys, session
threading). The REAL process grids live in `-m multihost`
(tests/test_multihost.py); the staleness bound in `-m conformance`.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import DFLConfig, Session
from repro.core import mixing
from repro.core.topology import metropolis_weights, ring_graph, torus_graph
from repro.dist import comm
from repro.scenarios import get_scenario
from repro.scenarios.schedule import schedule_support

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _cfg(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=8,
                rounds=3, local_steps=2, batch_size=8, topology="ring",
                scenario="static", p=0.5, T=2, lr=1e-3, seed=0)
    base.update(kw)
    return DFLConfig(**base)


def _tree(key, m=8, d=16, r=4):
    ks = jax.random.split(key, 4)
    return {"q": {"a": jax.random.normal(ks[0], (m, d, r)),
                  "b": jax.random.normal(ks[1], (m, r, d))},
            "v": {"a": jax.random.normal(ks[2], (m, d, r)),
                  "b": jax.random.normal(ks[3], (m, r, d))}}


# ---------------------------------------------------------------------------
# CommPlan compilation: structure, peers, padding, bytes
# ---------------------------------------------------------------------------

def test_comm_plan_ring_structure():
    """Ring, 8 clients over 4 shards: each shard owns 2 clients and only
    its two ring neighbours' border rows cross shard boundaries."""
    cp = comm.build_comm_plan(ring_graph(8), n_shards=4)
    assert (cp.m, cp.n_shards, cp.m_loc) == (8, 4, 2)
    # every owned row is a border row on a 2-client shard -> k = 2
    assert cp.k == 2
    # shard p talks exactly to its ring neighbours (p-1, p+1) mod 4
    for p in range(4):
        assert cp.recv_peers[p] == tuple(sorted({(p - 1) % 4, (p + 1) % 4}))
        assert cp.send_peers[p] == cp.recv_peers[p]
    # export tables address real local rows and agree with global ids
    assert cp.export_local.shape == (4, 2)
    assert ((0 <= cp.export_local) & (cp.export_local < 2)).all()
    np.testing.assert_array_equal(
        cp.export_global.reshape(4, 2),
        cp.export_local + (np.arange(4) * 2)[:, None])


def test_comm_plan_two_shards_vs_dense():
    """On 2 shards of a ring each side needs both of the other side's
    border rows; a complete graph needs ALL remote rows — sparse bytes
    then equal the dense all-gather exactly (no double counting)."""
    ring = comm.build_comm_plan(ring_graph(8), n_shards=2)
    assert ring.k == 2 and ring.cross_edges == 4
    full = comm.build_comm_plan(np.ones((8, 8), bool), n_shards=2)
    assert full.k == 4
    cols = 96
    assert full.sparse_recv_bytes(cols) == comm.dense_recv_bytes(8, 2, cols)
    assert ring.sparse_recv_bytes(cols) < comm.dense_recv_bytes(8, 2, cols)


def test_comm_plan_torus_asymmetric_exports_pad():
    """2x4 torus over 4 shards: column-pair shards export BOTH rows, so
    uneven needs still compile to one rectangular (n, k) table whose pad
    slots are real local rows (value-identical duplicate scatters)."""
    cp = comm.build_comm_plan(torus_graph(8, 2, 4), n_shards=4)
    assert cp.k >= 1
    for p in range(4):
        # padded entries remain valid local indices
        assert ((0 <= cp.export_local[p]) & (cp.export_local[p] < cp.m_loc)).all()
    owner = np.arange(8) // 2
    # every support edge crossing shards is covered by an export
    exported = set(cp.export_global.tolist())
    for i in range(8):
        for j in range(8):
            if cp.support[i, j] and owner[i] != owner[j]:
                assert j in exported, f"row {j} needed by {i} not exported"


def test_comm_plan_single_shard_degenerate():
    cp = comm.build_comm_plan(ring_graph(8), n_shards=1)
    assert cp.k == 0 and cp.cross_edges == 0
    assert cp.sparse_recv_bytes(100) == 0
    assert comm.dense_recv_bytes(8, 1, 100) == 0
    assert cp.recv_peers == ((),) and cp.send_peers == ((),)


def test_comm_plan_validation_errors():
    with pytest.raises(ValueError):
        comm.build_comm_plan(np.ones((3, 4)), n_shards=2)      # not square
    with pytest.raises(ValueError):
        comm.build_comm_plan(ring_graph(8), n_shards=3)        # 8 % 3 != 0


def test_comm_plan_signature_distinguishes():
    a = comm.build_comm_plan(ring_graph(8), n_shards=4)
    b = comm.build_comm_plan(ring_graph(8), n_shards=2)
    c = comm.build_comm_plan(torus_graph(8, 2, 4), n_shards=4)
    assert len({a.signature(), b.signature(), c.signature()}) == 3
    # deterministic: same inputs, same id
    assert a.signature() == comm.build_comm_plan(ring_graph(8),
                                                 n_shards=4).signature()


# ---------------------------------------------------------------------------
# schedule_support: union supports of the scenario schedules
# ---------------------------------------------------------------------------

def test_schedule_support_static_is_graph():
    sched = get_scenario("complete-static").build(8, seed=0)
    sup = schedule_support(sched)
    assert sup.dtype == bool and sup.all()


def test_schedule_support_gossip_transitive_closure():
    """A gossip round applies a PRODUCT of pair averagings, so one round
    can couple clients beyond graph edges — the support must be the
    transitive closure (complete on a connected graph), not the edge set."""
    sched = get_scenario("complete-gossip").build(8, seed=0)
    assert schedule_support(sched).all()


def test_schedule_support_edge_activation_is_edges():
    """Edge activation masks single edges of the underlying graph: the
    union support is exactly graph ∪ diagonal, never more."""
    sched = get_scenario("ring-edge").build(8, seed=0)
    sup = schedule_support(sched)
    expect = ring_graph(8).astype(bool) | np.eye(8, dtype=bool)
    np.testing.assert_array_equal(sup, expect)
    # and a long W_t sample stream stays inside the declared support
    for t in range(50):
        W = sched.next_w(t)
        assert (np.abs(W[~sup]) == 0).all(), f"round {t} left the support"


# ---------------------------------------------------------------------------
# mix_tree_sparse numerics (single-process degenerate path)
# ---------------------------------------------------------------------------

def test_sparse_mix_bitwise_equals_dense():
    """The sparse contraction is the SAME arithmetic as the planned dense
    path at the same operand layout: bitwise at the BINARY masks every
    paper method actually passes (RoundMasks are 0/1 scalars), float-equal
    at fractional (damped-variant) masks where the blend forms differ,
    with and without a (1-shard) CommPlan attached."""
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    lora = _tree(jax.random.PRNGKey(0))
    cp = comm.build_comm_plan(ring_graph(8), n_shards=1)
    for ma, mb in ((1.0, 1.0), (1.0, 0.0), (0.0, 1.0), (0.3, 0.8)):
        binary = {ma, mb} <= {0.0, 1.0}
        dense = mixing.mix_tree_planned(W, lora, ma, mb,
                                        flat_lowering="flat")
        for plan in (None, cp):
            for lowering in ("flat", "per_segment"):
                sparse = mixing.mix_tree_sparse(W, lora, ma, mb,
                                                comm_plan=plan,
                                                flat_lowering=lowering)
                for x, y in zip(jax.tree.leaves(dense),
                                jax.tree.leaves(sparse)):
                    if binary or lowering == "flat":
                        np.testing.assert_array_equal(np.asarray(x),
                                                      np.asarray(y))
                    else:
                        np.testing.assert_allclose(np.asarray(x),
                                                   np.asarray(y),
                                                   rtol=1e-5, atol=1e-6)


def test_sparse_overlap_delayed_semantics():
    """Overlap mode must equal the hand-computed delayed-gossip identity
    y = W_diag·x_post + W_offdiag·x_pre (per column segment blend), and
    reduce to plain sparse when pre == post."""
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    post = _tree(jax.random.PRNGKey(1))
    pre = _tree(jax.random.PRNGKey(2))

    # pre == post collapses to fresh mixing ARITHMETICALLY (the split-out
    # diagonal term changes summation order, so equality is to float
    # tolerance, not bitwise — bitwise is dense-vs-sparse's contract)
    same = mixing.mix_tree_sparse(W, post, 1.0, 1.0, comm_plan=None,
                                  lora_prev=post)
    plain = mixing.mix_tree_sparse(W, post, 1.0, 1.0, comm_plan=None)
    for x, y in zip(jax.tree.leaves(same), jax.tree.leaves(plain)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)

    got = mixing.mix_tree_sparse(W, post, 1.0, 1.0, comm_plan=None,
                                 lora_prev=pre)
    Wd = np.diag(np.diag(np.asarray(W)))
    Wo = np.asarray(W) - Wd
    for g, xp, xq in zip(jax.tree.leaves(got), jax.tree.leaves(post),
                         jax.tree.leaves(pre)):
        expect = (np.einsum("ij,jdr->idr", Wd, np.asarray(xp)) +
                  np.einsum("ij,jdr->idr", Wo, np.asarray(xq)))
        np.testing.assert_allclose(np.asarray(g), expect,
                                   rtol=1e-5, atol=1e-6)
    # and it genuinely differs from fresh mixing when pre != post
    fresh = jax.tree.leaves(plain)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(got), fresh))


class _FakeMesh:
    def __init__(self, size, axis_names):
        self.size = size
        self.axis_names = axis_names


def test_sparse_mesh_plan_mismatch_raises(monkeypatch):
    """A bound mesh whose shape cannot host the CommPlan used to fall
    through to the degenerate local contraction — parity held but the
    sparse savings silently vanished. It must refuse instead; the
    plan-less call (conformance probes, rate measurements) keeps the
    degenerate path."""
    from repro.dist import sharding
    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    lora = _tree(jax.random.PRNGKey(4))
    cp = comm.build_comm_plan(ring_graph(8), n_shards=2)

    monkeypatch.setattr(sharding, "current_mesh",
                        lambda: _FakeMesh(4, ("x",)))
    with pytest.raises(ValueError, match="4 devices"):
        mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=cp)

    monkeypatch.setattr(sharding, "current_mesh",
                        lambda: _FakeMesh(4, ("x", "y")))
    with pytest.raises(ValueError, match="1-D mesh"):
        mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=cp)

    # comm_plan=None under a multi-device mesh stays degenerate (the
    # conformance tier's rate probes depend on it)
    out = mixing.mix_tree_sparse(W, lora, 1.0, 1.0, comm_plan=None)
    assert jax.tree.structure(out) == jax.tree.structure(lora)


def test_sparse_lowering_auto_pins_flat():
    """`sparse_use_flat` auto pins the flat fused dot exactly where the
    fused gossip kernel lives (TPU meshes) and per-slot dots elsewhere —
    the dense path's heuristic, VALIDATED for the sparse path by the
    BENCH_multihost.json `sparse_lowering` probe (the sunk-flat-buffer
    argument for always-flat measured slower on CPU: the per-column seg
    blend costs more than per-slot scalar blends). Explicit pins always
    win, and BOTH lowerings stay bitwise equal."""
    on_tpu = jax.default_backend() == "tpu"
    assert mixing.sparse_use_flat("auto") is on_tpu
    assert mixing.sparse_use_flat(None) is on_tpu   # default defers to auto
    assert mixing.sparse_use_flat("flat") is True
    assert mixing.sparse_use_flat("per_segment") is False
    with pytest.raises(ValueError):
        mixing.sparse_use_flat("fused")
    prev = mixing.set_flat_lowering("per_segment")
    try:
        # an explicit process default IS honoured by the sparse resolver
        assert mixing.sparse_use_flat(None) is False
    finally:
        mixing.set_flat_lowering(prev)

    W = jnp.asarray(metropolis_weights(ring_graph(8)), jnp.float32)
    lora = _tree(jax.random.PRNGKey(3))
    for ma, mb in ((1.0, 1.0), (1.0, 0.0), (0.0, 1.0)):
        flat = mixing.mix_tree_sparse(W, lora, ma, mb, comm_plan=None,
                                      flat_lowering="flat")
        seg = mixing.mix_tree_sparse(W, lora, ma, mb, comm_plan=None,
                                     flat_lowering="per_segment")
        for x, y in zip(jax.tree.leaves(flat), jax.tree.leaves(seg)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# config / session surface
# ---------------------------------------------------------------------------

def test_mix_comm_validation_and_cache_key():
    with pytest.raises(ValueError):
        _cfg(mix_comm="pairwise")
    keys = {_cfg(mix_comm=m).cache_key() for m in
            ("dense", "sparse", "sparse_overlap")}
    assert len(keys) == 3, "mix_comm must enter the build cache key"
    assert _cfg().mix_comm == "dense"


def test_session_sparse_bitwise_equals_dense_run():
    """End-to-end degenerate check: a full single-process training run
    under mix_comm='sparse' reproduces the dense run bit-for-bit (static
    graph), and the session carries a CommPlan for the active support."""
    dense = Session(_cfg(mix_comm="dense"))
    sparse = Session(_cfg(mix_comm="sparse"))
    dense.run()
    sparse.run()
    for x, y in zip(jax.tree.leaves(dense.lora), jax.tree.leaves(sparse.lora)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert sparse.comm_plan is not None
    assert sparse.comm_plan.n_shards == 1
    assert dense.comm_plan is None


def test_session_sparse_overlap_runs_and_differs():
    """Overlap is a different algorithm: it must run cleanly to a finite
    loss on the same config but NOT match dense on a ring (the delayed
    off-diagonal terms lag one round)."""
    dense = Session(_cfg(mix_comm="dense", rounds=4))
    overlap = Session(_cfg(mix_comm="sparse_overlap", rounds=4))
    dense.run()
    res = overlap.run()
    assert np.isfinite(res.final_loss)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(dense.lora),
                               jax.tree.leaves(overlap.lora)))
