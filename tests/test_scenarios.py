"""Tier-1 unit tests for the scenario library: graph families, Metropolis
weights, schedule determinism/repair semantics, and the DFLConfig wiring.
(The quantitative theory predicates live in tests/test_conformance.py.)"""
import numpy as np
import pytest

from repro.api import DFLConfig, Session, schedule_from_config
from repro.core.topology import (complete_graph, exponential_graph, lambda2,
                                 lemma_a10_gap_bound, make_topology,
                                 metropolis_weights, ring_graph,
                                 rho_sq_from_samples, torus_dims, torus_graph,
                                 underlying_graph, watts_strogatz_graph)
from repro.scenarios import (SCENARIO_MATRIX, ClientChurn, EdgeActivation,
                             GossipSchedule, PhaseSwitch, StaticGraph,
                             StragglerDropout, TopologySchedule, get_scenario)

M = 8
ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


# ---------------------------------------------------------------------------
# graph families
# ---------------------------------------------------------------------------

def test_new_graph_families_structure():
    for fam in ("exponential", "torus", "small_world"):
        a = underlying_graph(fam, M, seed=0)
        assert a.shape == (M, M)
        assert (a == a.T).all() and (np.diag(a) == 0).all()
        assert lambda2(a) > 0, f"{fam} disconnected"


def test_spectral_ordering_of_families():
    """λ2: ring < torus < exponential < complete — the connectivity ladder
    the scenario matrix spans (m=8)."""
    l2 = {f: lambda2(underlying_graph(f, M, seed=0))
          for f in ("ring", "torus", "exponential", "complete")}
    assert l2["ring"] < l2["torus"] < l2["exponential"] < l2["complete"]


def test_torus_dims_and_custom_shape():
    assert torus_dims(8) == (2, 4)
    assert torus_dims(9) == (3, 3)
    assert torus_dims(7) == (1, 7)          # prime -> ring degeneration
    a = torus_graph(12, rows=3, cols=4)
    assert int(a.sum()) // 2 == 24          # 2*m edges on a proper torus
    with pytest.raises(ValueError):
        torus_graph(8, rows=3, cols=3)


def test_exponential_graph_degree():
    # m = 2^d: every node reaches +/-2^k -> degree 2*d - 1 dupes collapse
    a = exponential_graph(16)
    deg = a.sum(1)
    assert (deg == deg[0]).all() and deg[0] >= np.log2(16)


def test_watts_strogatz_stays_connected():
    for seed in range(6):
        a = watts_strogatz_graph(10, k=4, beta=0.5,
                                 rng=np.random.default_rng(seed))
        assert lambda2(a) > 1e-9


def test_make_topology_new_families_and_kwargs():
    t = make_topology("small_world", 10, 0.3, seed=1, ws_k=2, ws_beta=0.0)
    # beta=0: pure ring lattice with k=2 -> exactly the ring graph
    assert (t.adj == ring_graph(10)).all()
    with pytest.raises(ValueError):
        make_topology("moebius", 8, 0.5)


# ---------------------------------------------------------------------------
# metropolis weights + contraction helpers
# ---------------------------------------------------------------------------

def test_metropolis_weights_doubly_stochastic_with_isolated_nodes():
    a = np.zeros((5, 5))
    a[0, 1] = a[1, 0] = a[1, 2] = a[2, 1] = 1.0   # nodes 3, 4 isolated
    W = metropolis_weights(a)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= 0).all()
    assert W[3, 3] == 1.0 and W[4, 4] == 1.0      # identity-row repair


def test_rho_sq_from_samples_identity_and_complete():
    m = 6
    assert rho_sq_from_samples([np.eye(m)]) == pytest.approx(1.0)
    W = metropolis_weights(complete_graph(m))
    assert rho_sq_from_samples([W]) < 0.2          # near-J in one hop


def test_lemma_a10_gap_bound_capped():
    adj = complete_graph(12)                        # lambda2 = 12
    assert lemma_a10_gap_bound(adj, 1.0, c_mix=0.5) == 1.0
    assert lemma_a10_gap_bound(adj, 0.01, c_mix=0.5) == \
        pytest.approx(0.06)


# ---------------------------------------------------------------------------
# schedules: determinism, repair, phase switching
# ---------------------------------------------------------------------------

def test_edge_activation_deterministic_replay():
    a = underlying_graph("torus", M, 0)
    s1 = EdgeActivation(a, 0.4, seed=7)
    s2 = EdgeActivation(a, 0.4, seed=7)
    for t in range(10):
        np.testing.assert_array_equal(s1.next_w(t), s2.next_w(t))
    assert isinstance(s1, TopologySchedule)


def test_client_churn_identity_rows_for_offline_nodes():
    sched = ClientChurn(complete_graph(M), p=1.0, seed=3, leave=0.5,
                        rejoin=0.3, min_active=2)
    saw_offline = False
    for t in range(30):
        W = sched.next_w(t)
        assert sched.active.sum() >= 2
        for i in np.flatnonzero(~sched.active):
            saw_offline = True
            e = np.zeros(M)
            e[i] = 1.0
            np.testing.assert_array_equal(W[i], e)   # row = e_i
            np.testing.assert_array_equal(W[:, i], e)
    assert saw_offline                               # the chain actually churns


def test_straggler_dropout_doubly_stochastic():
    sched = StragglerDropout(ring_graph(M), p=0.8, seed=0, drop=0.5)
    for t in range(20):
        W = sched.next_w(t)
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W, W.T, atol=1e-12)


def test_phase_switch_changes_support():
    strong = complete_graph(M)
    weak = ring_graph(M)
    sched = PhaseSwitch(EdgeActivation(strong, 1.0, 0),
                        EdgeActivation(weak, 1.0, 1), switch_round=5)
    W_strong = sched.next_w(0)
    assert (np.abs(W_strong[~np.eye(M, dtype=bool)]) > 0).sum() > 2 * M
    W_weak = sched.next_w(5)
    off = np.abs(W_weak) > 1e-12
    np.fill_diagonal(off, False)
    assert (off <= (weak > 0)).all()                 # support within the ring
    with pytest.raises(ValueError):
        PhaseSwitch(EdgeActivation(strong, 1.0, 0),
                    EdgeActivation(ring_graph(M + 1), 1.0, 1), 5)


# ---------------------------------------------------------------------------
# config + Session wiring
# ---------------------------------------------------------------------------

def test_config_scenario_validation_and_roundtrip():
    with pytest.raises(ValueError):
        DFLConfig(scenario="chaos")
    with pytest.raises(ValueError):
        DFLConfig(scenario="gossip", scenario_kw={"leave": 0.5})
    with pytest.raises(ValueError):
        DFLConfig(topology="hyperbolic")
    c = DFLConfig(topology="small_world", scenario="churn",
                  topology_kw={"ws_k": 4}, scenario_kw={"leave": 0.2})
    back = DFLConfig.from_dict(c.to_dict())
    assert back == c and back.cache_key() == c.cache_key()
    assert c.cache_key() != DFLConfig(topology="small_world",
                                      scenario="straggler",
                                      topology_kw={"ws_k": 4}).cache_key()


def test_schedule_from_config_bad_kw_raises():
    cfg = DFLConfig(scenario="straggler", scenario_kw={"dorp": 0.2})
    with pytest.raises(ValueError, match="scenario_kw"):
        schedule_from_config(cfg)


def test_scenario_matrix_builds_valid_configs():
    for sc in SCENARIO_MATRIX:
        cfg = DFLConfig(n_clients=M, **sc.config_kw())
        sched = schedule_from_config(cfg)
        assert sched.m == M
    assert get_scenario("ring-edge").topology == "ring"
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_session_gossip_schedule_shares_topology_rng():
    """The default scenario's schedule must wrap the Session's Topology
    object (same RNG stream as pre-scenario Sessions)."""
    cfg = DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                    n_clients=4, rounds=2, local_steps=1, batch_size=4,
                    T=1, seed=0)
    s = Session(cfg)
    assert isinstance(s.topo_schedule, GossipSchedule)
    assert s.topo_schedule.topology is s.topology


def test_session_accepts_custom_topology_schedule():
    cfg = DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                    n_clients=4, rounds=2, local_steps=1, batch_size=4,
                    T=1, seed=0)
    sched = StaticGraph(ring_graph(4))
    s = Session(cfg, topology_schedule=sched)
    ev = s.step()
    np.testing.assert_array_equal(ev.W, metropolis_weights(ring_graph(4)))


def test_session_custom_schedule_with_auto_T_raises():
    """T=0 (topology-aware T*) cannot be resolved for a user-supplied
    topology_schedule — probing the live schedule would consume the run's
    W_t stream — so Session must fail loudly instead of silently picking
    T* from the config's (unrelated) default scenario."""
    cfg = DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                    n_clients=4, rounds=2, local_steps=1, batch_size=4,
                    T=0, seed=0)
    with pytest.raises(ValueError, match="topology_schedule"):
        Session(cfg, topology_schedule=StaticGraph(ring_graph(4)))


def test_session_rho_for_non_gossip_scenario():
    cfg = DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                    n_clients=6, rounds=2, local_steps=1, batch_size=4,
                    T=0, seed=0, topology="ring",
                    scenario="edge_activation", p=0.5)
    s = Session(cfg)
    assert 0.0 < s.rho < 1.0
    assert s.T >= 1                                  # T*(rho) resolved
