"""Substrate unit tests: checkpointing, data pipeline, optimizer,
sharding rules, topology-aware T* selector."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.core.topology import (make_topology, optimal_switching_interval,
                                 optimal_switching_interval_edge_activation)
from repro.data import federated_batches, label_skew_partitions, make_task
from repro.optim import AdamW


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {
        "groups": [{"attn": {"wq": jax.random.normal(key, (4, 8, 8))}},
                   {"moe": {"w_gate": jnp.ones((2, 3, 4))}}],
        "tail": [],
        "scalar": jnp.float32(3.5),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    loaded = load_pytree(path)
    np.testing.assert_allclose(loaded["groups"][0]["attn"]["wq"],
                               np.asarray(tree["groups"][0]["attn"]["wq"]))
    np.testing.assert_allclose(loaded["scalar"], 3.5)
    assert isinstance(loaded["groups"], list) and len(loaded["groups"]) == 2


def test_label_skew_matches_paper():
    b = label_skew_partitions(2, 10)
    assert b.shape == (10, 2)
    np.testing.assert_allclose(b.sum(1), 1.0)
    # 3x[0.9,0.1], 3x[0.1,0.9], 4x[0.5,0.5]
    assert (b[0] == [0.9, 0.1]).all() and (b[3] == [0.1, 0.9]).all() \
        and (b[6] == [0.5, 0.5]).all()
    m = label_skew_partitions(3, 10)
    assert m.shape == (10, 3)
    assert (m[0] == [0.9, 0.05, 0.05]).all()


def test_federated_batches_shapes():
    task = make_task("sst2")
    parts = label_skew_partitions(2, 10)
    batch = next(iter(federated_batches(task, parts, 8, 3, 1)))
    assert batch["tokens"].shape == (3, 10, 8, task.seq_len)
    assert batch["labels"].shape == (3, 10, 8)
    assert batch["tokens"].dtype == np.int32


def test_synthetic_task_learnable_signal():
    """Class-0 and class-1 sequences must differ in token statistics."""
    task = make_task("sst2")
    rng = np.random.default_rng(0)
    t0 = task.sample(np.zeros(200, int), rng)
    t1 = task.sample(np.ones(200, int), rng)
    # signal tokens of class 0 appear more in class-0 samples
    sig0 = set(task._signal[0].tolist())
    f0 = np.isin(t0, list(sig0)).mean()
    f1 = np.isin(t1, list(sig0)).mean()
    assert f0 > 3 * f1


def test_adamw_masked_update(key):
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"a": jnp.ones(4), "b": jnp.ones(4)}
    grads = {"a": jnp.ones(4), "b": jnp.ones(4)}
    state = opt.init(params)
    mask = lambda path: 0.0 if path[-1].key == "a" else 1.0
    new, state2 = opt.update(grads, state, params, update_mask=mask)
    np.testing.assert_allclose(np.asarray(new["a"]), 1.0)   # frozen
    assert float(jnp.max(jnp.abs(new["b"] - 1.0))) > 0.01   # moved
    # frozen leaf's moments untouched
    np.testing.assert_allclose(np.asarray(state2.mu["a"]), 0.0)
    assert float(jnp.max(jnp.abs(state2.mu["b"]))) > 0


def test_adamw_scale_invariance(key):
    """Per-client loss scaling by 1/m must not change AdamW directions
    (the fedtrain design assumption)."""
    opt = AdamW(lr=0.1, eps=1e-12, weight_decay=0.0)
    p = {"w": jax.random.normal(key, (8,))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    s1, s2 = opt.init(p), opt.init(p)
    n1, _ = opt.update(g, s1, p)
    n2, _ = opt.update(jax.tree.map(lambda x: x / 7.0, g), s2, p)
    np.testing.assert_allclose(np.asarray(n1["w"]), np.asarray(n2["w"]),
                               rtol=1e-4)


def test_tstar_selectors_monotone():
    rhos = [0.5, 0.9, 0.99, 0.999]
    ts = [optimal_switching_interval(r) for r in rhos]
    assert ts == sorted(ts)
    ps = [0.5, 0.1, 0.02]
    lam = 10.0
    te = [optimal_switching_interval_edge_activation(p, lam) for p in ps]
    assert te == sorted(te)


def test_param_sharding_rules():
    """Megatron rules: column weights shard d_out, row weights shard d_in,
    embed shards vocab, nothing shards rank/group dims."""
    import os as _os
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import _param_spec, DEFAULT_AXIS_MAP

    class FakeMesh:
        shape = {"data": 4, "model": 4}
        axis_names = ("data", "model")

    m = FakeMesh()
    am = DEFAULT_AXIS_MAP
    assert _param_spec("groups/0/attn/wq", (8, 64, 64), m, am) == \
        P(None, None, "model")
    assert _param_spec("groups/0/attn/wo", (8, 64, 64), m, am) == \
        P(None, "model", None)
    assert _param_spec("embed", (512, 64), m, am) == P("model", None)
    assert _param_spec("unembed", (64, 512), m, am) == P(None, "model")
    # expert-parallel rule: expert dim (divisible by model axis) shards
    # over "model"; with fsdp the w_down output dim shards over "data"
    assert _param_spec("groups/0/moe/w_down", (4, 64, 64), m, am,
                       fsdp=True) == P("model", None, "data")
    # non-divisible expert count falls back to row-parallel TP
    assert _param_spec("groups/0/moe/w_down", (3, 64, 64), m, am) == \
        P(None, "model", None)
    # non-divisible dims stay unsharded
    assert _param_spec("x/wq", (7, 9), m, am) == P(None, None)


def test_rho_estimate_bounds():
    topo = make_topology("complete", 8, p=1.0, seed=0)
    rho = topo.rho_estimate(50)
    assert 0.0 <= rho < 1.0
    sparse = make_topology("complete", 8, p=0.01, seed=0)
    assert sparse.rho_estimate(50) > rho
