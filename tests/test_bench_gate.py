"""The bench-regression gate and the benchmark registry's --only
validation: CI plumbing that must fail loudly, tested without importing
jax (the gate has to be cheap)."""
import json
import os
import subprocess
import sys

from benchmarks.check_regression import (TRACKED, _multihost, _scenarios,
                                         _serving, compare, main)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_extractors_cover_committed_artifacts():
    """Every committed BENCH_*.json baseline must yield at least one
    tracked metric — otherwise the gate silently watches nothing."""
    for name, extract in TRACKED.items():
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            metrics = extract(json.load(f))
        assert metrics, f"{name}: extractor produced no metrics"
        for key, (value, direction) in metrics.items():
            assert value > 0, (name, key)
            assert direction in ("lower", "higher")


def test_compare_directions_and_threshold():
    base = {"a_us": (100.0, "lower"), "b_tok_s": (100.0, "higher")}
    # within the band: no regressions
    ok = {"a_us": (120.0, "lower"), "b_tok_s": (80.0, "higher")}
    regs, _ = compare(base, ok, threshold=0.25)
    assert regs == []
    # a_us 30% slower and b_tok_s 30% lower both breach a 25% band
    bad = {"a_us": (130.0, "lower"), "b_tok_s": (70.0, "higher")}
    regs, _ = compare(base, bad, threshold=0.25)
    assert len(regs) == 2
    # improvements never fail, regardless of direction
    good = {"a_us": (10.0, "lower"), "b_tok_s": (500.0, "higher")}
    regs, _ = compare(base, good, threshold=0.25)
    assert regs == []
    # missing + new metrics surface as notes, not failures
    regs, notes = compare(base, {"c": (1.0, "lower")}, threshold=0.25)
    assert regs == [] and len(notes) == 3


def test_extractor_shapes():
    sc = _scenarios({"scenarios": [
        {"scenario": "ring-edge", "us_per_round": 5308.1,
         "rounds_per_s": 188.4}]})
    assert sc == {"scenario_ring-edge_us": (5308.1, "lower")}
    sv = _serving({"rows": [
        {"n_slots": 4, "mode": "multi", "n_adapters": 8, "tok_s": 621.8}]})
    assert sv == {"serving_s4_multi8_tok_s": (621.8, "higher")}
    mh = _multihost({"rows": [{"n_processes": 2, "rounds_per_s": 3.5}]})
    assert mh == {"multihost_2p_rounds_per_s": (3.5, "higher")}


def test_gate_cli_end_to_end(tmp_path):
    """Dir-vs-dir gate run: pass on equal artifacts, fail on a >25%
    slowdown, and refuse a summary with failed benchmarks."""
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    base_dir.mkdir()
    cur_dir.mkdir()
    doc = {"session_us_per_round": 6000.0}
    (base_dir / "BENCH_round_loop.json").write_text(json.dumps(doc))
    (cur_dir / "BENCH_round_loop.json").write_text(json.dumps(doc))
    assert main(["--baseline-dir", str(base_dir),
                 "--current-dir", str(cur_dir)]) == 0

    slow = {"session_us_per_round": 9000.0}     # +50%
    (cur_dir / "BENCH_round_loop.json").write_text(json.dumps(slow))
    assert main(["--baseline-dir", str(base_dir),
                 "--current-dir", str(cur_dir)]) == 1
    # a generous threshold lets the same diff through
    assert main(["--baseline-dir", str(base_dir),
                 "--current-dir", str(cur_dir), "--threshold", "0.6"]) == 0

    summary = cur_dir / "bench_summary.json"
    summary.write_text(json.dumps(
        [{"name": "kernels", "failed": True}]))
    assert main(["--baseline-dir", str(base_dir),
                 "--current-dir", str(cur_dir), "--threshold", "0.6",
                 "--summary", str(summary)]) == 1


def test_gate_artifact_scoping(tmp_path):
    """--artifacts restricts the gate to what the job regenerated: a
    regression in an out-of-scope artifact is ignored, an unknown name
    is rejected."""
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    base_dir.mkdir()
    cur_dir.mkdir()
    (base_dir / "BENCH_round_loop.json").write_text(
        json.dumps({"session_us_per_round": 6000.0}))
    (cur_dir / "BENCH_round_loop.json").write_text(
        json.dumps({"session_us_per_round": 9000.0}))      # +50% regression
    mh = {"rows": [{"n_processes": 2, "rounds_per_s": 3.5}]}
    (base_dir / "BENCH_multihost.json").write_text(json.dumps(mh))
    (cur_dir / "BENCH_multihost.json").write_text(json.dumps(mh))
    args = ["--baseline-dir", str(base_dir), "--current-dir", str(cur_dir)]
    assert main(args) == 1                                  # unscoped: fails
    assert main(args + ["--artifacts", "BENCH_multihost.json"]) == 0
    assert main(args + ["--artifacts", "BENCH_nope.json"]) == 2


def test_gate_refuses_vacuous_pass(tmp_path):
    """Zero artifacts checked (typo'd dirs, bench wrote elsewhere) must
    fail — a gate that watched nothing cannot go green."""
    empty_a, empty_b = tmp_path / "a", tmp_path / "b"
    empty_a.mkdir()
    empty_b.mkdir()
    assert main(["--baseline-dir", str(empty_a),
                 "--current-dir", str(empty_b)]) == 1


def test_run_only_rejects_unknown_names():
    """A typo'd --only must exit non-zero in milliseconds (validated
    before the benchmark imports), not silently run nothing."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "kernles"],
        capture_output=True, text=True, cwd=ROOT,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert proc.returncode == 2
    assert "unknown benchmark" in proc.stderr
    assert "kernles" in proc.stderr
