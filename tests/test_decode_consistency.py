"""Decode-by-replay must equal full-sequence forward (KV cache, rolling
windows, RoPE offsets, recurrent states, cross-attn caches)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as tf

ARCHS = ["gemma3-1b", "recurrentgemma-2b", "xlstm-1.3b", "mixtral-8x22b",
         "granite-34b", "whisper-tiny", "llama-3.2-vision-11b", "qwen2-7b"]


def _fill_cross(params, cfg, cache, frontend, B):
    from repro.models.transformer import _encoder_forward
    mem = (_encoder_forward(params, cfg, frontend, None)
           if cfg.family == "encdec" else frontend)

    def fill(attn_p):
        k = (mem @ attn_p["wk"] + attn_p.get("bk", 0)).reshape(
            B, -1, cfg.n_kv_heads, cfg.hd)
        v = (mem @ attn_p["wv"] + attn_p.get("bv", 0)).reshape(
            B, -1, cfg.n_kv_heads, cfg.hd)
        return {"ck": k, "cv": v}

    for j, spec in enumerate(cfg.pattern):
        gp = params["groups"][j]
        target = gp.get("cross") or (gp["attn"] if spec.kind == "cross"
                                     else None)
        if target is None:
            continue
        for g in range(cfg.n_groups):
            pg = jax.tree.map(lambda x: x[g], target)
            cc = fill(pg)
            cache["groups"][j]["cross"] = jax.tree.map(
                lambda buf, new, g=g: buf.at[g].set(new),
                cache["groups"][j]["cross"], cc)
    return cache


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced()
    params = tf.init_params(key, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    full, _ = tf.forward(params, cfg, tokens, frontend=frontend, remat=False)
    cache = tf.init_cache(cfg, B, S)
    if frontend is not None:
        cache = _fill_cross(params, cfg, cache, frontend, B)
    for t in range(S):
        lg, cache = tf.decode_step(params, cfg, tokens[:, t:t + 1], cache)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 5e-4, (arch, t, err)


def test_rolling_window_cache(key):
    """Sliding-window decode with cache shorter than the sequence must match
    the windowed full forward (rolling overwrite correctness)."""
    cfg = get_config("mixtral-8x22b").reduced()
    w = cfg.pattern[0].window
    assert w is not None and w <= 8
    params = tf.init_params(key, cfg)
    B, S = 1, 20  # S >> window
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = tf.forward(params, cfg, tokens, remat=False)
    cache = tf.init_cache(cfg, B, S)   # attn layers clamp to window size
    for t in range(S):
        lg, cache = tf.decode_step(params, cfg, tokens[:, t:t + 1], cache)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 5e-4, (t, err)
