"""Multi-process execution tests.

Two groups:
  * tier-1 units — in-process, single-process-degenerate behaviour of the
    multihost helpers, `BroadcastSchedule`, the `mix_gather` lowering, and
    `ClusterSession` (which must be an exact `Session` on one process).
  * `-m multihost` — tests that spawn a REAL simulated process grid via
    `repro.launch.cluster` (CPU backend, gloo collectives) and assert the
    acceptance bar: a 2-process `ClusterSession` reproduces the
    single-process `Session` bit-for-bit, and checkpoints round-trip
    across process counts with exact RNG replay. These run in the
    dedicated `multihost` CI job.
"""
import json
import os

import numpy as np
import pytest

import jax

from repro.api import ClusterSession, DFLConfig, HistoryRecorder, Session
from repro.checkpoint import load_pytree
from repro.core.topology import make_topology
from repro.dist import multihost, sharding
from repro.launch.cluster import failed_ranks, spawn_simulated
from repro.scenarios.schedule import BroadcastSchedule, GossipSchedule

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _clf_config(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=4,
                rounds=6, local_steps=2, batch_size=8, p=0.5, T=2,
                lr=1e-3, seed=0)
    base.update(kw)
    return DFLConfig(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# tier-1: helpers + degenerate single-process behaviour
# ---------------------------------------------------------------------------

def test_multihost_helpers_single_process():
    assert not multihost.is_distributed()
    assert multihost.is_primary()
    assert multihost.process_count() == 1
    mesh = multihost.cluster_mesh()
    assert mesh.axis_names == ("data",)
    slc = multihost.local_client_slice(8, mesh)
    assert (slc.start, slc.stop) == (0, 8)

    class _Grid9:                       # mesh stub: 9 devices
        size = 9
    with pytest.raises(ValueError):
        multihost.local_client_slice(4, _Grid9())
    # replicate / shard / gather round-trip exactly
    x = np.arange(24, dtype=np.float32).reshape(4, 3, 2)
    g = multihost.shard_clients(mesh, x[multihost.local_client_slice(4, mesh)],
                                x.shape, axis=0)
    np.testing.assert_array_equal(np.asarray(g), x)
    r = multihost.replicate(mesh, x)
    np.testing.assert_array_equal(np.asarray(r), x)
    back = multihost.to_host({"x": g}, mesh)
    np.testing.assert_array_equal(back["x"], x)
    multihost.sync("noop")  # single-process barrier is a no-op


def test_broadcast_schedule_passthrough_single_process():
    """On one process the wrapper must not perturb the inner schedule's
    stream — same matrices, same dtype, same RNG advancement."""
    topo_a = make_topology("complete", 4, 0.5, seed=3)
    topo_b = make_topology("complete", 4, 0.5, seed=3)
    inner, wrapped = GossipSchedule(topo_a), \
        BroadcastSchedule(GossipSchedule(topo_b))
    assert wrapped.m == 4 and wrapped.symmetric is False
    for t in range(5):
        np.testing.assert_array_equal(inner.next_w(t), wrapped.next_w(t))


def test_mix_gather_modes_and_key():
    with pytest.raises(ValueError):
        _clf_config(mix_gather="sometimes")
    on, off = _clf_config(mix_gather="on"), _clf_config(mix_gather="off")
    assert on.cache_key() != off.cache_key()
    from repro.api.session import _resolve_mix_gather
    assert _resolve_mix_gather("on") is True
    assert _resolve_mix_gather("off") is False
    # single-process "auto" resolves off
    assert _resolve_mix_gather("auto") is (jax.process_count() > 1)


def test_mix_gather_bitwise_noop_single_process():
    """mix_gather pins the communication lowering; it must not change a
    single bit of the single-process numerics."""
    a = Session(_clf_config(rounds=3, mix_gather="off"))
    b = Session(_clf_config(rounds=3, mix_gather="on"))
    a.run()
    b.run()
    _assert_trees_equal(a.lora, b.lora)


def test_cluster_session_degenerate_matches_session():
    """A 1-process ClusterSession is an exact Session: same losses, same
    final state, and no leaked mesh binding afterwards."""
    assert sharding.current_mesh() is None
    rec_c, rec_s = HistoryRecorder(), HistoryRecorder()
    cs = ClusterSession(_clf_config(), callbacks=[rec_c])
    cs.run()
    assert sharding.current_mesh() is None      # _bound() restored state
    ss = Session(_clf_config(), callbacks=[rec_s])
    ss.run()
    assert [h["loss"] for h in rec_c.history] == \
        [h["loss"] for h in rec_s.history]
    _assert_trees_equal(cs.lora, ss.lora)


def test_cluster_checkpoint_interop_single_process(tmp_path):
    """ClusterSession.save writes Session's exact checkpoint format."""
    path = os.path.join(tmp_path, "cs.npz")
    cs = ClusterSession(_clf_config())
    cs.run(3)
    cs.save(path)
    cs.run(3)
    resumed = Session(_clf_config())
    assert resumed.restore(path) == 3
    resumed.run(3)
    _assert_trees_equal(cs.lora, resumed.lora)


# ---------------------------------------------------------------------------
# -m multihost: real simulated process grids (dedicated CI job)
# ---------------------------------------------------------------------------

def _spawn_ok(n, args, timeout=600.0):
    results = spawn_simulated(n, args, timeout=timeout)
    bad = failed_ranks(results)
    assert not bad, "\n".join(report for _, report in bad)
    return results


@pytest.mark.multihost
def test_two_process_parity_bitwise(tmp_path):
    """THE acceptance bar: a 2-process simulated ClusterSession reproduces
    the single-process Session's params bit-for-bit for the same
    DFLConfig/seed — local training shard-local, W_t broadcast from rank
    0, gossip mix through the cross-process all-gather."""
    config = _clf_config()
    cfg_path = os.path.join(tmp_path, "cfg.json")
    ckpt = os.path.join(tmp_path, "cluster2.npz")
    out_json = os.path.join(tmp_path, "cluster2.json")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    _spawn_ok(2, ["--config", cfg_path, "--ckpt", ckpt,
                  "--json", out_json, "--eval", "--quiet"])

    rec = HistoryRecorder()
    single = Session(config, callbacks=[rec])
    single.run()

    tree = load_pytree(ckpt)
    _assert_trees_equal(tree["lora"], single.lora)
    _assert_trees_equal(tree["opt"]["mu"], single.opt_state.mu)
    payload = json.load(open(out_json))
    assert payload["n_processes"] == 2
    assert payload["final_loss"] == rec.history[-1]["loss"]
    # dense run: the measured collective payload is the dense all-gather,
    # with the sparse alternative reported alongside for comparison
    assert payload["mix_comm"] == "dense"
    assert payload["comm_bytes_per_round"] > 0
    assert payload["comm_bytes_per_round"] == \
        payload["dense_comm_bytes_per_round"]
    # complete graph at 4 clients / 2 shards: every row is a border row,
    # so the sparse halo carries exactly the dense byte count (strict
    # reduction on sparser graphs is pinned in tests/test_comm.py)
    assert 0 < payload["sparse_comm_bytes_per_round"] <= \
        payload["dense_comm_bytes_per_round"]
    # evaluate() works on the grid (global eval batch + sharded lora
    # slices) and scores identically to the single-process run
    assert payload["eval_acc"] == single.evaluate(n=64)["acc"]


@pytest.mark.multihost
def test_two_process_parity_adaptive_T(tmp_path):
    """Adaptive-T parity: the online controller consumes the RAW W_t at
    full precision, so the broadcast must be bit-exact (not a float32
    shadow) or the two sides can pick different T at a decision boundary.
    Guards the float64 byte-transport in `BroadcastSchedule`."""
    config = _clf_config(adaptive_T=True, rounds=6)
    cfg_path = os.path.join(tmp_path, "cfg.json")
    ckpt = os.path.join(tmp_path, "adaptive2.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    _spawn_ok(2, ["--config", cfg_path, "--ckpt", ckpt, "--quiet"])

    single = Session(config)
    single.run()
    _assert_trees_equal(load_pytree(ckpt)["lora"], single.lora)


@pytest.mark.multihost
def test_checkpoint_across_process_counts(tmp_path):
    """Save under a 2-process ClusterSession, restore single-process:
    params AND the replayed RNG streams must line up exactly — the
    restored run continues bit-for-bit into the same final state as an
    uninterrupted single-process run."""
    config = _clf_config(rounds=6)
    cfg_path = os.path.join(tmp_path, "cfg.json")
    ckpt = os.path.join(tmp_path, "half.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    # 2-process grid runs the FIRST 3 rounds and checkpoints
    _spawn_ok(2, ["--config", cfg_path, "--run-rounds", "3",
                  "--ckpt", ckpt, "--quiet"])

    # single-process restore: replays data/topology/schedule RNGs 0..2,
    # then runs rounds 3..5
    resumed = Session(config)
    assert resumed.restore(ckpt) == 3
    resumed.run(3)

    # reference: uninterrupted single-process run of all 6 rounds
    full = Session(config)
    full.run()

    assert resumed.t == full.t == 6
    _assert_trees_equal(resumed.lora, full.lora)
    _assert_trees_equal(resumed.opt_state.mu, full.opt_state.mu)
    _assert_trees_equal(resumed.opt_state.nu, full.opt_state.nu)


@pytest.mark.multihost
def test_restore_into_two_process_grid(tmp_path):
    """The reverse direction: a single-process checkpoint restores into a
    2-process grid and continues to the same final state."""
    config = _clf_config(rounds=6)
    cfg_path = os.path.join(tmp_path, "cfg.json")
    half = os.path.join(tmp_path, "half1p.npz")
    done = os.path.join(tmp_path, "done2p.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)

    first = Session(config)
    first.run(3)
    first.save(half)

    _spawn_ok(2, ["--config", cfg_path, "--restore", half,
                  "--run-rounds", "3", "--ckpt", done, "--quiet"])

    full = Session(config)
    full.run()
    _assert_trees_equal(load_pytree(done)["lora"], full.lora)


# ---------------------------------------------------------------------------
# -m multihost: topology-sparse gossip (mix_comm) on real grids
# ---------------------------------------------------------------------------

SPARSE_FAMILIES = ("ring", "torus", "exponential", "small_world",
                   "erdos_renyi", "complete")


def _sparse_cfg(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=8,
                rounds=3, local_steps=2, batch_size=8, scenario="static",
                topology="ring", p=0.5, T=2, lr=1e-3, seed=0,
                mix_comm="sparse")
    base.update(kw)
    return DFLConfig(**base)


def _spawn_ckpt(n, config, tmp_path, tag, extra=()):
    cfg_path = os.path.join(tmp_path, f"{tag}.json")
    ckpt = os.path.join(tmp_path, f"{tag}.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    _spawn_ok(n, ["--config", cfg_path, "--ckpt", ckpt, "--quiet", *extra])
    return load_pytree(ckpt)


@pytest.mark.multihost
@pytest.mark.parametrize("topology", SPARSE_FAMILIES)
def test_sparse_parity_bitwise_across_grids(topology, tmp_path):
    """mix_comm='sparse' on a static graph is the dense algorithm with a
    smaller exchange: a 2-process grid must reproduce the single-process
    run bit-for-bit for EVERY library graph family (each exercises a
    different CommPlan shape — border rows only, asymmetric exports,
    all-rows-remote on complete)."""
    config = _sparse_cfg(topology=topology)
    tree = _spawn_ckpt(2, config, tmp_path, f"sparse2_{topology}")
    single = Session(config)
    single.run()
    _assert_trees_equal(tree["lora"], single.lora)
    if topology == "ring":
        # and the sparse lowering IS dense end-to-end (same grid count)
        dense = Session(_sparse_cfg(topology=topology, mix_comm="dense"))
        dense.run()
        _assert_trees_equal(tree["lora"], dense.lora)


@pytest.mark.multihost
def test_sparse_four_process_parity_and_comm_bytes(tmp_path):
    """4 shards of a ring: parity still bitwise, and the reported
    collective payload is the SPARSE halo figure. At 8 clients / 4
    shards every ring row is a border row, so the halo carries exactly
    the dense byte count (the win at this ratio is fewer/smaller
    collectives, not bytes — strict byte reduction is asserted at 2
    shards, where interior rows exist)."""
    config = _sparse_cfg()
    out_json = os.path.join(tmp_path, "sparse4.json")
    tree = _spawn_ckpt(4, config, tmp_path, "sparse4",
                       extra=["--json", out_json])
    single = Session(config)
    single.run()
    _assert_trees_equal(tree["lora"], single.lora)
    payload = json.load(open(out_json))
    assert payload["mix_comm"] == "sparse"
    assert payload["comm_bytes_per_round"] == \
        payload["sparse_comm_bytes_per_round"] > 0
    assert payload["sparse_comm_bytes_per_round"] == \
        payload["dense_comm_bytes_per_round"]


@pytest.mark.multihost
def test_sparse_overlap_parity_across_grids(tmp_path):
    """Overlapped (one-round-delayed) gossip is a DIFFERENT algorithm
    from dense, but its semantics must not depend on the process count:
    1-, 2- and 4-process grids land on identical states."""
    config = _sparse_cfg(mix_comm="sparse_overlap", rounds=4)
    tree2 = _spawn_ckpt(2, config, tmp_path, "overlap2")
    tree4 = _spawn_ckpt(4, config, tmp_path, "overlap4")
    single = Session(config)
    single.run()
    _assert_trees_equal(tree2["lora"], single.lora)
    _assert_trees_equal(tree4["lora"], single.lora)
    _assert_trees_equal(tree2["opt"]["mu"], single.opt_state.mu)
    # and it genuinely differs from the dense algorithm on a ring
    dense = Session(_sparse_cfg(mix_comm="dense", rounds=4))
    dense.run()
    import jax as _jax
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(_jax.tree.leaves(dense.lora),
                        _jax.tree.leaves(single.lora)))


# ---------------------------------------------------------------------------
# shards data source on process grids (streaming data layer)
# ---------------------------------------------------------------------------

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "shards",
                       "mnli_tiny")


def _shards_cfg(**kw):
    base = dict(model="encoder", task="mnli", model_kw=ENC_KW, n_clients=8,
                rounds=4, local_steps=2, batch_size=4, p=0.6, T=2,
                lr=1e-3, seed=0, data_source="shards", data_path=FIXTURE,
                partitioner="domain")
    base.update(kw)
    return DFLConfig(**base)


def test_cluster_degenerate_on_shards():
    """Tier-1: a 1-process ClusterSession on the shards data source is an
    exact Session (the stream is drawn globally; _to_device slices the
    local client block, which on one process is everything)."""
    cs = ClusterSession(_shards_cfg(rounds=3))
    cs.run()
    ss = Session(_shards_cfg(rounds=3))
    ss.run()
    _assert_trees_equal(cs.lora, ss.lora)


@pytest.mark.multihost
def test_shards_batch_order_invariant_across_grids(tmp_path):
    """1-, 2- and 4-process grids see the identical global batch order:
    `FederatedStream.round_batch(t)` is a pure function of the round
    index drawn identically on every process, so sharding the client
    axis cannot perturb a single sample — final params are bitwise equal
    across process counts."""
    config = _shards_cfg()
    tree2 = _spawn_ckpt(2, config, tmp_path, "shards2")
    tree4 = _spawn_ckpt(4, config, tmp_path, "shards4")
    single = Session(config)
    single.run()
    _assert_trees_equal(tree2["lora"], single.lora)
    _assert_trees_equal(tree4["lora"], single.lora)
    _assert_trees_equal(tree2["opt"]["mu"], single.opt_state.mu)
    _assert_trees_equal(tree4["opt"]["nu"], single.opt_state.nu)


@pytest.mark.multihost
def test_shards_midepoch_ckpt_across_process_counts(tmp_path):
    """A 2-process grid checkpoints MID-EPOCH (round 3 of a 6-round client
    epoch on the fixture); a single-process restore seeks the stream to
    the saved round and continues bit-for-bit into the same final state
    as an uninterrupted run."""
    config = _shards_cfg(rounds=6)
    cfg_path = os.path.join(tmp_path, "cfg.json")
    ckpt = os.path.join(tmp_path, "shards_half.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    _spawn_ok(2, ["--config", cfg_path, "--run-rounds", "3",
                  "--ckpt", ckpt, "--quiet"])

    resumed = Session(config)
    assert resumed.restore(ckpt) == 3
    resumed.run(3)
    full = Session(config)
    full.run()
    _assert_trees_equal(resumed.lora, full.lora)
    _assert_trees_equal(resumed.opt_state.mu, full.opt_state.mu)


@pytest.mark.multihost
def test_cold_join_warm_start_parity_on_grid(tmp_path):
    """Cold-join adapter warm start on a grid: the joiner repair is a
    host-side client-axis matrix applied to the GLOBAL state (gathered,
    repaired, re-sharded), so a 2-process hierarchical cold-join run must
    land bitwise on the single-process result."""
    config = _shards_cfg(rounds=5, scenario="cold_join",
                         topology="hierarchical",
                         topology_kw=dict(hier_silos=3),
                         scenario_kw=dict(joiners=2, join_round=2))
    tree2 = _spawn_ckpt(2, config, tmp_path, "coldjoin2")
    single = Session(config)
    single.run()
    _assert_trees_equal(tree2["lora"], single.lora)
    _assert_trees_equal(tree2["opt"]["mu"], single.opt_state.mu)


# ---------------------------------------------------------------------------
# -m multihost: compressed gossip (mix_quant) on real grids
# ---------------------------------------------------------------------------

@pytest.mark.multihost
def test_quant_parity_across_grids_and_bytes(tmp_path):
    """int8 compressed gossip is grid-invariant: per-row quantization of a
    shard's block equals the global quantization of those rows, so 1-, 2-
    and 4-process grids land on identical states AND identical EF
    buffers. The reported wire payload is the compressed figure, at most
    0.3x the fp32 sparse bytes (the acceptance ratio)."""
    config = _sparse_cfg(mix_comm="sparse_overlap", mix_quant="int8",
                         rounds=4)
    out_json = os.path.join(tmp_path, "quant4.json")
    tree2 = _spawn_ckpt(2, config, tmp_path, "quant2")
    tree4 = _spawn_ckpt(4, config, tmp_path, "quant4",
                        extra=["--json", out_json])
    single = Session(config)
    single.run()
    _assert_trees_equal(tree2["lora"], single.lora)
    _assert_trees_equal(tree4["lora"], single.lora)
    assert single.ef is not None
    np.testing.assert_array_equal(np.asarray(tree2["ef"]),
                                  np.asarray(single.ef))
    np.testing.assert_array_equal(np.asarray(tree4["ef"]),
                                  np.asarray(single.ef))
    payload = json.load(open(out_json))
    assert payload["mix_quant"] == "int8"
    quant_b = payload["sparse_quant_comm_bytes_per_round"]
    assert payload["comm_bytes_per_round"] == quant_b > 0
    assert quant_b <= 0.3 * payload["sparse_comm_bytes_per_round"]


@pytest.mark.multihost
def test_quant_ckpt_restores_into_two_process_grid(tmp_path):
    """A single-process quant checkpoint restores into a 2-process grid
    and continues to the same final state as the uninterrupted run (the
    EF buffer re-globalizes onto the grid)."""
    config = _sparse_cfg(mix_comm="sparse", mix_quant="int8", rounds=4)
    half = Session(config)
    half.run(2)
    ckpt = os.path.join(tmp_path, "quant_half.npz")
    half.save(ckpt)
    full = Session(config)
    full.run()
    cfg_path = os.path.join(tmp_path, "quant_restore.json")
    out = os.path.join(tmp_path, "quant_restored.npz")
    with open(cfg_path, "w") as f:
        json.dump(config.to_dict(), f)
    _spawn_ok(2, ["--config", cfg_path, "--restore", ckpt,
                  "--run-rounds", "2", "--ckpt", out, "--quiet"])
    tree = load_pytree(out)
    _assert_trees_equal(tree["lora"], full.lora)
    np.testing.assert_array_equal(np.asarray(tree["ef"]),
                                  np.asarray(full.ef))
