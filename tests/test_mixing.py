"""Mixing-lowering equivalence: mix_tree (oracle) vs mix_tree_concat vs
the plan-cached mix_tree_planned default, across mask regimes and leaf
layouts, plus the MixPlan cache contract (built once per tree signature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing

M = 6


def _tree(key, m=M, dtype=jnp.float32):
    """Plain (m, d, r) and group-stacked (G, m, d, r) a/b leaves."""
    def n(i, shape):
        return jax.random.normal(jax.random.fold_in(key, i),
                                 shape).astype(dtype)
    return {
        "groups": [{"attn": {"wq": {"a": n(1, (3, m, 16, 4)),
                                    "b": n(2, (3, m, 4, 16))}}}],
        "tail": [{"ffn": {"a": n(3, (m, 10, 4)),
                          "b": n(4, (m, 4, 10))}},
                 {"attn": {"wv": {"a": n(5, (m, 24, 4)),
                                  "b": n(6, (m, 4, 24))}}}],
    }


def _w(key, m=M):
    W = jax.random.uniform(key, (m, m))
    W = W / W.sum(1, keepdims=True)
    W = 0.5 * (W + W.T)
    return W / W.sum(1, keepdims=True)


@pytest.mark.parametrize("mask_a,mask_b", [
    (1.0, 1.0),            # joint mixing (TAD)
    (1.0, 0.0),            # active-only / frozen-block no-mix (RoLoRA)
    (0.0, 1.0),
    (0.3, 0.7),            # fractional (damped-mixing variant)
])
def test_lowerings_agree(key, mask_a, mask_b):
    tree = _tree(key)
    W = _w(jax.random.fold_in(key, 99))
    oracle = mixing.mix_tree(W, tree, mask_a, mask_b)
    concat = mixing.mix_tree_concat(W, tree, mask_a, mask_b)
    planned = mixing.mix_tree_planned(W, tree, mask_a, mask_b)
    for lo, lc, lp in zip(jax.tree.leaves(oracle), jax.tree.leaves(concat),
                          jax.tree.leaves(planned)):
        np.testing.assert_allclose(np.asarray(lo), np.asarray(lc),
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(lo), np.asarray(lp),
                                   rtol=2e-5, atol=1e-6)


def test_planned_bitwise_at_equal_masks(key):
    """At equal masks W_eff reduces to W exactly — the planned path must
    match the per-leaf oracle bit-for-bit, not just allclose."""
    tree = _tree(key)
    W = _w(jax.random.fold_in(key, 98))
    oracle = mixing.mix_tree(W, tree, 1.0, 1.0)
    planned = mixing.mix_tree_planned(W, tree, 1.0, 1.0)
    for lo, lp in zip(jax.tree.leaves(oracle), jax.tree.leaves(planned)):
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(lp))


def test_planned_identity_W_noop(key):
    tree = _tree(key)
    out = mixing.mix_tree_planned(jnp.eye(M, dtype=jnp.float32), tree,
                                  1.0, 1.0)
    for l1, l0 in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                                   atol=1e-6)


def test_plan_built_once_per_treedef(key):
    """The MixPlan is cached on the tree's static signature: repeated
    (jitted) mixing calls on same-structured trees never re-walk the tree
    in Python."""
    tree = _tree(key)
    W = _w(jax.random.fold_in(key, 97))
    fn = jax.jit(lambda W, t, a, b: mixing.mix_tree_planned(W, t, a, b))
    before = mixing.plan_builds()
    fn(W, tree, jnp.float32(1.0), jnp.float32(1.0))
    after_first = mixing.plan_builds()
    assert after_first <= before + 1
    tree2 = _tree(jax.random.fold_in(key, 5))      # same structure, new data
    fn(W, tree2, jnp.float32(1.0), jnp.float32(0.0))
    fn(W, tree, jnp.float32(0.3), jnp.float32(0.7))
    assert mixing.plan_builds() == after_first     # no rebuilds

    # a different structure (extra leaf) builds exactly one more plan
    tree3 = {**tree, "extra": {"a": jnp.ones((M, 8, 4)),
                               "b": jnp.zeros((M, 4, 8))}}
    mixing.mix_tree_planned(W, tree3, 1.0, 1.0)
    assert mixing.plan_builds() == after_first + 1


def test_plan_layout_matches_tree(key):
    tree = _tree(key)
    plan = mixing.get_mix_plan(tree)
    leaves = jax.tree.leaves(tree)
    assert plan.m == M
    assert plan.cols == sum(x.size for x in leaves) // M
    assert plan.padded % plan.bp == 0 and plan.padded >= plan.cols
    assert plan.a_indicator.shape == (1, plan.padded)
    # offsets are contiguous and in flatten order
    off = 0
    for slot, leaf in zip(plan.slots, leaves):
        assert slot.offset == off
        assert slot.cols == leaf.size // M
        off += slot.cols
    # segment indicator marks exactly the "a" columns
    n_a_cols = sum(s.cols for s in plan.slots if s.is_a)
    assert float(plan.a_indicator.sum()) == n_a_cols


def test_unknown_leaf_name_raises(key):
    """A LoRA tree with a leaf named neither 'a' nor 'b' is malformed —
    every lowering must refuse instead of silently mixing it as a 'b'
    leaf (the historical fallback)."""
    bad = {"attn": {"a": jnp.ones((M, 8, 4)), "c": jnp.zeros((M, 4, 8))}}
    W = _w(key)
    with pytest.raises(ValueError, match="'c'"):
        mixing.mix_tree(W, bad, 1.0, 1.0)
    with pytest.raises(ValueError, match="'c'"):
        mixing.mix_tree_concat(W, bad, 1.0, 1.0)
    with pytest.raises(ValueError, match="'c'"):
        mixing.build_mix_plan(bad)


def test_plan_cache_lru_bounded(key, monkeypatch):
    """The plan cache is LRU-bounded: churning tree signatures past the
    cap evicts the oldest entries instead of growing forever, recently
    used plans survive, and clear_mix_plans() empties it."""
    monkeypatch.setattr(mixing, "_PLAN_CACHE_MAX", 4)
    mixing.clear_mix_plans()

    def tree_of(cols):
        return {"a": jnp.ones((M, cols, 4)), "b": jnp.ones((M, 4, cols))}

    first = tree_of(3)
    mixing.get_mix_plan(first)
    for c in range(4, 10):
        mixing.get_mix_plan(tree_of(c))
        mixing.get_mix_plan(first)          # keep `first` recently used
        assert len(mixing._PLAN_CACHE) <= 4
    before = mixing.plan_builds()
    mixing.get_mix_plan(first)              # still cached: no rebuild
    assert mixing.plan_builds() == before
    mixing.get_mix_plan(tree_of(4))         # evicted: rebuilds
    assert mixing.plan_builds() == before + 1
    mixing.clear_mix_plans()
    assert len(mixing._PLAN_CACHE) == 0
    mixing.get_mix_plan(first)
    assert mixing.plan_builds() == before + 2


def test_resolve_bp_shrinks_to_divisor():
    from repro.kernels.gossip_mix import _resolve_bp
    assert _resolve_bp(1024, 512) == 512
    assert _resolve_bp(256, 512) == 256       # bp capped at P
    assert _resolve_bp(768, 512) == 256       # gcd fallback, not assert
    assert _resolve_bp(700, 512) == 4
    assert _resolve_bp(7, 512) == 7
    for P, bp in ((0, 512), (512, 0), (-8, 512)):
        with pytest.raises(ValueError):
            _resolve_bp(P, bp)


def test_gossip_mix_validation_raises_not_asserts(key):
    """Shape validation survives `python -O`: ValueError, not assert, and
    a non-multiple P runs via the divisor fallback instead of tripping."""
    from repro.kernels.gossip_mix import gossip_mix
    m = 4
    W = _w(key, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, 768))
    with pytest.raises(ValueError, match="w_eff"):
        gossip_mix(W[:3, :3], x, interpret=True)
    with pytest.raises(ValueError, match="seg"):
        gossip_mix(W, x, jnp.ones((1, 99)), interpret=True)
    # P=768 at the default bp=512: shrink-to-divisor keeps it running
    from repro.kernels import ref
    y = gossip_mix(W, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.gossip_mix_ref(W, x)),
                               rtol=2e-5, atol=2e-5)


def test_gossip_mix_seg_kernel_interpret(key):
    """Segmented kernel (interpret) vs the jnp oracle, non-uniform seg."""
    from repro.kernels import ref
    from repro.kernels.gossip_mix import gossip_mix
    m, P = 8, 1024
    W = _w(key, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, P))
    seg = (jax.random.uniform(jax.random.fold_in(key, 2), (1, P)) > 0.5
           ).astype(jnp.float32) * 0.8
    y = gossip_mix(W, x, seg, interpret=True)
    yr = ref.gossip_mix_seg_ref(W, x, seg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_lora_matmul_interpret_nonsquare(key):
    """lora_matmul pallas-interpret vs ref at a non-square (M≠K≠N) shape."""
    from repro.kernels import ref
    from repro.kernels.lora_matmul import lora_matmul
    M_, K_, N_, r = 192, 320, 448, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M_, K_))
    w = jax.random.normal(ks[1], (K_, N_))
    a = jax.random.normal(ks[2], (K_, r)) * 0.1
    b = jax.random.normal(ks[3], (r, N_)) * 0.1
    y = lora_matmul(x, w, a, b, scale=1.5, bm=64, bn=64, bk=64,
                    interpret=True)
    yr = ref.lora_matmul_ref(x, w, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-3)
