"""Paged-KV serving core: page pool / block tables, the paged decode
kernel vs its oracle, paged-vs-contiguous bitwise parity, chunked
prefill, and the admission scheduler (DRR, quotas, preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.serving import AdapterPool, ServingSession
from repro.configs import get_config
from repro.core.lora import build_lora_tree
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attn_decode
from repro.launch.serving import Request, ServeEngine, TenantQuota
from repro.models import transformer as tf
from repro.serving import BlockTables, PagePool, QuotaExceeded, Scheduler

TOLS = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# paging primitives
# ---------------------------------------------------------------------------
def test_page_pool_basics():
    pool = PagePool(5)                       # page 0 reserved -> capacity 4
    assert pool.capacity == 4 and pool.n_free == 4 and pool.n_used == 0
    got = [pool.alloc() for _ in range(4)]
    assert 0 not in got and len(set(got)) == 4
    assert pool.alloc() is None              # dry, no exception
    pool.free(got[:2])
    assert pool.n_free == 2
    with pytest.raises(ValueError):
        pool.free([got[0]])                  # double free
    with pytest.raises(ValueError):
        pool.free([0])                       # the null page is never owned


def test_page_pool_free_is_atomic():
    """`free` validates the WHOLE batch before mutating: a raising call
    (bad page mid-sequence, double free, intra-batch duplicate) leaves
    the pool exactly as it was — no stranded half-freed prefix."""
    pool = PagePool(8)
    got = pool.alloc_many(5)
    pool.free(got[:2])
    snap_list, snap_set = list(pool._free), set(pool._free_set)
    for bad_batch in (
        [got[2], got[3], 0],          # valid prefix, then the null page
        [got[2], 99, got[3]],         # out-of-range mid-sequence
        [got[2], got[0], got[3]],     # double free (already in the pool)
        [got[2], got[2]],             # duplicate within the batch
    ):
        with pytest.raises(ValueError):
            pool.free(bad_batch)
        assert pool._free == snap_list, f"pool mutated by {bad_batch}"
        assert pool._free_set == snap_set
    pool.free(got[2:])                # the valid remainder still frees
    assert pool.n_free == pool.capacity
    assert pool._free_set == set(pool._free)


def test_page_pool_free_set_tracks_alloc():
    """The membership set stays consistent through alloc/alloc_many/free
    cycles (it backs the O(1) double-free check)."""
    pool = PagePool(10)
    a = pool.alloc()
    many = pool.alloc_many(3)
    assert a not in pool._free_set
    assert not (set(many) & pool._free_set)
    assert pool._free_set == set(pool._free)
    pool.free([a, *many])
    assert pool._free_set == set(pool._free)
    assert pool.n_free == pool.capacity


def test_page_pool_alloc_many_all_or_nothing():
    pool = PagePool(4)
    assert pool.alloc_many(5) is None and pool.n_free == 3
    got = pool.alloc_many(3)
    assert len(got) == 3 and pool.n_free == 0


def test_block_tables_grow_release():
    pool = PagePool(9)
    tbl = BlockTables(n_slots=2, pages_per_seq=4)
    assert tbl.grow(0, 2, pool)              # pages 0..2 of slot 0
    assert tbl.n_pages(0) == 3 and pool.n_used == 3
    assert tbl.grow(0, 1, pool)              # idempotent, allocates nothing
    assert pool.n_used == 3
    assert (tbl.table[0, :3] > 0).all() and (tbl.table[0, 3:] == 0).all()
    assert (tbl.table[1] == 0).all()         # untouched slot maps to null
    with pytest.raises(ValueError):
        tbl.grow(0, 4, pool)                 # beyond pages_per_seq
    tbl.release(0, pool)
    assert pool.n_used == 0 and (tbl.table[0] == 0).all()


def test_block_tables_grow_all_or_nothing():
    pool = PagePool(3)                       # capacity 2
    tbl = BlockTables(n_slots=1, pages_per_seq=4)
    assert not tbl.grow(0, 3, pool)          # needs 4, pool has 2
    assert pool.n_used == 0 and (tbl.table[0] == 0).all()


# ---------------------------------------------------------------------------
# the kernel vs its oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,KV,G,hd,ps,P", [(2, 1, 4, 64, 8, 4),
                                            (3, 2, 2, 128, 16, 2),
                                            (1, 4, 1, 64, 8, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attn_kernel_vs_ref(B, KV, G, hd, ps, P, dtype, key):
    """Flash-decode paged-attention kernel (interpret mode) vs the gather
    oracle, over shuffled page tables and partial last pages."""
    H = KV * G
    n_pages = 1 + B * P
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, hd), dtype)
    rng = np.random.default_rng(B * ps)
    perm = rng.permutation(np.arange(1, n_pages))      # non-trivial mapping
    table = jnp.asarray(perm.reshape(B, P), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * ps + 1, size=B), jnp.int32)

    yr = ref.paged_attn_decode_ref(q, kp, vp, table, lengths)
    qg = q.reshape(B, KV, G, hd)
    y = paged_attn_decode(qg, kp, vp, table, lengths,
                          interpret=True).reshape(B, 1, H, hd)
    tol = TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol * 4)


def test_paged_ref_matches_contiguous_attention(key):
    """The ref oracle IS the contiguous softmax-attention computation on
    the gathered pages — bitwise, not approximately."""
    B, KV, G, hd, ps, P = 2, 2, 3, 32, 4, 3
    H, L = KV * G, ps * P
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, L, KV, hd))
    vc = jax.random.normal(ks[2], (B, L, KV, hd))
    # lay the contiguous cache into pages via an arbitrary table
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, 1 + B * P))
    table = perm.reshape(B, P)
    kp = jnp.zeros((1 + B * P, ps, KV, hd))
    vp = jnp.zeros((1 + B * P, ps, KV, hd))
    for b in range(B):
        for p in range(P):
            kp = kp.at[table[b, p]].set(kc[b, p * ps:(p + 1) * ps])
            vp = vp.at[table[b, p]].set(vc[b, p * ps:(p + 1) * ps])
    lengths = jnp.asarray([L, L - ps + 1], jnp.int32)

    y = ref.paged_attn_decode_ref(q, kp, vp, jnp.asarray(table, jnp.int32),
                                  lengths)
    # contiguous reference: same einsums on the flat cache
    import math
    mask = (jnp.arange(L)[None, :] < lengths[:, None])[:, None, None, None, :]
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bskgh,blkh->bkgsl", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(mask, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    yc = jnp.einsum("bkgsl,blkh->bskgh", pr, vc.astype(jnp.float32))
    yc = yc.reshape(B, 1, H, hd).astype(q.dtype)
    assert (np.asarray(y) == np.asarray(yc)).all()


# ---------------------------------------------------------------------------
# engine parity: paged == contiguous, chunked == teacher-forced
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    cfg = get_config("gemma3-1b").reduced()
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


def _drain_tokens(eng, prompts, max_new, adapters=None):
    rids = [eng.submit(p, max_new=max_new,
                       adapter=adapters[i % len(adapters)] if adapters
                       else None)
            for i, p in enumerate(prompts)]
    eng.run(max_ticks=5000)
    return [eng.requests[r].tokens_out for r in rids]


def test_paged_decode_step_bitwise_vs_contiguous(served):
    """Teacher-force the same tokens through a contiguous cache and a
    paged cache (shuffle-free table) — logits must be BITWISE equal at
    every step: the oracle reproduces `_attend`'s exact reduction."""
    cfg, params = served
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
    B, L, ps = 2, 32, 8
    cache_c = tf.init_cache(cfg, B, L)
    cache_p = tf.init_cache(cfg, B, L, paging=(1 + B * L // ps, ps))
    # populate the tables: slot b gets pages in allocation order
    pool = PagePool(1 + B * L // ps)
    tables = BlockTables(B, L // ps)
    for b in range(B):
        assert tables.grow(b, L // ps - 1, pool)
    cache_p["pages"]["table"] = jnp.asarray(tables.table)
    for t in toks:
        x = jnp.asarray([[t]] * B, jnp.int32)
        lc, cache_c = tf.decode_step(params, cfg, x, cache_c)
        lp, cache_p = tf.decode_step(params, cfg, x, cache_p)
        assert (np.asarray(lc) == np.asarray(lp)).all()


def test_engine_paged_matches_contiguous(served):
    """Full engine: same requests, paged vs contiguous KV, slot turnover
    included — identical generated tokens, one compile each."""
    cfg, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 3, 7, 2, 9)]
    eng_c = ServeEngine(params, cfg, n_slots=2, max_len=64)
    toks_c = _drain_tokens(eng_c, prompts, 6)
    eng_p = ServeEngine(params, cfg, n_slots=2, max_len=64, paged=True,
                        page_size=8)
    toks_p = _drain_tokens(eng_p, prompts, 6)
    assert toks_c == toks_p
    assert eng_c.compile_count == 1 and eng_p.compile_count == 1


@pytest.mark.parametrize("chunk", [4, 32])
def test_chunked_prefill_matches_teacher_forced(served, chunk):
    """Chunked prefill (both paged and rolling layer paths) produces the
    same generated tokens as teacher-forced prefill, including prompts
    shorter than one chunk and the length-1 prompt that skips chunking."""
    cfg, params = served
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (1, 3, 9, 13)]
    eng_tf = ServeEngine(params, cfg, n_slots=2, max_len=64)
    want = _drain_tokens(eng_tf, prompts, 5)

    eng_ck = ServeEngine(params, cfg, n_slots=2, max_len=64,
                         prefill_chunk=chunk)
    assert _drain_tokens(eng_ck, prompts, 5) == want
    eng_pg = ServeEngine(params, cfg, n_slots=2, max_len=64, paged=True,
                         page_size=8, prefill_chunk=chunk)
    assert _drain_tokens(eng_pg, prompts, 5) == want
    # one chunk trace + one decode trace, regardless of prompt lengths
    assert eng_ck.prefill.compile_count == 1
    assert eng_ck.compile_count == 1


def test_preemption_by_eviction_completes_exactly(served):
    """A pool too small for two full streams forces eviction; preempted
    requests recompute on re-admission and still produce the exact
    tokens of an uncontended run."""
    cfg, params = served
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
               for _ in range(3)]
    eng_c = ServeEngine(params, cfg, n_slots=2, max_len=32)
    want = _drain_tokens(eng_c, prompts, 10)

    # each request needs 5 pages of 4; capacity 6 < 2*5 -> must preempt
    eng_e = ServeEngine(params, cfg, n_slots=2, max_len=32, paged=True,
                        page_size=4, n_pages=7)
    got = _drain_tokens(eng_e, prompts, 10)
    m = eng_e.metrics()
    assert got == want
    assert m["preemptions"] > 0
    assert eng_e.compile_count == 1
    assert eng_e.page_pool.n_used == 0          # all pages returned


def test_submit_rejects_request_that_can_never_fit(served):
    cfg, params = served
    eng = ServeEngine(params, cfg, n_slots=2, max_len=32, paged=True,
                      page_size=4, n_pages=4)    # capacity 3 pages
    with pytest.raises(ValueError):
        eng.submit(np.arange(10, dtype=np.int32), max_new=10)  # needs 5


# ---------------------------------------------------------------------------
# scheduler: DRR fairness, quotas, lifecycle metrics
# ---------------------------------------------------------------------------
def test_drr_single_queue_is_fifo():
    s = Scheduler()
    for i in range(4):
        s.submit(Request(rid=i, prompt=np.zeros(1, np.int32)), tick=i)
    order = [s.next_request({}).rid for _ in range(4)]
    assert order == [0, 1, 2, 3]


def test_drr_alternates_between_adapter_queues():
    """One flooding tenant cannot starve another: admission alternates
    between non-empty queues regardless of queue depth."""
    s = Scheduler()
    for i in range(6):
        s.submit(Request(rid=i, prompt=np.zeros(1, np.int32),
                         adapter="big"), tick=0)
    s.submit(Request(rid=100, prompt=np.zeros(1, np.int32),
                     adapter="small"), tick=0)
    s.submit(Request(rid=101, prompt=np.zeros(1, np.int32),
                     adapter="small"), tick=0)
    picked = [s.next_request({}).adapter for _ in range(4)]
    assert picked == ["big", "small", "big", "small"]


def test_quota_max_queued_rejects_submit():
    s = Scheduler(quotas={"a": TenantQuota(max_queued=2)})
    s.submit(Request(rid=0, prompt=np.zeros(1, np.int32), adapter="a"))
    s.submit(Request(rid=1, prompt=np.zeros(1, np.int32), adapter="a"))
    with pytest.raises(QuotaExceeded):
        s.submit(Request(rid=2, prompt=np.zeros(1, np.int32), adapter="a"))
    assert 2 not in s.requests                  # rejected = never registered
    # other tenants are unaffected
    s.submit(Request(rid=3, prompt=np.zeros(1, np.int32), adapter="b"))


def test_quota_max_active_holds_queue_back():
    s = Scheduler(quotas={"a": TenantQuota(max_active=1)})
    s.submit(Request(rid=0, prompt=np.zeros(1, np.int32), adapter="a"))
    s.submit(Request(rid=1, prompt=np.zeros(1, np.int32), adapter="b"))
    # tenant "a" already holds 1 slot -> its queue is skipped
    assert s.next_request({"a": 1}).adapter == "b"
    assert s.next_request({"a": 1}) is None
    assert s.next_request({"a": 0}).adapter == "a"


def test_engine_enforces_max_active_quota(served):
    cfg, params = served
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=3).astype(np.int32)
               for _ in range(3)]
    stacked = build_lora_tree(jax.random.key(3), params, cfg, n_clients=2)
    pool = AdapterPool.from_stacked(stacked, consensus=False)
    eng = ServeEngine(params, cfg, n_slots=4, max_len=32, adapters=pool,
                      quotas={"client_0": TenantQuota(max_active=1)})
    for p in prompts:
        eng.submit(p, max_new=4, adapter="client_0")
    eng.tick()
    active = [s.req for s in eng.slots if s.req is not None]
    assert len(active) == 1                     # held to 1 despite 4 slots
    eng.run()                                   # but all drain eventually
    assert all(r.done for r in eng.requests.values())


def test_lifecycle_metrics(served):
    cfg, params = served
    eng = ServeEngine(params, cfg, n_slots=1, max_len=32)
    r0 = eng.submit(np.asarray([1, 2, 3], np.int32), max_new=3)
    r1 = eng.submit(np.asarray([4, 5], np.int32), max_new=3)
    eng.run()
    q0, q1 = eng.requests[r0], eng.requests[r1]
    assert q0.queue_wait_ticks == 0
    assert q1.queue_wait_ticks > 0              # waited for the single slot
    # teacher-forced prefill: prompt[0] feeds on the admit tick, so the
    # first generated token lands len(prompt)-1 ticks after submit
    assert q0.ttft_ticks == len(q0.prompt) - 1
    m = eng.metrics()
    assert m["completed"] == 2 and m["queued"] == 0
    assert m["ttft_ticks"]["n"] == 2
    assert m["latency_s"]["p50"] > 0


# ---------------------------------------------------------------------------
# idle-awareness + the one-compile invariant under occupancy churn
# ---------------------------------------------------------------------------
def test_idle_engine_skips_device(served):
    cfg, params = served
    eng = ServeEngine(params, cfg, n_slots=2, max_len=32)
    assert eng.tick() == 0
    eng.run()                                   # returns immediately
    assert eng.device_steps == 0 and eng.compile_count == 0
    eng.submit(np.asarray([1, 2], np.int32), max_new=2)
    eng.run()
    steps = eng.device_steps
    assert steps > 0
    eng.run()                                   # drained -> idle again
    assert eng.device_steps == steps


def test_one_compile_across_adapters_and_occupancy(served):
    """The acceptance invariant: {1,4,8} adapters x varying active-page
    occupancy (staggered lengths, turnover, idle gaps) through ONE traced
    decode step, paged + chunked."""
    cfg, params = served
    stacked = build_lora_tree(jax.random.key(3), params, cfg, n_clients=8)
    c = [0]

    def fill(x):
        c[0] += 1
        return 0.1 * jax.random.normal(jax.random.key(50 + c[0]), x.shape)
    pool = AdapterPool.from_stacked(jax.tree.map(fill, stacked),
                                    consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=4, max_len=64, paged=True, page_size=8,
                             prefill_chunk=8)
    rng = np.random.default_rng(7)
    names = [f"client_{i}" for i in range(8)]
    for n_adapters in (1, 4, 8):
        for j in range(n_adapters + 2):         # staggered lengths/occupancy
            p = rng.integers(0, cfg.vocab_size,
                             size=2 + 5 * (j % 3)).astype(np.int32)
            serving.submit(p, adapter=names[j % n_adapters],
                           max_new=2 + 3 * (j % 2))
        serving.run()
    assert serving.compile_count == 1
    assert serving.engine.prefill.compile_count == 1
    assert serving.metrics()["completed"] == sum(n + 2 for n in (1, 4, 8))
