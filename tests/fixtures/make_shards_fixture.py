"""Regenerate the committed tiny shard set under tests/fixtures/shards/.

    PYTHONPATH=src python tests/fixtures/make_shards_fixture.py

The fixture is the tier-1 smoke data for the streaming data layer: an
MNLI-style 10-domain shard set small enough to commit (a few KB of npz),
vocab 256 so it fits the test encoder's embedding table, with a shard
size chosen so the train split spans MULTIPLE shards — the reader's
cross-shard gather is exercised by every test that touches it. Tests pin
the manifest signature; regenerating with unchanged parameters is
byte-stable (all randomness is seeded).
"""
import os

from repro.data import write_paper_task_shards

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "shards", "mnli_tiny")

SPEC = dict(n_clients=10, n_per_client=48, n_val=96, shard_size=64,
            seed=0, vocab_size=256, feature_shift=2)


def main() -> None:
    ss = write_paper_task_shards(OUT, "mnli", **SPEC)
    print(f"wrote {OUT}: train={ss.split_size('train')} "
          f"val={ss.split_size('val')} sig={ss.signature()}")


if __name__ == "__main__":
    main()
