"""Roofline machinery unit tests: jaxpr walker scan-awareness, HLO
collective parser, report assembly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (collective_bytes_from_hlo, jaxpr_cost,
                                     model_flops, roofline_report)


def test_jaxpr_cost_counts_scan_trips():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    jxp = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((128, 128), jnp.float32))
    cost = jaxpr_cost(jxp)
    expected = 10 * 2 * 128 ** 3
    assert abs(cost["flops"] - expected) / expected < 0.05


def test_jaxpr_cost_counts_grad_flops():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    g = jax.grad(loss)
    jxp = jax.make_jaxpr(g)(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                            jax.ShapeDtypeStruct((32, 64), jnp.float32))
    fwd = jax.make_jaxpr(loss)(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                               jax.ShapeDtypeStruct((32, 64), jnp.float32))
    assert jaxpr_cost(jxp)["flops"] > 1.8 * jaxpr_cost(fwd)["flops"]


def test_collective_parser_trip_counts():
    hlo = """
HloModule test

%body_comp (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = tuple(...)
}

%cond_comp (p: (s32[], f32[8,8])) -> pred[] {
  ROOT %lt = pred[] compare(...)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar = f32[4,4]{1,0} all-reduce(%a), to_apply=%sum
  %w = (s32[], f32[8,8]) while(%init), condition=%cond_comp, body=%body_comp, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    res = collective_bytes_from_hlo(hlo)
    # all-reduce once: 4*4*4 = 64; all-gather 7x: 7*8*8*4 = 1792
    assert res["by_type"]["all-reduce"] == 64.0
    assert res["by_type"]["all-gather"] == 7 * 256.0
    assert res["ops"] == 8


def test_roofline_report_bottleneck():
    rep = roofline_report(flops=1e15, hbm_bytes=1e12,
                          coll_bytes_per_device=1e3, n_chips=256,
                          model_fl=5e14)
    assert rep["bottleneck"] == "compute"
    assert 0 < rep["useful_compute_ratio"] <= 1.0
    rep2 = roofline_report(flops=1e12, hbm_bytes=1e12,
                           coll_bytes_per_device=1e12, n_chips=256,
                           model_fl=1e12)
    assert rep2["bottleneck"] == "collective"


def test_model_flops_moe_uses_active():
    from repro.configs import get_config
    dense = get_config("granite-34b")
    moe = get_config("mixtral-8x22b")
    assert model_flops(moe, 100, training=True) < \
        6 * moe.param_count() * 100
    assert model_flops(dense, 100, training=True) == \
        6 * dense.param_count() * 100
