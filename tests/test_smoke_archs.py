"""Per-architecture smoke tests (task-mandated): instantiate the REDUCED
variant of each assigned family (<=2 pattern repeats, d_model<=512,
<=4 experts), run one forward and one train step on CPU, assert output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import build_lora_tree, make_dfl_round, round_masks
from repro.models import transformer as tf
from repro.optim import AdamW

B, S = 2, 16


def _inputs(cfg, key, batch=B, seq=S):
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return tokens, frontend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward(arch, key):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.n_experts <= 4
    assert cfg.n_layers <= 2 * max(len(cfg.pattern), 1)
    params = tf.init_params(key, cfg)
    tokens, frontend = _inputs(cfg, key)
    logits, aux = tf.forward(params, cfg, tokens, frontend=frontend,
                             remat=False)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch, key):
    """One DFL round (the paper's technique) on the reduced config."""
    cfg = get_config(arch).reduced()
    m, local_steps, b = 4, 2, 2
    params = tf.init_params(key, cfg)
    lora = build_lora_tree(jax.random.key(7), params, cfg, n_clients=m)
    assert jax.tree.leaves(lora), f"no LoRA targets found for {arch}"
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(lora)

    def loss_fn(bp, lo, micro):
        return tf.lm_loss(bp, cfg, micro["tokens"], micro["targets"],
                          frontend=micro.get("frontend"), lora=lo)[0]

    round_fn = make_dfl_round(loss_fn, opt, local_steps=local_steps)
    tokens = jax.random.randint(key, (local_steps, m, b, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=-1)}
    if cfg.n_frontend_tokens:
        batch["frontend"] = jax.random.normal(
            key, (local_steps, m, b, cfg.n_frontend_tokens,
                  cfg.d_model)) * 0.02
    W = jnp.eye(m) * 0.5 + 0.5 / m   # valid doubly-stochastic mix
    masks = round_masks("tad", 0, 2).as_array()
    lora2, opt2, metrics = jax.jit(round_fn)(params, lora, opt_state,
                                             batch, W, masks)
    assert jnp.isfinite(metrics["loss"])
    # the active block must have moved on at least one leaf
    diffs = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         lora, lora2)
    assert max(jax.tree.leaves(diffs)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch, key):
    cfg = get_config(arch).reduced()
    params = tf.init_params(key, cfg)
    cache = tf.init_cache(cfg, B, 32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = tf.decode_step(params, cfg, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()
    # cache advanced
    flat1 = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat2 = {tuple(str(k) for k in p): v
             for p, v in jax.tree_util.tree_flatten_with_path(cache2)[0]}
    for p, v in flat1:
        kp = tuple(str(k) for k in p)
        if kp[-1].endswith("'t'") or "t" == getattr(p[-1], "key", ""):
            assert (flat2[kp] == v + 1).all()
