"""`repro.control` contract tests: ControlConfig validation and the
flat-knob deprecation path (identical cache keys, bit-for-bit Session
parity), the unified RhoEstimator routes against their legacy float
sequences, FMMC weight structure and its gap-vs-Metropolis guarantee,
Metropolis edge-case regressions, the scenario-schedule `set_weights`
hook, the shared RoundStats observation surface, the one-compile
invariant across control policies, and checkpoint replay under an
active control plane."""
import os
import warnings

import numpy as np
import pytest

import jax

from repro.api import ControlConfig, ControlPlane, DFLConfig, RoundStats, Session
from repro.api.callbacks import Callback
from repro.api.schedule import AdaptiveSchedule
from repro.control import (FMMCWeightPolicy, FrozenContractionRho, GramRho,
                           SpectralRho, make_estimator, metropolis_policy,
                           weight_conformance)
from repro.core.adaptive import AdaptiveTController
from repro.core.topology import (GRAPH_FAMILIES, fastest_mixing_weights,
                                 lambda2, metropolis_weights,
                                 rho_sq_from_samples, underlying_graph)
from repro.scenarios.schedule import (BroadcastSchedule, EdgeActivation,
                                      GossipSchedule, PhaseSwitch,
                                      StaticGraph)

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _clf_config(**kw):
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=4,
                rounds=4, local_steps=2, batch_size=8, p=0.5, T=2,
                lr=1e-3, seed=0, scenario="edge_activation")
    base.update(kw)
    return DFLConfig(**base)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


# ---------------------------------------------------------------------------
# ControlConfig validation + coercion
# ---------------------------------------------------------------------------

def test_control_config_validation():
    with pytest.raises(ValueError):
        ControlConfig(t_policy="magic")
    with pytest.raises(ValueError):
        ControlConfig(rho_estimator="oracle")
    with pytest.raises(ValueError):
        ControlConfig(weight_policy="uniform")
    with pytest.raises(ValueError):
        ControlConfig(c=0.0)
    with pytest.raises(ValueError):
        ControlConfig(t_min=5, t_max=3)
    with pytest.raises(ValueError):
        ControlConfig(ewma=1.5)
    with pytest.raises(ValueError):
        ControlConfig(gram_window=0)
    # coercion: None -> inert default; Mapping -> fields; passthrough
    assert not ControlConfig.coerce(None).active
    cc = ControlConfig.coerce({"t_policy": "adaptive", "c": 0.5})
    assert cc.t_policy == "adaptive" and cc.c == 0.5
    assert ControlConfig.coerce(cc) is cc
    assert ControlConfig(weight_policy="fmmc").active


def test_control_config_method_and_scenario_validation():
    with pytest.raises(ValueError):   # adaptive T needs an alternating method
        _clf_config(method="ffa", control={"t_policy": "adaptive"})
    with pytest.raises(ValueError):   # gossip draws its own W: no policy hook
        _clf_config(scenario="gossip", control={"weight_policy": "fmmc"})


# ---------------------------------------------------------------------------
# flat adaptive_* knobs: deprecation mapping, identical cache keys
# ---------------------------------------------------------------------------

def test_flat_adaptive_knobs_deprecated_and_equivalent():
    with pytest.warns(DeprecationWarning):
        old = _clf_config(adaptive_T=True, adaptive_c=0.5, adaptive_t_max=8)
    new = _clf_config(control=ControlConfig(t_policy="adaptive", c=0.5,
                                            t_max=8))
    assert old.control == new.control
    assert old.cache_key() == new.cache_key()
    # json round-trip of the deprecated spelling stays silent and equal
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        back = DFLConfig.from_dict(old.to_dict())
    assert back == old and back.cache_key() == old.cache_key()


def test_default_config_emits_no_deprecation_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = _clf_config()
        cfg.replace(lr=2e-3)
    assert not cfg.control.active


def test_conflicting_flat_and_structured_raise():
    with pytest.raises(ValueError):
        _clf_config(adaptive_T=True,
                    control=ControlConfig(t_policy="fixed"))


# ---------------------------------------------------------------------------
# Metropolis edge-case regressions
# ---------------------------------------------------------------------------

def test_metropolis_all_zero_adjacency_is_identity():
    W = metropolis_weights(np.zeros((4, 4)))
    np.testing.assert_allclose(W, np.eye(4))


def test_metropolis_single_edge_graph():
    adj = np.zeros((3, 3))
    adj[0, 1] = adj[1, 0] = 1.0
    W = metropolis_weights(adj)
    assert W[0, 1] == pytest.approx(0.5)
    assert W[2, 2] == pytest.approx(1.0)   # isolated node keeps its state
    np.testing.assert_allclose(W.sum(1), 1.0)


def test_metropolis_rejects_malformed_adjacency():
    with pytest.raises(ValueError):
        metropolis_weights(np.zeros((3, 4)))            # non-square
    with pytest.raises(ValueError):
        metropolis_weights(np.triu(np.ones((3, 3)), 1))  # asymmetric support
    bad = np.zeros((3, 3))
    bad[0, 1] = bad[1, 0] = np.nan
    with pytest.raises(ValueError):
        metropolis_weights(bad)                          # non-finite


# ---------------------------------------------------------------------------
# fastest_mixing_weights (FMMC)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", GRAPH_FAMILIES)
def test_fmmc_structure_and_gap_vs_metropolis(family):
    m = 8
    adj = underlying_graph(family, m, seed=0)
    W = fastest_mixing_weights(adj)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
    assert (W >= -1e-12).all()
    # weight only where the graph has edges (plus the diagonal)
    off = W - np.diag(np.diag(W))
    assert (np.abs(off[adj <= 0]) < 1e-12).all()
    J = np.ones((m, m)) / m
    gap_f = 1.0 - float(np.linalg.norm(W - J, 2))
    gap_m = 1.0 - float(np.linalg.norm(metropolis_weights(adj) - J, 2))
    # init at Metropolis + best-iterate tracking makes this structural
    assert gap_f >= gap_m - 1e-9, (family, gap_f, gap_m)


def test_fmmc_edge_cases():
    np.testing.assert_allclose(fastest_mixing_weights(np.zeros((3, 3))),
                               np.eye(3))
    adj = np.zeros((2, 2))
    adj[0, 1] = adj[1, 0] = 1.0
    W = fastest_mixing_weights(adj)
    assert W[0, 1] == pytest.approx(0.5, abs=1e-6)


def test_fmmc_link_cost_penalizes_expensive_edges():
    adj = underlying_graph("complete", 6, seed=0)
    cost = np.ones((6, 6))
    cost[0, 1] = cost[1, 0] = 50.0   # one link is 50x the others
    W0 = fastest_mixing_weights(adj, cost, cost_weight=0.0)
    W1 = fastest_mixing_weights(adj, cost, cost_weight=0.5)
    assert W1[0, 1] < W0[0, 1]       # weight moves off the expensive link
    np.testing.assert_allclose(W1.sum(1), 1.0, atol=1e-9)


# ---------------------------------------------------------------------------
# scenario-schedule set_weights hook
# ---------------------------------------------------------------------------

def test_set_weights_hook_static_and_edge_activation():
    adj = underlying_graph("ring", 6, seed=0)
    sg = StaticGraph(adj)
    sg.set_weights(FMMCWeightPolicy())
    np.testing.assert_allclose(sg.next_w(0), fastest_mixing_weights(adj),
                               atol=1e-12)
    ea = EdgeActivation(adj, p=1.0, seed=0)   # p=1: full graph every round
    ea.set_weights(FMMCWeightPolicy())
    np.testing.assert_allclose(ea.next_w(0), fastest_mixing_weights(adj),
                               atol=1e-12)
    # metropolis_policy restores the default weights exactly
    ea.set_weights(metropolis_policy)
    np.testing.assert_allclose(ea.next_w(1), metropolis_weights(adj),
                               atol=1e-12)


def test_set_weights_hook_partial_activation_renormalizes():
    # FMMC weights are computed on the FULL graph; a fired subgraph must
    # still yield a doubly-stochastic nonnegative W (diagonal absorbs the
    # unfired edges' weight)
    adj = underlying_graph("erdos_renyi", 8, seed=0, er_q=0.6)
    ea = EdgeActivation(adj, p=0.4, seed=3)
    ea.set_weights(FMMCWeightPolicy())
    for t in range(30):
        W = ea.next_w(t)
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
        np.testing.assert_allclose(W, W.T, atol=1e-12)
        assert (W >= -1e-12).all()


def test_set_weights_hook_phase_switch_and_broadcast_proxy():
    cfg = _clf_config(scenario="phase_switch", topology="complete",
                      scenario_kw={"switch_round": 2, "weak_graph": "ring",
                                   "weak_p": 1.0}, p=1.0)
    from repro.scenarios import schedule_from_config
    ps = schedule_from_config(cfg)
    assert isinstance(ps, PhaseSwitch)
    ps.set_weights(FMMCWeightPolicy())
    W_strong = ps.next_w(0)
    W_weak = ps.next_w(5)
    np.testing.assert_allclose(
        W_strong, fastest_mixing_weights(
            underlying_graph("complete", 4, seed=0)), atol=1e-12)
    np.testing.assert_allclose(
        W_weak, fastest_mixing_weights(underlying_graph("ring", 4, seed=0)),
        atol=1e-12)
    # BroadcastSchedule proxies the hook to its inner schedule
    adj = underlying_graph("ring", 4, seed=0)
    bs = BroadcastSchedule(EdgeActivation(adj, p=1.0, seed=0))
    bs.set_weights(FMMCWeightPolicy())
    np.testing.assert_allclose(bs.inner.next_w(0),
                               fastest_mixing_weights(adj), atol=1e-12)
    # gossip draws its own W by construction: no hook
    from repro.core.topology import make_topology
    gossip = GossipSchedule(make_topology("ring", 4, p=0.5, seed=0))
    assert not hasattr(gossip, "set_weights")


# ---------------------------------------------------------------------------
# RhoEstimator routes vs their legacy float sequences
# ---------------------------------------------------------------------------

def test_spectral_estimator_matches_legacy_controller_floats():
    ea = EdgeActivation(underlying_graph("ring", 6, seed=0), p=0.5, seed=1)
    legacy = AdaptiveTController(ewma=0.2)
    est = SpectralRho(ewma=0.2, rho_sq0=legacy.rho_sq)
    for t in range(25):
        W = ea.next_w(t)
        legacy.observe_mixing_matrix(W)
        est.update(RoundStats(t, W))
        assert est.rho_sq == legacy.rho_sq   # bit-for-bit, every round


def test_gram_estimator_matches_rho_sq_from_samples():
    ea = EdgeActivation(underlying_graph("torus", 8, seed=0), p=0.5, seed=2)
    est = GramRho(window=16)
    Ws = []
    for t in range(20):
        W = ea.next_w(t)
        Ws.append(W)
        est.update(RoundStats(t, W))
    assert est.rho_sq == pytest.approx(rho_sq_from_samples(Ws[-16:]),
                                       abs=1e-12)


def test_frozen_estimator_resets_on_w_only_stats():
    est = FrozenContractionRho(ewma=1.0)
    W = np.eye(4)

    class FakeStats(RoundStats):
        def __init__(self, t, d):
            super().__init__(t, W, phase=0)
            self._d = d

        def frozen_delta_sq(self):
            return self._d

    est.update(FakeStats(0, 1.0))
    est.update(FakeStats(1, 0.25))        # ratio 0.25 -> rho_sq 0.25
    assert est.rho_sq == pytest.approx(0.25)
    est.update(RoundStats(2, W))          # W-only: no state -> probe resets
    est.update(FakeStats(3, 0.04))        # first sample after reset: no pair
    assert est.rho_sq == pytest.approx(0.25)


def test_make_estimator_rejects_unknown():
    with pytest.raises(ValueError):
        make_estimator("oracle")
    assert isinstance(make_estimator("frozen"), FrozenContractionRho)


def test_adaptive_schedule_estimator_none_pins_controller():
    ctrl = AdaptiveTController()
    sched = AdaptiveSchedule("tad", estimator="none", controller=ctrl)
    before = ctrl.rho_sq
    sched.next_masks(0, {"W": np.eye(4)})
    assert ctrl.rho_sq == before
    with pytest.raises(ValueError):
        AdaptiveSchedule("tad", estimator="magic")


# ---------------------------------------------------------------------------
# Session integration: parity, one compile, stats surface, checkpointing
# ---------------------------------------------------------------------------

def test_session_parity_flat_vs_structured_bitwise():
    """The deprecated flat spelling must drive the exact same run as its
    ControlConfig equivalent: bitwise-equal client state after training."""
    with pytest.warns(DeprecationWarning):
        old_cfg = _clf_config(adaptive_T=True, adaptive_c=0.5)
    new_cfg = _clf_config(control={"t_policy": "adaptive", "c": 0.5})
    s_old, s_new = Session(old_cfg), Session(new_cfg)
    s_old.run(), s_new.run()
    for a, b in zip(_leaves(s_old.lora), _leaves(s_new.lora)):
        np.testing.assert_array_equal(a, b)


def test_inert_control_keeps_baseline_bitwise():
    """weight_policy='metropolis' + t_policy='fixed' must not perturb the
    no-control baseline: same schedule objects, same trained state."""
    s0 = Session(_clf_config())
    s1 = Session(_clf_config(control=ControlConfig()))
    assert s1.control is None           # inert config -> no plane at all
    s0.run(), s1.run()
    for a, b in zip(_leaves(s0.lora), _leaves(s1.lora)):
        np.testing.assert_array_equal(a, b)


def test_closed_loop_session_single_compile():
    """Every control policy at fixed shapes reuses ONE compiled round —
    retuned T and swapped W policies are data, not code."""
    base = dict(model="encoder", task="sst2", model_kw=ENC_KW, n_clients=4,
                rounds=3, local_steps=1, batch_size=4, T=2, seed=0, p=0.5,
                scenario="edge_activation",
                lr=1.413e-3)   # unique lr -> private build-cache entry
    variants = (None,
                {"weight_policy": "fmmc"},
                {"t_policy": "adaptive", "rho_estimator": "gram"},
                {"t_policy": "adaptive", "weight_policy": "fmmc",
                 "rho_estimator": "spectral"})
    round_fns = set()
    for control in variants:
        session = Session(DFLConfig(**base, control=control))
        session.run()
        assert np.isfinite(session.last_stats.loss)
        round_fns.add(session.round_fn)
    assert len(round_fns) == 1, "control policies built distinct rounds"
    (round_fn,) = round_fns
    assert round_fn._cache_size() == 1, (
        f"expected 1 jit compilation across {len(variants)} control "
        f"policies, got {round_fn._cache_size()}")


def test_round_stats_shared_with_callbacks():
    """One observation surface: the RoundEvent's stats IS the payload the
    control plane observed (same object), with W/masks/phase/comm set."""
    seen = []

    class Grab(Callback):
        def on_round_end(self, ev):
            seen.append(ev.stats)

    cfg = _clf_config(rounds=3, control={"t_policy": "adaptive"})
    session = Session(cfg, callbacks=[Grab()])
    session.run()
    assert len(seen) == 3
    assert seen[-1] is session.last_stats
    for t, st in enumerate(seen):
        assert st.t == t
        assert st.W.shape == (4, 4)
        assert st.masks is not None and st.lora is not None
        assert np.isfinite(st.loss)
        assert st.loss_per_client.shape == (4,)
        assert st.comm_bytes >= 0
    # the plane folded every round into its history
    assert [row["t"] for row in session.control.history] == [0, 1, 2]
    assert 0.0 < session.control.rho_hat < 1.0


def test_control_plane_history_tracks_phase_and_T():
    cfg = _clf_config(rounds=6, T=2,
                      control={"t_policy": "adaptive", "c": 2.0,
                               "t_max": 4})
    session = Session(cfg)
    session.run()
    hist = session.control.history
    assert len(hist) == 6
    assert all(row["T"] >= 1 for row in hist)
    assert hist[-1]["phase"] >= 1      # alternation actually switched
    assert session.control.T == session.schedule.T


def test_checkpoint_resume_with_active_control():
    """Save mid-run under fmmc+adaptive, restore into a fresh session,
    finish: bitwise-equal state AND equal estimator state vs an
    uninterrupted run."""
    import tempfile
    cfg = _clf_config(rounds=6, control={"t_policy": "adaptive",
                                         "rho_estimator": "gram",
                                         "weight_policy": "fmmc"})
    ref = Session(cfg)
    ref.run()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        s1 = Session(cfg)
        s1.run(3)
        s1.save(path)
        s2 = Session(cfg)
        assert s2.restore(path) == 3
        assert s2.control.estimator.rho_sq == \
            pytest.approx(s1.control.estimator.rho_sq, abs=1e-12)
        s2.run(3)            # finish rounds 3..5 (run(n) = n MORE rounds)
    for a, b in zip(_leaves(ref.lora), _leaves(s2.lora)):
        np.testing.assert_array_equal(a, b)


def test_weight_conformance_predicate_on_live_session():
    cfg = _clf_config(rounds=5, topology="ring", n_clients=4, p=0.9,
                      control={"weight_policy": "fmmc"})
    session = Session(cfg)
    Ws = []

    class Grab(Callback):
        def on_round_end(self, ev):
            Ws.append(np.asarray(ev.stats.W))

    session.callbacks.append(Grab())
    session.run()
    adj = underlying_graph("ring", 4, seed=0)
    rep = weight_conformance(Ws, adj, p_eff=0.9)
    assert rep["ok"], rep
    assert rep["gap"] >= rep["bound"]
    assert rep["sym_err"] < 1e-8 and rep["ds_err"] < 1e-8


def test_cluster_session_rejects_frozen_estimator_on_grid(monkeypatch):
    from repro.api.cluster import ClusterSession
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    with pytest.raises(ValueError, match="frozen"):
        ClusterSession(_clf_config(control={"t_policy": "adaptive",
                                            "rho_estimator": "frozen"}))


def test_control_plane_standalone_observe():
    """ControlPlane drives without a Session: fold synthetic RoundStats,
    watch rho and T move."""
    plane = ControlPlane(ControlConfig(t_policy="adaptive",
                                       rho_estimator="spectral",
                                       c=1.0, t_max=8, ewma=1.0))
    adj = underlying_graph("ring", 8, seed=0)
    W = metropolis_weights(adj)
    for t in range(4):
        plane.observe(RoundStats(t, W))
    J = np.ones((8, 8)) / 8
    assert plane.rho_hat == pytest.approx(
        float(np.linalg.norm(W - J, 2)), abs=1e-12)
    assert plane.controller.target_T() > 1   # ring at m=8 wants T > 1
