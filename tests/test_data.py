"""The streaming data layer: shard readers, partitioners, FederatedStream.

Tier-1 guards for the determinism contract the data layer is built
around: `FederatedStream.round_batch(t)` is a pure function of the round
index, so checkpoint/restore replays bit-for-bit and every process grid
sees the identical global batch order (the grid half lives in
tests/test_multihost.py). The committed fixture under
tests/fixtures/shards/mnli_tiny (regenerate:
tests/fixtures/make_shards_fixture.py) spans multiple shards on purpose
— every stream test exercises the cross-shard gather.
"""
import os

import numpy as np
import pytest

import jax

from repro.data import (FederatedStream, ShardSet,
                        client_label_distributions, label_skew,
                        label_skew_partitions, make_partition, write_shards)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "shards",
                       "mnli_tiny")


@pytest.fixture(scope="module")
def shards() -> ShardSet:
    return ShardSet(FIXTURE)


# ---------------------------------------------------------------------------
# shard reader
# ---------------------------------------------------------------------------

def test_fixture_manifest(shards):
    assert shards.n_classes == 3
    assert shards.vocab_size == 256
    assert shards.seq_len == 16
    assert shards.split_size("train") == 480
    assert shards.split_size("val") == 96
    assert len(shards.splits["train"]) > 1, \
        "fixture must span multiple shards or the gather tests are vacuous"


def test_fixture_signature_pinned(shards):
    # byte-stable regeneration: make_shards_fixture.py with unchanged
    # SPEC must reproduce exactly this manifest
    assert shards.signature() == "24c7e8d7ba55a6d7"


def test_read_gathers_across_shard_boundaries(shards):
    full = np.concatenate([np.load(os.path.join(FIXTURE, fn))["tokens"]
                           for fn, _ in shards.splits["train"]])
    idx = np.array([0, 63, 64, 65, 479, 128, 63])   # boundaries + repeat
    got = shards.read("train", idx)
    np.testing.assert_array_equal(got["tokens"], full[idx])
    assert got["tokens"].dtype == np.int32
    assert got["labels"].shape == (len(idx),)


def test_read_rejects_bad_inputs(shards):
    with pytest.raises(KeyError):
        shards.read("test", np.array([0]))
    with pytest.raises(IndexError):
        shards.read("train", np.array([480]))


def test_eval_batch_balanced_and_seeded(shards):
    a = shards.eval_batch(64, seed=5)
    b = shards.eval_batch(64, seed=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    counts = np.bincount(a["labels"], minlength=3)
    assert (counts > 0).all()


def test_write_shards_validates(tmp_path):
    toks = np.zeros((10, 4), np.int32)
    with pytest.raises(ValueError, match="labels outside"):
        write_shards(str(tmp_path / "bad"), "t", n_classes=2, vocab_size=8,
                     splits={"train": {"tokens": toks,
                                       "labels": np.full(10, 5)}})
    with pytest.raises(ValueError, match="exceed vocab_size"):
        write_shards(str(tmp_path / "bad2"), "t", n_classes=2, vocab_size=8,
                     splits={"train": {"tokens": toks + 9,
                                       "labels": np.zeros(10, np.int32)}})


def test_shardset_requires_meta(tmp_path):
    with pytest.raises(FileNotFoundError):
        ShardSet(str(tmp_path))


# ---------------------------------------------------------------------------
# partitioners (tier-1 basics; distribution properties in test_property.py)
# ---------------------------------------------------------------------------

def test_domain_partition_recovers_generating_clients(shards):
    """The fixture is generated in per-client domain blocks; the domain
    partitioner must hand each client exactly one whole domain."""
    labels = shards.labels("train")
    parts = make_partition("domain", labels, 10, seed=3,
                           domains=shards.domains("train"))
    doms = shards.domains("train")
    for p in parts:
        assert len(np.unique(doms[p])) == 1
        assert len(p) == 48


def test_partitioners_cover_fixture(shards):
    labels = shards.labels("train")
    doms = shards.domains("train")
    for name in ("iid", "dirichlet", "quantity", "domain", "paper"):
        parts = make_partition(name, labels, 10, seed=1, domains=doms)
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(allidx)
        assert all(len(p) >= 1 for p in parts)


def test_paper_partition_matches_paper_rows(shards):
    """The 'paper' partitioner realizes the §VI-A label-skew rows on real
    rows — client 0's empirical mix must be ~[0.9, .05, .05]."""
    labels = shards.labels("train")
    parts = make_partition("paper", labels, 10, seed=0)
    dist = client_label_distributions(parts, labels, 3)
    rows = label_skew_partitions(3, 10)
    # sampling without replacement from 480 rows can't hit 0.9 exactly for
    # the last clients (the pool runs dry), but the dominant-class
    # structure must survive with most of the mass
    np.testing.assert_array_equal(dist.argmax(1), rows.argmax(1))
    assert dist[np.arange(10), rows.argmax(1)].min() > 0.6


def test_unknown_partitioner_rejected(shards):
    with pytest.raises(ValueError, match="unknown partitioner"):
        make_partition("zipf", shards.labels("train"), 4)
    with pytest.raises(ValueError, match="bad partitioner_kw"):
        make_partition("dirichlet", shards.labels("train"), 4, beta=2.0)


def test_label_skew_measure_orders_regimes(shards):
    labels = shards.labels("train")
    iid = make_partition("iid", labels, 10, seed=0)
    skewed = make_partition("dirichlet", labels, 10, seed=0, alpha=0.05)
    assert label_skew(iid, labels, 3) < label_skew(skewed, labels, 3)


# ---------------------------------------------------------------------------
# label_skew_partitions generalized branch (the once-unseeded path)
# ---------------------------------------------------------------------------

def test_generalized_label_skew_seeded_regression():
    """The non-paper shapes are a seeded Dirichlet draw: same seed ->
    identical matrix (pinned), different seed -> different matrix. The
    pre-fix branch created an rng and never used it."""
    a = label_skew_partitions(4, 6, seed=0)
    b = label_skew_partitions(4, 6, seed=0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (6, 4)
    np.testing.assert_allclose(a.sum(1), 1.0, atol=1e-12)
    assert (a >= 0).all()
    # client i's heaviest class is i mod n_classes (paper-row structure)
    np.testing.assert_array_equal(a.argmax(1), [0, 1, 2, 3, 0, 1])
    assert not np.allclose(a, label_skew_partitions(4, 6, seed=1))
    # regression pin: the default draw must stay reproducible across
    # releases (resampling would silently move every non-paper benchmark)
    np.testing.assert_allclose(
        a[0], [0.972891, 0.016838, 0.009560, 0.000711], atol=1e-5)


def test_paper_shapes_untouched_by_seed():
    np.testing.assert_array_equal(label_skew_partitions(3, 10, seed=0),
                                  label_skew_partitions(3, 10, seed=7))


# ---------------------------------------------------------------------------
# FederatedStream determinism
# ---------------------------------------------------------------------------

def _stream(shards, seed=7, prefetch=0):
    parts = make_partition("domain", shards.labels("train"), 10, seed=3,
                           domains=shards.domains("train"))
    return FederatedStream(shards, parts, batch=4, local_steps=2,
                           seed=seed, prefetch=prefetch)


def test_stream_shapes_and_dtype(shards):
    batch = next(_stream(shards))
    assert batch["tokens"].shape == (2, 10, 4, 16)
    assert batch["labels"].shape == (2, 10, 4)
    assert batch["tokens"].dtype == np.int32


def test_stream_pure_function_of_round(shards):
    """round_batch(t) is independent of visitation order — the property
    checkpoint replay and grid invariance both reduce to."""
    st = _stream(shards)
    forward = [st.round_batch(t) for t in range(8)]
    st2 = _stream(shards)
    for t in reversed(range(8)):
        got = st2.round_batch(t)
        np.testing.assert_array_equal(got["tokens"], forward[t]["tokens"])
        np.testing.assert_array_equal(got["labels"], forward[t]["labels"])


def test_stream_epoch_covers_every_row_once(shards):
    """Within one epoch a client visits each of its rows exactly once
    (per-epoch permutations, not i.i.d. draws)."""
    st = _stream(shards)
    # client 0 owns 48 rows; per round it consumes 8 -> epoch = 6 rounds
    rows = np.concatenate([st.client_rows(0, t) for t in range(6)])
    assert len(rows) == 48
    np.testing.assert_array_equal(np.sort(rows), np.sort(st.parts[0]))
    # the next epoch is a different permutation of the same rows
    rows2 = np.concatenate([st.client_rows(0, t) for t in range(6, 12)])
    np.testing.assert_array_equal(np.sort(rows2), np.sort(rows))
    assert (rows2 != rows).any()


def test_stream_checkpoint_midepoch_replays_bitwise(shards):
    """Checkpoint mid-epoch, restore, and the stream replays the exact
    batches the original would have produced — seek() IS the restore
    path (`Session.restore` calls it with the saved round)."""
    st = _stream(shards)
    for _ in range(3):           # 3 rounds x 8 samples = mid-epoch (48)
        next(st)
    want = [next(st) for _ in range(4)]
    restored = _stream(shards)
    restored.seek(3)
    for w in want:
        got = next(restored)
        np.testing.assert_array_equal(got["tokens"], w["tokens"])
        np.testing.assert_array_equal(got["labels"], w["labels"])


def test_stream_prefetch_bitwise_equal(shards):
    sync = _stream(shards)
    pre = _stream(shards, prefetch=2)
    try:
        for _ in range(5):
            a, b = next(sync), next(pre)
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
        pre.seek(1)
        sync2 = _stream(shards)
        sync2.seek(1)
        np.testing.assert_array_equal(next(pre)["tokens"],
                                      next(sync2)["tokens"])
    finally:
        pre.close()
    pre.close()          # idempotent


def test_stream_rejects_empty_client(shards):
    with pytest.raises(ValueError, match=">= 1 row"):
        FederatedStream(shards, [np.array([0, 1]), np.array([], np.int64)],
                        batch=2, local_steps=1)


def test_stream_seed_moves_order(shards):
    a = next(_stream(shards, seed=7))
    b = next(_stream(shards, seed=8))
    assert (a["tokens"] != b["tokens"]).any()


# ---------------------------------------------------------------------------
# Session integration (the tier-1 smoke of the full path)
# ---------------------------------------------------------------------------

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _shards_config(**kw):
    from repro.api import DFLConfig
    base = dict(model="encoder", task="mnli", model_kw=ENC_KW, n_clients=10,
                rounds=3, T=2, local_steps=2, batch_size=4, p=0.6,
                lr=5e-3, data_source="shards", data_path=FIXTURE,
                partitioner="domain", seed=0, eval_n=48)
    base.update(kw)
    return DFLConfig(**base)


def test_session_runs_on_shards():
    from repro.api import Session
    sess = Session(_shards_config())
    res = sess.run()
    assert np.isfinite(res.final_loss)
    ev = sess.evaluate(n=48)
    assert 0.0 <= ev["acc"] <= 1.0


def test_session_shard_checkpoint_restore_bitwise(tmp_path):
    from repro.api import Session
    cfg = _shards_config(rounds=4)
    a = Session(cfg)
    a.run(2)
    path = str(tmp_path / "ck.npz")
    a.save(path)
    a.run(2)
    b = Session(cfg)
    assert b.restore(path) == 2
    b.run(2)
    for la, lb in zip(jax.tree.leaves(a.lora), jax.tree.leaves(b.lora)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_session_partitioner_changes_data_not_compile():
    from repro.api import Session
    s1 = Session(_shards_config())
    s2 = Session(_shards_config(partitioner="dirichlet",
                                partitioner_kw=dict(alpha=0.2)))
    # same build signature -> same compiled round object (cache hit)
    assert s1.round_fn is s2.round_fn
    b1 = next(s1._batches)
    b2 = next(s2._batches)
    assert (b1["labels"] != b2["labels"]).any()


def test_config_validates_data_fields():
    from repro.api import DFLConfig
    with pytest.raises(ValueError, match="requires data_path"):
        _shards_config(data_path="")
    with pytest.raises(ValueError, match="unknown partitioner"):
        _shards_config(partitioner="zipf")
    with pytest.raises(ValueError, match="apply to data_source"):
        DFLConfig(model="encoder", task="mnli",
                  partitioner_kw=dict(alpha=0.1))
    with pytest.raises(ValueError, match="classifier tasks"):
        DFLConfig(task="lm", data_source="shards", data_path=FIXTURE)


def test_cache_key_tracks_data_fields():
    keys = {_shards_config().cache_key(),
            _shards_config(partitioner="dirichlet").cache_key(),
            _shards_config(partitioner="dirichlet",
                           partitioner_kw=dict(alpha=0.1)).cache_key()}
    assert len(keys) == 3
