"""Docs cannot rot silently: THEORY.md's symbol map must resolve against
the live package, its file:line pins must point inside real files, every
relative markdown link must hit an existing file, and every public
`repro.api` symbol must carry a docstring."""
import importlib
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THEORY = os.path.join(ROOT, "docs", "THEORY.md")
DOC_FILES = [
    os.path.join(ROOT, "README.md"),
    os.path.join(ROOT, "ROADMAP.md"),
    os.path.join(ROOT, "docs", "ARCHITECTURE.md"),
    THEORY,
]

_BACKTICK = re.compile(r"`([^`]+)`")
_DOTTED = re.compile(r"^repro(?:\.\w+)+$")
_FILE_PIN = re.compile(r"\(((?:src|tests|benchmarks)/[\w/.]+\.py):(\d+)\)")
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _theory_text():
    with open(THEORY) as f:
        return f.read()


def _dotted_refs():
    return sorted({tok for tok in _BACKTICK.findall(_theory_text())
                   if _DOTTED.match(tok)})


def test_theory_md_symbols_resolve():
    """Every backticked `repro.x.y[.z]` in THEORY.md must import/getattr:
    longest importable module prefix, then attribute-walk the rest
    (classes, methods, properties, module constants)."""
    refs = _dotted_refs()
    assert len(refs) >= 30, f"THEORY.md map looks gutted: {len(refs)} refs"
    bad = []
    for ref in refs:
        parts = ref.split(".")
        obj = None
        for cut in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:cut]))
                break
            except ImportError:
                continue
        if obj is None:
            bad.append(ref)
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            bad.append(ref)
    assert not bad, f"THEORY.md names missing symbols: {bad}"


def test_theory_md_test_references_exist():
    """Backticked `test_*` names in THEORY.md must exist as test functions
    in this tree (file-level match: `def test_name(`)."""
    text = _theory_text()
    names = sorted({tok for tok in _BACKTICK.findall(text)
                    if re.match(r"^test_\w+$", tok)})
    assert names, "THEORY.md should cite the asserting tests"
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    corpus = ""
    for fn in os.listdir(tests_dir):
        if fn.endswith(".py"):
            with open(os.path.join(tests_dir, fn)) as f:
                corpus += f.read()
    missing = [n for n in names if f"def {n}(" not in corpus]
    assert not missing, f"THEORY.md cites unknown tests: {missing}"


def test_theory_md_file_line_pins_valid():
    """(path.py:NN) pins must name real files with at least NN lines."""
    pins = _FILE_PIN.findall(_theory_text())
    assert pins, "THEORY.md should pin file:line locations"
    bad = []
    for path, line in pins:
        full = os.path.join(ROOT, path)
        if not os.path.exists(full):
            bad.append(f"{path} (missing)")
            continue
        with open(full) as f:
            n = sum(1 for _ in f)
        if int(line) > n:
            bad.append(f"{path}:{line} (file has {n} lines)")
    assert not bad, f"stale THEORY.md pins: {bad}"


@pytest.mark.parametrize("doc", DOC_FILES,
                         ids=[os.path.relpath(d, ROOT) for d in DOC_FILES])
def test_markdown_relative_links_resolve(doc):
    """Every relative [text](target) link in the doc tree must point at an
    existing file or directory (http(s) targets are skipped)."""
    assert os.path.exists(doc), doc
    with open(doc) as f:
        text = f.read()
    base = os.path.dirname(doc)
    bad = []
    for target in _MD_LINK.findall(text):
        if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            bad.append(target)
    assert not bad, f"{os.path.relpath(doc, ROOT)} has dead links: {bad}"


def test_api_public_symbols_documented():
    """Every name `repro.api` exports carries a non-empty docstring."""
    api = importlib.import_module("repro.api")
    missing = [n for n in api.__all__
               if not (getattr(api, n).__doc__ or "").strip()]
    assert not missing, f"undocumented repro.api exports: {missing}"
