"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs ref.py
oracle (task-mandated per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gossip_mix import gossip_mix
from repro.kernels.lora_matmul import lora_matmul, slot_lora_matmul
from repro.kernels.rglru_scan import rglru_scan

TOLS = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOLS[jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 8), (256, 384, 512, 16),
                                     (128, 256, 128, 4), (512, 128, 256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul(M, K, N, r, dtype, key):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = (jax.random.normal(ks[2], (K, r)) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N)) * 0.1).astype(dtype)
    y = lora_matmul(x, w, a, b, scale=2.0, interpret=True)
    yr = ref.lora_matmul_ref(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=K * _tol(dtype) * 0.05)


@pytest.mark.parametrize("B,K,N,r,n_ad", [(4, 128, 128, 8, 4),
                                          (3, 256, 384, 16, 8),
                                          (8, 128, 256, 4, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_slot_lora_matmul(B, K, N, r, n_ad, dtype, key):
    """Per-slot adapter gather kernel (multi-adapter serving) vs oracle,
    including repeated and out-of-order slot ids."""
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = (jax.random.normal(ks[2], (n_ad, K, r)) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[3], (n_ad, r, N)) * 0.1).astype(dtype)
    rng = np.random.default_rng(B * K)
    slots = jnp.asarray(rng.integers(0, n_ad, size=B), jnp.int32)
    y = slot_lora_matmul(x, w, a, b, slots, scale=2.0, bk=64, interpret=True)
    yr = ref.slot_lora_matmul_ref(x, w, a, b, slots, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=K * _tol(dtype) * 0.05)


def test_slot_lora_matmul_matches_single_adapter(key):
    """Slot row i with adapter s is bit-for-bit the plain single-adapter
    lora path for (x_i, a[s], b[s]) — the serving-equals-training-math
    invariant multi-adapter decode relies on."""
    from repro.kernels import ops
    ks = jax.random.split(key, 4)
    B, K, N, r, n_ad = 4, 128, 192, 8, 6
    x = jax.random.normal(ks[0], (B, K))
    w = jax.random.normal(ks[1], (K, N))
    a = jax.random.normal(ks[2], (n_ad, K, r)) * 0.1
    b = jax.random.normal(ks[3], (n_ad, r, N)) * 0.1
    slots = jnp.asarray([5, 0, 5, 2], jnp.int32)
    y = ops.slot_lora_matmul(x, w, a, b, slots, 2.0)
    for i, s in enumerate([5, 0, 5, 2]):
        yi = x[i:i + 1] @ w + ((x[i:i + 1] @ a[s]) @ b[s]) * 2.0
        np.testing.assert_array_equal(np.asarray(y[i:i + 1]),
                                      np.asarray(yi))


@pytest.mark.parametrize("S,L,window,causal", [
    (128, 128, None, True), (256, 256, 64, True), (128, 128, None, False),
    (256, 256, 200, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(S, L, window, causal, dtype, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 3, S, 64), dtype)
    k = jax.random.normal(ks[1], (2, 3, L, 64), dtype)
    v = jax.random.normal(ks[2], (2, 3, L, 64), dtype)
    y = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64,
                        interpret=True)
    yr = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 10)


@pytest.mark.parametrize("m,P", [(10, 512), (16, 2048), (4, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix(m, P, dtype, key):
    rng = np.random.default_rng(0)
    # random doubly-stochastic (symmetrized sinkhorn-ish)
    W = rng.random((m, m))
    for _ in range(50):
        W /= W.sum(1, keepdims=True)
        W /= W.sum(0, keepdims=True)
    W = jnp.asarray(W, jnp.float32)
    x = jax.random.normal(key, (m, P), dtype)
    y = gossip_mix(W, x, interpret=True)
    yr = ref.gossip_mix_ref(W, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("B,T,W", [(2, 256, 64), (1, 512, 96), (3, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, T, W, dtype, key):
    ks = jax.random.split(key, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W))).astype(dtype)
    u = (jax.random.normal(ks[1], (B, T, W)) * 0.1).astype(dtype)
    y = rglru_scan(a, u, bt=64, interpret=True)
    yr = ref.rglru_scan_ref(a, u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ops_dispatch_cpu_fallback(key):
    """ops.* must route to the jnp reference on CPU and stay correct."""
    from repro.kernels import ops
    x = jax.random.normal(key, (64, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    a = jax.random.normal(jax.random.fold_in(key, 2), (64, 8)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (8, 64)) * 0.1
    assert jax.default_backend() == "cpu"
    y = ops.lora_matmul(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.lora_matmul_ref(x, w, a, b, 2.0)))
    ops.set_backend("pallas_interpret")
    try:
        y2 = ops.lora_matmul(x, w, a, b, 2.0)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y), rtol=1e-4,
                                   atol=1e-4)
    finally:
        ops.set_backend(None)


def test_gossip_mix_flat_identity_mask(key):
    """mask=0 -> identity regardless of W (frozen-block no-mix)."""
    from repro.kernels import ops
    W = jnp.zeros((6, 6)) + 1.0 / 6
    x = jax.random.normal(key, (6, 100))
    y = ops.gossip_mix_flat(W, x, mask=0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)
