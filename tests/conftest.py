"""Shared fixtures. NOTE: no device-count override here by design — smoke
tests and benches must see the real single CPU device (task spec); only
launch/dryrun.py forces 512 host devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
