"""Integration tests of the DFL engine: method semantics, phase behaviour,
consensus dynamics — the paper's mechanics at CPU scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_lora_tree, consensus_stats, make_dfl_round,
                        make_topology, mix_tree, round_masks)
from repro.core.alternating import phase_is_a
from repro.data import federated_batches, label_skew_partitions, make_task
from repro.models.classifier import (classifier_loss, encoder_config,
                                     init_classifier)
from repro.optim import AdamW

M = 6


@pytest.fixture(scope="module")
def setup():
    cfg = encoder_config(n_layers=1, d_model=32, n_heads=2, d_ff=64,
                         vocab_size=256)
    key = jax.random.key(0)
    base = init_classifier(key, cfg, n_classes=2)
    lora = build_lora_tree(jax.random.key(1), base, cfg, n_clients=M)
    opt = AdamW(lr=1e-3)

    def loss_fn(bp, lo, micro):
        return classifier_loss(bp, cfg, micro["tokens"], micro["labels"],
                               lora=lo)

    round_fn = jax.jit(make_dfl_round(loss_fn, opt, local_steps=2))
    task = make_task("sst2", vocab_size=256)
    parts = label_skew_partitions(2, M)
    return cfg, base, lora, opt, round_fn, task, parts


def _run(setup, method, rounds=6, T=2, p=1.0, seed=0):
    cfg, base, lora, opt, round_fn, task, parts = setup
    topo = make_topology("complete", M, p=p, seed=seed)
    opt_state = opt.init(lora)
    for t, batch in enumerate(federated_batches(task, parts, 8, 2, rounds,
                                                seed=seed)):
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks(method, t, T).as_array()
        lora, opt_state, metrics = round_fn(base, lora, opt_state,
                                            jax.tree.map(jnp.asarray, batch),
                                            W, masks)
    return lora, metrics


def test_phase_schedule():
    # B-phase when floor(t/T) even (paper Algorithm 1)
    assert not phase_is_a(0, 3) and not phase_is_a(2, 3)
    assert phase_is_a(3, 3) and phase_is_a(5, 3)
    assert not phase_is_a(6, 3)


def test_ffa_freezes_a(setup):
    _, _, lora0, *_ = setup
    lora, _ = _run(setup, "ffa", rounds=4)
    for (p1, l1), (_, l0) in zip(
            jax.tree_util.tree_flatten_with_path(lora)[0],
            jax.tree_util.tree_flatten_with_path(lora0)[0]):
        name = p1[-1].key
        if name == "a":
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                                       atol=1e-7)
        else:
            assert float(jnp.max(jnp.abs(l1 - l0))) > 0


def test_alternating_updates_one_block_per_phase(setup):
    cfg, base, lora0, opt, round_fn, task, parts = setup
    opt_state = opt.init(lora0)
    batch = next(iter(federated_batches(task, parts, 8, 2, 1)))
    W = jnp.eye(M, dtype=jnp.float32)
    # round 0 with T=1 -> B-phase: a must stay (identity mixing)
    masks = round_masks("tad", 0, 1).as_array()
    lora1, _, _ = round_fn(base, lora0, opt_state,
                           jax.tree.map(jnp.asarray, batch), W, masks)
    for (p, l1), (_, l0) in zip(
            jax.tree_util.tree_flatten_with_path(lora1)[0],
            jax.tree_util.tree_flatten_with_path(lora0)[0]):
        if p[-1].key == "a":
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                                       atol=1e-7)
    # round 1 with T=1 -> A-phase: b must stay
    masks = round_masks("tad", 1, 1).as_array()
    lora2, _, _ = round_fn(base, lora1, opt_state,
                           jax.tree.map(jnp.asarray, batch), W, masks)
    for (p, l2), (_, l1) in zip(
            jax.tree_util.tree_flatten_with_path(lora2)[0],
            jax.tree_util.tree_flatten_with_path(lora1)[0]):
        if p[-1].key == "b":
            np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                                       atol=1e-7)


def test_tad_joint_mixing_cuts_frozen_drift(setup):
    """TAD (joint mixing) must keep smaller frozen-block disagreement than
    RoLoRA (active-only mixing) under sparse communication — the paper's
    central mechanism (Fig. 2 rationale)."""
    lora_tad, _ = _run(setup, "tad", rounds=8, T=2, p=0.3, seed=3)
    lora_rol, _ = _run(setup, "rolora", rounds=8, T=2, p=0.3, seed=3)
    s_tad = consensus_stats(lora_tad)
    s_rol = consensus_stats(lora_rol)
    tot_tad = float(s_tad["delta_a_sq"] + s_tad["delta_b_sq"])
    tot_rol = float(s_rol["delta_a_sq"] + s_rol["delta_b_sq"])
    assert tot_tad < tot_rol


def test_loss_decreases(setup):
    """Held-out loss on a FIXED batch must improve after training
    (per-round losses are on heterogeneous fresh batches — too noisy)."""
    from repro.data.synthetic import eval_batch
    from repro.models.classifier import classifier_loss
    cfg, base, lora, opt, round_fn, task, parts = setup
    topo = make_topology("complete", M, p=1.0, seed=0)
    opt_state = opt.init(lora)
    ev = eval_batch(task, 128, seed=5)
    toks, labs = jnp.asarray(ev["tokens"]), jnp.asarray(ev["labels"])

    def held_out(lo):
        li = jax.tree.map(lambda x: x[..., 0, :, :], lo)
        return float(classifier_loss(base, cfg, toks, labs, lora=li))

    before = held_out(lora)
    for t, batch in enumerate(federated_batches(task, parts, 16, 2, 15,
                                                seed=1)):
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks("tad", t, 2).as_array()
        lora, opt_state, metrics = round_fn(base, lora, opt_state,
                                            jax.tree.map(jnp.asarray, batch),
                                            W, masks)
    after = held_out(lora)
    assert after < before, (before, after)


def test_identity_mixing_is_noop(setup):
    _, _, lora, *_ = setup
    W = jnp.eye(M, dtype=jnp.float32)
    mixed = mix_tree(W, lora, 1.0, 1.0)
    for l1, l0 in zip(jax.tree.leaves(mixed), jax.tree.leaves(lora)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=1e-7)


def test_planned_round_matches_per_leaf_oracle(setup):
    """The default round (planned fused mixing) must match a per_leaf
    round bit-for-bit at equal mix masks — same batch, same W, same
    state in, identical state out."""
    cfg, base, lora0, opt, _, task, parts = setup

    def loss_fn(bp, lo, micro):
        return classifier_loss(bp, cfg, micro["tokens"], micro["labels"],
                               lora=lo)

    rf_planned = jax.jit(make_dfl_round(loss_fn, opt, local_steps=2))
    rf_oracle = jax.jit(make_dfl_round(loss_fn, opt, local_steps=2,
                                       mix_impl="per_leaf"))
    batch = jax.tree.map(jnp.asarray,
                         next(iter(federated_batches(task, parts, 8, 2, 1))))
    topo = make_topology("complete", M, p=0.5, seed=7)
    W = jnp.asarray(topo.sample(), jnp.float32)
    masks = round_masks("lora", 0, 1).as_array()    # equal mix masks
    st1 = opt.init(lora0)
    st2 = opt.init(lora0)
    l1, o1, m1 = rf_planned(base, lora0, st1, batch, W, masks)
    l2, o2, m2 = rf_oracle(base, lora0, st2, batch, W, masks)
    for a, b in zip(jax.tree.leaves((l1, o1)), jax.tree.leaves((l2, o2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m1["loss"]),
                                  np.asarray(m2["loss"]))
