"""Dry-run machinery on the REAL single CPU device (no forced device
count — task rule): the launch/steps builders must produce lowerable
programs on a trivial 1x1 mesh for reduced configs.

The production 16x16 / 2x16x16 meshes are exercised by
`python -m repro.launch.dryrun` (results/dryrun.json); here we pin the
machinery itself: spec building, sharding resolution, jaxpr costing.
"""
import numpy as np

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import SHAPES, get_config
from repro.configs.shapes import InputShape
from repro.dist import sharding as shd
from repro.launch import steps as steps_mod
from repro.roofline.analysis import jaxpr_cost


def tiny_shape(kind: str) -> InputShape:
    return {"train": InputShape("t", 64, 4, "train"),
            "prefill": InputShape("p", 64, 2, "prefill"),
            "decode": InputShape("d", 64, 2, "decode")}[kind]


@pytest.fixture()
def mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


@pytest.mark.parametrize("arch", ["gemma3-1b", "deepseek-moe-16b",
                                  "xlstm-1.3b", "whisper-tiny",
                                  "recurrentgemma-2b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_reduced_combo_lowers(arch, kind, mesh1):
    cfg = get_config(arch).reduced()
    shape = tiny_shape(kind)
    shd.set_mesh(mesh1, shd.DEFAULT_AXIS_MAP)
    try:
        step, specs, n_tokens, training = steps_mod.build(
            cfg, shape, mesh1, local_steps=1, dtype=jnp.float32,
            axis_map=shd.DEFAULT_AXIS_MAP)
        lowered = jax.jit(step).lower(*specs)
        assert "hlo" in lowered.as_text().lower() or lowered is not None
        # jaxpr cost must be positive and scan-aware
        jxp = jax.make_jaxpr(step)(*specs)
        cost = jaxpr_cost(jxp)
        assert cost["flops"] > 0 and cost["bytes"] > 0
    finally:
        shd.clear_mesh()


def test_train_flops_scale_with_local_steps(mesh1):
    cfg = get_config("gemma3-1b").reduced()
    shape = tiny_shape("train")
    shd.set_mesh(mesh1, shd.DEFAULT_AXIS_MAP)
    try:
        costs = {}
        for ls in (1, 2):
            step, specs, *_ = steps_mod.build(
                cfg, shape, mesh1, local_steps=ls, dtype=jnp.float32,
                axis_map=shd.DEFAULT_AXIS_MAP)
            costs[ls] = jaxpr_cost(jax.make_jaxpr(step)(*specs))["flops"]
        ratio = costs[2] / costs[1]
        assert 1.7 < ratio < 2.3, ratio
    finally:
        shd.clear_mesh()


def test_shape_applicability_matrix():
    """34 runnable combos: 40 minus 6 long_500k skips."""
    from repro.configs import ARCH_IDS, all_configs, shape_applicable
    runnable = skipped = 0
    for cfg in all_configs().values():
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            runnable += ok
            skipped += not ok
            if not ok:
                assert s.name == "long_500k" and why
    assert runnable == 34 and skipped == 6
