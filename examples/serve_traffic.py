"""Poisson traffic through the paged serving core, end to end.

Drives a `ServingSession` in its full serving-core configuration — paged
KV (page pool + per-slot block tables, the scalar-prefetch paged-attention
kernel on TPU), chunked prefill, and the DRR admission scheduler with a
per-tenant quota — under open-loop Poisson arrivals across several
adapters, then prints the request-lifecycle metrics the scheduler
collects (queue wait, TTFT, latency percentiles, preemptions) and asserts
the one-compile invariant held across every occupancy the trace visited.

The page pool is deliberately sized BELOW full per-slot coverage so a
burst triggers preemption-by-page-eviction: the latest-admitted stream
loses its pages, requeues at the front, and recomputes on re-admission —
its final tokens are exactly what an uncontended run produces.

  PYTHONPATH=src python examples/serve_traffic.py
  PYTHONPATH=src python examples/serve_traffic.py --requests 50 --rate 1.0
"""
import argparse

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3-1b")
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--requests", type=int, default=16)
ap.add_argument("--rate", type=float, default=0.5,
                help="mean arrivals per engine tick")
ap.add_argument("--gen", type=int, default=8)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

import jax

from repro.api.serving import AdapterPool, ServingSession
from repro.configs import get_config
from repro.core.lora import build_lora_tree
from repro.models import transformer as tf

cfg = get_config(args.arch).reduced()
params = tf.init_params(jax.random.key(0), cfg)

# 4 distinct adapters (as if 4 tenants fine-tuned separately)
tree = build_lora_tree(jax.random.key(3), params, cfg, n_clients=4)
c = [0]


def fill(x):
    c[0] += 1
    return 0.1 * jax.random.normal(jax.random.key(10 + c[0]), x.shape)


pool = AdapterPool.from_stacked(jax.tree.map(fill, tree), consensus=False)

page_size, max_len = 8, 64
pages_full = args.slots * (max_len // page_size)
serving = ServingSession(
    model_cfg=cfg, params=params, adapters=pool, n_slots=args.slots,
    max_len=max_len, paged=True, page_size=page_size,
    n_pages=1 + max(max_len // page_size, int(0.4 * pages_full)),
    prefill_chunk=page_size)
eng = serving.engine
names = [f"client_{i}" for i in range(4)]
print(f"engine: {args.slots} slots, {eng.page_pool.capacity} pages of "
      f"{page_size} (vs {pages_full} for full coverage), chunked prefill")

rng = np.random.default_rng(args.seed)
arrive = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
           for n in rng.integers(2, 20, size=args.requests)]

nxt, max_streams = 0, 0
while nxt < args.requests or eng.scheduler.n_queued or \
        any(s.req is not None for s in eng.slots):
    while nxt < args.requests and arrive[nxt] <= eng.ticks:
        serving.submit(prompts[nxt], adapter=names[nxt % 4],
                       max_new=args.gen)
        nxt += 1
    max_streams = max(max_streams, eng.tick())

m = serving.metrics()
print(f"completed {m['completed']}/{args.requests} requests in "
      f"{m['ticks']} ticks ({m['device_steps']} device steps, "
      f"{m['preemptions']} preemptions, max {max_streams} streams)")
print(f"queue wait p50 {m['queue_wait_ticks']['p50']:.0f} ticks, "
      f"TTFT p50 {m['ttft_ticks']['p50']:.0f} ticks, "
      f"latency p50 {m['latency_s']['p50'] * 1e3:.0f} ms / "
      f"p99 {m['latency_s']['p99'] * 1e3:.0f} ms")
assert m["completed"] == args.requests
assert serving.compile_count == 1, "decode retraced under traffic"
assert eng.prefill.compile_count == 1, "chunk prefill retraced"
assert eng.page_pool.n_used == 0, "pages leaked"
print("one compiled decode step + one compiled chunk step across the "
      "whole trace; all pages returned")
