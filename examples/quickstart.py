"""Quickstart: TAD-LoRA (Algorithm 1) in ~20 lines of declarative API.

Runs 15 decentralized rounds of alternating-LoRA fine-tuning of a reduced
gemma3-1b on synthetic LM data with 6 clients over a sparse Erdős–Rényi
gossip graph, printing loss and the theory diagnostics each round.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ConsoleLogger, DFLConfig, Session

config = DFLConfig(
    model="gemma3-1b", task="lm",            # any assigned arch; reduced()
    n_clients=6, topology="complete", p=0.15,
    method="tad", T=0,                       # T=0 -> topology-aware T* (Cor. A.11)
    rounds=15, local_steps=2, batch_size=4, seq_len=32,
    lr=1e-3, seed=0,
)

session = Session(config, callbacks=[ConsoleLogger(consensus=True)])
print(f"rho≈{session.rho:.3f} -> topology-aware switching interval "
      f"T*={session.T}")
result = session.run()

print(f"final loss {result.final_loss:.4f} after {result.rounds} rounds "
      f"({result.wall_s:.1f}s)")
print("done — swap method to 'rolora'/'ffa'/'lora' to compare baselines.")
