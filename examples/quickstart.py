"""Quickstart: TAD-LoRA (Algorithm 1) in ~60 lines of public API.

Runs 15 decentralized rounds of alternating-LoRA fine-tuning of a reduced
gemma3-1b on synthetic LM data with 6 clients over a sparse Erdős–Rényi
gossip graph, printing loss and the theory diagnostics each round.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (build_lora_tree, consensus_stats, make_dfl_round,
                        make_topology, optimal_switching_interval,
                        round_masks)
from repro.data.synthetic import lm_token_stream
from repro.models import transformer as tf
from repro.optim import AdamW

M, ROUNDS, LOCAL_STEPS, BATCH, SEQ = 6, 15, 2, 4, 32

# 1) model: any assigned architecture; reduced() for CPU
cfg = get_config("gemma3-1b").reduced()
key = jax.random.key(0)
base = tf.init_params(key, cfg)                       # frozen base weights
lora = build_lora_tree(key, base, cfg, n_clients=M)   # per-client adapters

# 2) communication: ER edge-activation gossip, topology-aware T* (Cor. A.11)
topo = make_topology("complete", M, p=0.15, seed=0)
rho = topo.rho_estimate(100)
T = optimal_switching_interval(rho)
print(f"rho≈{rho:.3f} -> topology-aware switching interval T*={T}")

# 3) the DFL round (local AdamW on the active block + joint mixing)
opt = AdamW(lr=1e-3)
opt_state = opt.init(lora)

def loss_fn(bp, lo, micro):
    return tf.lm_loss(bp, cfg, micro["tokens"], micro["targets"],
                      lora=lo)[0]

round_fn = jax.jit(make_dfl_round(loss_fn, opt, local_steps=LOCAL_STEPS))

stream = lm_token_stream(cfg.vocab_size, BATCH * LOCAL_STEPS, SEQ,
                         n_clients=M, seed=0)
for t in range(ROUNDS):
    raw = next(stream)
    batch = {k: jnp.asarray(
        v.reshape(M, LOCAL_STEPS, BATCH, SEQ).swapaxes(0, 1))
        for k, v in raw.items()}
    W = jnp.asarray(topo.sample(), jnp.float32)       # this round's graph
    masks = round_masks("tad", t, T).as_array()       # TAD-LoRA (ours)
    lora, opt_state, metrics = round_fn(base, lora, opt_state, batch, W,
                                        masks)
    stats = consensus_stats(lora)
    phase = "A" if masks[0] else "B"
    print(f"round {t:2d} [{phase}-phase] loss={float(metrics['loss']):.4f} "
          f"‖C‖={float(stats['cross_norm']):.2e} "
          f"Δ_A²={float(stats['delta_a_sq']):.2e} "
          f"Δ_B²={float(stats['delta_b_sq']):.2e}")

print("done — swap masks to 'rolora'/'ffa'/'lora' to compare baselines.")
