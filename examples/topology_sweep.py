"""Topology sweep: the paper's headline phenomenon in one script.

Trains TAD-LoRA and RoLoRA classifiers across p ∈ {0.5, 0.1, 0.02} and
reports final accuracy + consensus diagnostics — TAD's advantage appears
as p shrinks (Fig. 2), and the cross-term grows as communication weakens
(Prop. A.5). `--graphs` sweeps the underlying graph family as well
(`repro.core.topology.GRAPH_FAMILIES`: complete, ring, erdos_renyi,
exponential, torus, small_world) — the spectral ladder λ2(L) orders how
fast each family degrades.

  PYTHONPATH=src python examples/topology_sweep.py [--rounds 40]
  PYTHONPATH=src python examples/topology_sweep.py \
      --graphs complete,torus,ring --rounds 40
"""
import argparse

from repro.api import DFLConfig, Session
from repro.core.topology import GRAPH_FAMILIES

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=40)
ap.add_argument("--graphs", default="complete",
                help="comma-separated graph families "
                     f"(choices: {','.join(GRAPH_FAMILIES)}, or 'all')")
args = ap.parse_args()
graphs = list(GRAPH_FAMILIES) if args.graphs == "all" \
    else [g.strip() for g in args.graphs.split(",") if g.strip()]

base = DFLConfig(
    model="encoder", task="mnli",
    model_kw=dict(n_layers=2, d_model=64, vocab_size=512),
    n_clients=10, rounds=args.rounds, local_steps=4, batch_size=16,
    T=3, lr=2e-3, seed=0, data_seed=5, eval_seed=10_000,
)

print(f"{'graph':>12} {'p':>6} {'method':>8} {'acc':>8} {'‖C‖':>10} "
      f"{'Δ_A²+Δ_B²':>10}")
for graph in graphs:
    for p in (0.5, 0.1, 0.02):
        for method in ("tad", "rolora"):
            session = Session(base.replace(topology=graph, p=p,
                                           method=method))
            session.run()
            acc = session.evaluate()["acc"]
            s = session.consensus()
            print(f"{graph:>12} {p:>6} {method:>8} {acc:>8.4f} "
                  f"{s['cross_norm']:>10.2e} "
                  f"{s['delta_a_sq'] + s['delta_b_sq']:>10.2e}")
