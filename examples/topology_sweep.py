"""Topology sweep: the paper's headline phenomenon in one script.

Trains TAD-LoRA and RoLoRA classifiers across p ∈ {0.5, 0.1, 0.02} and
reports final accuracy + consensus diagnostics — TAD's advantage appears
as p shrinks (Fig. 2), and the cross-term grows as communication weakens
(Prop. A.5).

  PYTHONPATH=src python examples/topology_sweep.py [--rounds 40]
"""
import argparse

from repro.api import DFLConfig, Session

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=40)
args = ap.parse_args()

base = DFLConfig(
    model="encoder", task="mnli",
    model_kw=dict(n_layers=2, d_model=64, vocab_size=512),
    n_clients=10, rounds=args.rounds, local_steps=4, batch_size=16,
    T=3, lr=2e-3, seed=0, data_seed=5, eval_seed=10_000,
)

print(f"{'p':>6} {'method':>8} {'acc':>8} {'‖C‖':>10} {'Δ_A²+Δ_B²':>10}")
for p in (0.5, 0.1, 0.02):
    for method in ("tad", "rolora"):
        session = Session(base.replace(p=p, method=method))
        session.run()
        acc = session.evaluate()["acc"]
        s = session.consensus()
        print(f"{p:>6} {method:>8} {acc:>8.4f} {s['cross_norm']:>10.2e} "
              f"{s['delta_a_sq'] + s['delta_b_sq']:>10.2e}")
