"""Topology sweep: the paper's headline phenomenon in one script.

Trains TAD-LoRA and RoLoRA classifiers across p ∈ {0.5, 0.1, 0.02} and
reports final accuracy + consensus diagnostics — TAD's advantage appears
as p shrinks (Fig. 2), and the cross-term grows as communication weakens
(Prop. A.5).

  PYTHONPATH=src python examples/topology_sweep.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_lora_tree, consensus_stats, make_dfl_round,
                        make_topology, round_masks)
from repro.data import federated_batches, label_skew_partitions, make_task
from repro.data.synthetic import eval_batch
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     encoder_config, init_classifier)
from repro.optim import AdamW

M, ROUNDS, LOCAL_STEPS, T = 10, 40, 4, 3

cfg = encoder_config(n_layers=2, d_model=64, vocab_size=512)
task = make_task("mnli")
parts = label_skew_partitions(task.n_classes, M)
key = jax.random.key(0)
base = init_classifier(key, cfg, n_classes=task.n_classes)
lora0 = build_lora_tree(jax.random.key(1), base, cfg, n_clients=M)
opt = AdamW(lr=2e-3)

def loss_fn(bp, lo, micro):
    return classifier_loss(bp, cfg, micro["tokens"], micro["labels"],
                           lora=lo)

round_fn = jax.jit(make_dfl_round(loss_fn, opt, local_steps=LOCAL_STEPS))
test = eval_batch(task, 384)
toks, labs = jnp.asarray(test["tokens"]), jnp.asarray(test["labels"])

print(f"{'p':>6} {'method':>8} {'acc':>8} {'‖C‖':>10} {'Δ_A²+Δ_B²':>10}")
for p in (0.5, 0.1, 0.02):
    for method in ("tad", "rolora"):
        topo = make_topology("complete", M, p=p, seed=0)
        lora, opt_state = lora0, opt.init(lora0)
        for t, batch in enumerate(federated_batches(
                task, parts, 16, LOCAL_STEPS, ROUNDS, seed=5)):
            W = jnp.asarray(topo.sample(), jnp.float32)
            masks = round_masks(method, t, T).as_array()
            lora, opt_state, _ = round_fn(
                base, lora, opt_state, jax.tree.map(jnp.asarray, batch),
                W, masks)
        accs = [float(classifier_accuracy(
            base, cfg, toks, labs,
            lora=jax.tree.map(lambda x: x[..., i, :, :], lora)))
            for i in range(M)]
        s = consensus_stats(lora)
        print(f"{p:>6} {method:>8} {np.mean(accs):>8.4f} "
              f"{float(s['cross_norm']):>10.2e} "
              f"{float(s['delta_a_sq'] + s['delta_b_sq']):>10.2e}")
