"""Serving example: batched prefill + KV-cache decode on a reduced
architecture, optionally with merged TAD-LoRA adapters — exercises the same
decode path the decode_32k / long_500k dry-runs lower.

  PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mixtral-8x22b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
key = jax.random.key(0)
params = tf.init_params(key, cfg)
tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                            cfg.vocab_size)
frontend = None
if cfg.n_frontend_tokens:
    frontend = jax.random.normal(
        key, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02

# prefill: last-position logits (the 32k dry-run lowers exactly this step)
t0 = time.time()
last_logits = tf.prefill(params, cfg, tokens, frontend=frontend)
print(f"prefill: batch={args.batch} len={args.prompt_len} "
      f"-> logits {last_logits.shape} in {time.time()-t0:.2f}s")

# decode: replay prompt into the cache, then greedy-generate
cache = tf.init_cache(cfg, args.batch, args.prompt_len + args.gen + 1)
decode = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, t, c))
for t in range(args.prompt_len):
    logits, cache = decode(params, cache, tokens[:, t:t + 1])

cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
out = [cur]
t0 = time.time()
for _ in range(args.gen):
    logits, cache = decode(params, cache, cur)
    cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    out.append(cur)
dt = time.time() - t0
gen = jnp.concatenate(out, axis=1)
print(f"decode: {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
      f"({args.gen*args.batch/dt:.1f} tok/s, rolling-window caches "
      f"{'on' if any(s.window for s in cfg.pattern) else 'off'})")
print("sample tokens:", gen[0, :12].tolist())
