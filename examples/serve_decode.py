"""Train -> checkpoint -> multi-adapter serve, end to end.

A few TAD-LoRA rounds on a reduced architecture produce one adapter per
client; `ServingSession` then serves every client's adapter (plus the
gossip consensus) side by side from ONE compiled decode step — each decode
slot gathers its adapter by slot id inside the kernel, so heterogeneous
adapters cost no recompilation. `--skip-train` serves the base model only
(pure engine benchmark; the decode path here is what the decode_32k /
long_500k dry-runs lower at production scale).

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b
"""
import argparse
import os
import tempfile
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3-1b")
ap.add_argument("--rounds", type=int, default=2)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--gen", type=int, default=16)
ap.add_argument("--skip-train", action="store_true")
args = ap.parse_args()

from repro.api import (CheckpointCallback, DFLConfig, ServingSession,
                       Session)

ckpt = ""
if not args.skip_train:
    # 1. train: a short decentralized run, one LoRA adapter per client
    ckpt = os.path.join(tempfile.mkdtemp(), "run.npz")
    config = DFLConfig(model=args.arch, task="lm", n_clients=args.clients,
                       rounds=args.rounds, local_steps=1, batch_size=2,
                       seq_len=16, T=1)
    session = Session(config, callbacks=[CheckpointCallback(ckpt)])
    result = session.run()
    print(f"trained {args.rounds} rounds, final loss {result.final_loss:.3f}"
          f" -> {ckpt}")

# 2. serve: every per-client adapter + consensus from one compiled step
serving = ServingSession(args.arch, checkpoint=ckpt,
                         n_slots=args.clients,
                         max_len=args.prompt_len + args.gen + 8)
cfg = serving.model_cfg
rng = np.random.default_rng(0)
# every trained adapter + consensus ("base" excluded — it is the zero row);
# --skip-train has no pool and serves the base model on every slot
names = [n for n in serving.adapters if n != "base"] or [None]
rids = []
for i in range(max(args.clients, len(names))):
    prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len)
    rids.append(serving.submit(prompt, adapter=names[i % len(names)],
                               max_new=args.gen))

t0 = time.time()
serving.run()
dt = time.time() - t0
total = len(rids) * (args.prompt_len + args.gen)
print(f"decoded {args.gen} tokens x {len(rids)} requests in {dt:.2f}s "
      f"({total / dt:.1f} tok/s, {serving.compile_count} compile, "
      f"adapters: {names})")
for rid in rids:
    req = serving.engine.requests[rid]
    print(f"  [{req.adapter or 'base':>9}] {serving.result(rid)[:10]}")
