"""End-to-end driver: train a ~100M-param LM for a few hundred DFL rounds.

Builds a ~100M decoder (gemma3-family geometry scaled down), fine-tunes it
with TAD-LoRA over a 8-client gossip graph for 200 rounds (LM objective on
synthetic non-IID token streams) through a `repro.api.Session`, checkpoints
the LoRA state, then merges the consensus adapters and compares held-out
perplexity before/after.

  PYTHONPATH=src python examples/dfl_finetune.py [--rounds 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConsoleLogger, DFLConfig, Session
from repro.configs import get_config
from repro.configs.base import LayerSpec, ATTN, DENSE
from repro.core import client_mean, merge_lora
from repro.data.synthetic import lm_token_stream
from repro.models import transformer as tf


def model_100m():
    """~100M-param decoder (8L, d=768, 12H, ff=2048, vocab 32k)."""
    return dataclasses.replace(
        get_config("gemma3-1b"),
        name="gemma-ish-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        pattern=(LayerSpec(kind=ATTN, window=256, ffn=DENSE),
                 LayerSpec(kind=ATTN, window=None, ffn=DENSE)),
    )


def perplexity(base, cfg, lora, batches):
    tot, n = 0.0, 0
    for b in batches:
        loss, (ce, _) = tf.lm_loss(base, cfg, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["targets"]), lora=lora,
                                   remat=False)
        tot += float(ce)
        n += 1
    return float(np.exp(tot / n))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--small", action="store_true",
                    help="reduced model + fewer rounds (CI-speed)")
    args = ap.parse_args()

    config = DFLConfig(
        model="gemma3-1b", task="lm", reduced=args.small,
        n_clients=args.clients, p=args.p, method="tad", T=0,
        rounds=10 if args.small else args.rounds,
        local_steps=args.local_steps, batch_size=args.batch,
        seq_len=args.seq, lr=2e-3, seed=0,
    )
    session = Session(config,
                      model_cfg=None if args.small else model_100m(),
                      callbacks=[ConsoleLogger(every=20)])
    cfg, base = session.model_cfg, session.base

    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.0f}M params, "
          f"{config.rounds} rounds x {config.local_steps} local steps, "
          f"m={config.n_clients}")
    n_lora = sum(x.size for x in jax.tree.leaves(session.lora)) \
        // config.n_clients
    print(f"LoRA params per client: {n_lora/1e3:.1f}K "
          f"({100*n_lora/n_params:.3f}% of base)")
    print(f"T*={session.T}")

    # held-out eval stream (same non-IID mixture, new draws)
    eval_stream = lm_token_stream(cfg.vocab_size, 8, args.seq, seed=777)
    eval_batches = [next(eval_stream) for _ in range(4)]
    ppl0 = perplexity(base, cfg, None, eval_batches)
    print(f"held-out perplexity before training: {ppl0:.1f}")

    result = session.run()
    print(f"trained {result.rounds} rounds in {result.wall_s:.1f}s "
          f"({result.wall_s / result.rounds:.2f}s/round)")

    session.save("results/dfl_finetune_lora.npz")
    print("checkpoint -> results/dfl_finetune_lora.npz")

    consensus = client_mean(session.lora)
    merged = merge_lora(base, consensus, cfg)
    ppl1 = perplexity(merged, cfg, None, eval_batches)
    print(f"held-out perplexity after merge: {ppl1:.1f} "
          f"(improvement {ppl0/ppl1:.2f}x)")


if __name__ == "__main__":
    main()
