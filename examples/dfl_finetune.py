"""End-to-end driver: train a ~100M-param LM for a few hundred DFL rounds.

Builds a ~100M decoder (gemma3-family geometry scaled down), fine-tunes it
with TAD-LoRA over a 8-client gossip graph for 200 rounds (LM objective on
synthetic non-IID token streams), checkpoints the LoRA state, then merges
the consensus adapters and compares held-out perplexity before/after.

  PYTHONPATH=src python examples/dfl_finetune.py [--rounds 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.configs.base import LayerSpec, ATTN, DENSE
from repro.core import (build_lora_tree, client_mean, make_dfl_round,
                        make_topology, merge_lora, optimal_switching_interval,
                        round_masks)
from repro.data.synthetic import lm_token_stream
from repro.models import transformer as tf
from repro.optim import AdamW


def model_100m():
    """~100M-param decoder (8L, d=768, 12H, ff=2048, vocab 32k)."""
    return dataclasses.replace(
        get_config("gemma3-1b"),
        name="gemma-ish-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        pattern=(LayerSpec(kind=ATTN, window=256, ffn=DENSE),
                 LayerSpec(kind=ATTN, window=None, ffn=DENSE)),
    )


def perplexity(base, cfg, lora, batches):
    tot, n = 0.0, 0
    for b in batches:
        loss, (ce, _) = tf.lm_loss(base, cfg, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["targets"]), lora=lora,
                                   remat=False)
        tot += float(ce)
        n += 1
    return float(np.exp(tot / n))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--small", action="store_true",
                    help="reduced model + fewer rounds (CI-speed)")
    args = ap.parse_args()

    cfg = get_config("gemma3-1b").reduced() if args.small else model_100m()
    rounds = 10 if args.small else args.rounds
    m = args.clients

    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.0f}M params, "
          f"{rounds} rounds x {args.local_steps} local steps, m={m}")

    key = jax.random.key(0)
    base = tf.init_params(key, cfg)
    lora = build_lora_tree(jax.random.key(1), base, cfg, n_clients=m)
    n_lora = sum(x.size for x in jax.tree.leaves(lora)) // m
    print(f"LoRA params per client: {n_lora/1e3:.1f}K "
          f"({100*n_lora/n_params:.3f}% of base)")

    topo = make_topology("complete", m, p=args.p, seed=0)
    T = optimal_switching_interval(topo.rho_estimate(100))
    print(f"T*={T}")

    opt = AdamW(lr=2e-3)
    opt_state = opt.init(lora)

    def loss_fn(bp, lo, micro):
        return tf.lm_loss(bp, cfg, micro["tokens"], micro["targets"],
                          lora=lo)[0]

    round_fn = jax.jit(make_dfl_round(loss_fn, opt,
                                      local_steps=args.local_steps))
    stream = lm_token_stream(cfg.vocab_size, args.batch * args.local_steps,
                             args.seq, n_clients=m, seed=0)

    # held-out eval stream (same non-IID mixture, new draws)
    eval_stream = lm_token_stream(cfg.vocab_size, 8, args.seq, seed=777)
    eval_batches = [next(eval_stream) for _ in range(4)]
    ppl0 = perplexity(base, cfg, None, eval_batches)
    print(f"held-out perplexity before training: {ppl0:.1f}")

    t0 = time.time()
    for t in range(rounds):
        raw = next(stream)
        batch = {k: jnp.asarray(v.reshape(m, args.local_steps, args.batch,
                                          args.seq).swapaxes(0, 1))
                 for k, v in raw.items()}
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks("tad", t, T).as_array()
        lora, opt_state, metrics = round_fn(base, lora, opt_state, batch,
                                            W, masks)
        if t % 20 == 0 or t == rounds - 1:
            print(f"  round {t:4d} loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(t+1):.2f}s/round)")

    save_pytree("results/dfl_finetune_lora.npz", {"lora": lora})
    print("checkpoint -> results/dfl_finetune_lora.npz")

    consensus = client_mean(lora)
    merged = merge_lora(base, consensus, cfg)
    ppl1 = perplexity(merged, cfg, None, eval_batches)
    print(f"held-out perplexity after merge: {ppl1:.1f} "
          f"(improvement {ppl0/ppl1:.2f}x)")


if __name__ == "__main__":
    main()
