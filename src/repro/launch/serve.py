"""Serving CLI on `repro.api.ServingSession`: continuous-batching decode
with per-request TAD-LoRA adapters from a training checkpoint.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --batch 4 --prompt-len 32 --gen 16 \
      [--lora run.npz] [--merge] [--adapter consensus]

Default with ``--lora``: every per-client adapter the checkpoint holds
(plus their consensus mean) is served side-by-side from ONE compiled decode
step — request i decodes under adapter i mod n_adapters. ``--merge`` folds
the consensus adapter into the base weights instead (the pre-multi-adapter
behavior); ``--adapter NAME`` pins every request to one adapter.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api.serving import AdapterPool, ServingSession
from repro.checkpoint import load_pytree
from repro.core.lora import client_mean, merge_lora
from repro.models import transformer as tf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (= decode slots)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--lora", default="",
                    help="Session checkpoint with per-client LoRA adapters")
    ap.add_argument("--merge", action="store_true",
                    help="fold the consensus adapter into the base weights "
                         "instead of multi-adapter serving")
    ap.add_argument("--adapter", default="",
                    help="serve every request with this one adapter")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    key = jax.random.key(args.seed)
    pool = None
    params = None
    if args.lora and args.merge:
        # legacy path: one merged model, no adapter pool
        from repro.configs import get_config
        cfg = get_config(args.arch)
        if not args.full:
            cfg = cfg.reduced()
        params = tf.init_params(key, cfg)
        lora_tree = jax.tree.map(jax.numpy.asarray,
                                 load_pytree(args.lora)["lora"])
        params = merge_lora(params, client_mean(lora_tree), cfg)
        print(f"merged consensus LoRA from {args.lora}")
        serving = ServingSession(args.arch, reduced=not args.full,
                                 params=params, n_slots=args.batch,
                                 max_len=args.prompt_len + args.gen + 8,
                                 init_seed=args.seed)
    else:
        if args.lora:
            pool = AdapterPool.from_checkpoint(args.lora)
            print(f"serving adapters from {args.lora}: {pool.ids}")
        serving = ServingSession(args.arch, reduced=not args.full,
                                 adapters=pool, n_slots=args.batch,
                                 max_len=args.prompt_len + args.gen + 8,
                                 init_seed=args.seed)
    cfg = serving.model_cfg

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        serving.engine.set_frontend(frontend)

    # round-robin over the trained adapters + consensus ("base" excluded —
    # it is the reserved zero row, not one of the run's models)
    names = ([n for n in serving.adapters if n != "base"]
             if (args.lora and not args.merge) else [None])
    if args.adapter:
        names = [args.adapter]
    rids = [serving.submit(prompts[i], adapter=names[i % len(names)],
                           max_new=args.gen)
            for i in range(args.batch)]

    t0 = time.time()
    serving.run()
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"decoded {args.gen} tokens x{args.batch} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. prefill-by-decode, "
          f"{serving.compile_count} compile)")
    for rid in rids[:2]:
        req = serving.engine.requests[rid]
        tag = req.adapter if req.adapter is not None else "base"
        print(f"sample [{tag}]:", serving.result(rid)[:12])


if __name__ == "__main__":
    main()
