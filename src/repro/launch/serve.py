"""Serving driver: prefill a batch of prompts, then decode with the KV
cache — optionally with a merged LoRA checkpoint from train.py.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --batch 4 --prompt-len 32 --gen 16 [--lora ckpt.npz]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_pytree
from repro.configs import get_config
from repro.core.lora import client_mean, merge_lora
from repro.models import transformer as tf


def prefill_and_cache(params, cfg, tokens, frontend=None):
    """Forward over the prompt, then build the decode cache by replaying
    tokens through decode_step (small-scale path; production prefill fills
    the cache from the forward pass activations)."""
    B, S = tokens.shape
    cache = tf.init_cache(cfg, B, max(2 * S, 64))
    if frontend is not None:
        cache = _fill_cross(params, cfg, cache, frontend)
    logits = None
    for t in range(S):
        logits, cache = tf.decode_step(params, cfg, tokens[:, t:t + 1], cache)
    return logits, cache


def _fill_cross(params, cfg, cache, frontend):
    from repro.models.transformer import _encoder_forward
    mem = (_encoder_forward(params, cfg, frontend, None)
           if cfg.family == "encdec" else frontend)
    B = frontend.shape[0]

    def fill(attn_p):
        k = (mem @ attn_p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
        v = (mem @ attn_p["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
        return {"ck": k, "cv": v}

    for j, spec in enumerate(cfg.pattern):
        gp = params["groups"][j]
        target = gp.get("cross") or (gp["attn"] if spec.kind == "cross"
                                     else None)
        if target is None:
            continue
        for g in range(cfg.n_groups):
            pg = jax.tree.map(lambda x: x[g], target)
            cc = fill(pg)
            cache["groups"][j]["cross"] = jax.tree.map(
                lambda buf, new, g=g: buf.at[g].set(new),
                cache["groups"][j]["cross"], cc)
    return cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--lora", default="", help="LoRA checkpoint to merge")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    params = tf.init_params(key, cfg)

    if args.lora:
        tree = load_pytree(args.lora)["lora"]
        lora_tree = jax.tree.map(jnp.asarray, tree)
        consensus = client_mean(lora_tree)
        params = merge_lora(params, consensus, cfg)
        print(f"merged consensus LoRA from {args.lora}")

    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02

    t0 = time.time()
    logits, cache = prefill_and_cache(params, cfg, tokens, frontend)
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{time.time() - t0:.2f}s")

    decode = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, t, c))
    cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    out = [cur]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, cache, cur)
        cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        out.append(cur)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.gen} tokens x{args.batch} in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
