"""Batched serving runtime with slot management (continuous batching).

A fixed pool of ``n_slots`` decode slots shares ONE compiled decode_step.
Every engine tick advances every active slot by exactly one token:
slots still consuming their prompt are teacher-forced (prefill-by-decode,
the default small-scale path; ``prefill_chunk`` switches long prompts to
the serving core's chunked prefill — `repro.serving.prefill`), slots past
it consume their previously generated token. Finished sequences (EOS /
max_new) free their slot immediately and the next queued request is
admitted on the following tick — no batch-wide barrier, which is the
continuous-batching property. Ticks with no active slot skip the device
entirely (``device_steps`` counts real compiled-step invocations).

Admission policy lives in `repro.serving.scheduler`: per-adapter queues
under deficit-round-robin with optional per-tenant quotas; the engine's
``queue``/``requests`` attributes are views onto it (one queue + no
quotas degenerates to the old FIFO behavior exactly). Request lifecycle
metrics (queue wait, TTFT, latency, preemptions) come out of
``engine.metrics()``.

KV storage has two modes:

- contiguous (default): per-slot rolling caches sized max_len — simple,
  but ``n_slots x max_len`` is a compile-time memory wall.
- ``paged=True``: GLOBAL attention layers keep their K/V in a shared
  physical page pool (`repro.serving.paging`); each slot holds a block
  table mapping logical pages to pool pages, shipped to the device as
  data each tick. Windowed layers keep rolling caches (already O(window)).
  When the pool runs dry the engine preempts the latest-admitted slot
  (pages freed, request requeued at the front; on re-admission its
  prompt + already-generated tokens are teacher-forced back in, which
  reproduces the exact cache state, so the continuation is unchanged).

Per-slot position counters in the KV cache ("t": (B,), models/attention)
make admission a pure cache-row reset: positions restart at 0 for the new
request and the per-row validity mask hides the previous occupant's stale
entries. No reallocation, no recompilation, ever.

Multi-adapter serving: pass ``adapters`` (an object with ``row(name)`` and
``serving_lora(slot_rows)`` — repro.api.serving.AdapterPool) and each
request may name the TAD-LoRA adapter it wants. The engine keeps a per-slot
adapter-row map and hands decode_step a lora tree whose leaves carry the
whole stacked pool plus the (B,) slot map; adapter selection is DATA
(per-row gather in kernels.ops.slot_lora_matmul), so heterogeneous
adapters, hot-swapped weights, and retargeted slots all reuse the one
compiled step. ``compile_count`` counts traces and must stay at 1 for the
engine's lifetime (asserted by tests/test_serving.py and
benchmarks/serving.py).

(The decode_32k / long_500k dry-run shapes are exactly one engine tick at
production scale.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serving.paging import BlockTables, PagePool
from repro.serving.prefill import ChunkedPrefill
from repro.serving.scheduler import (Request, Scheduler,  # noqa: F401
                                     TenantQuota)

__all__ = ["Request", "ServeEngine", "TenantQuota"]


@dataclass
class _Slot:
    req: Optional[Request] = None
    seed: Optional[np.ndarray] = None    # prompt (+ replayed tokens_out)
    fed: int = 0                         # seed tokens consumed so far
    pos: int = 0                         # next cache position to be written


class _QueueView:
    """The engine's pre-scheduler ``queue`` deque, as a facade over the
    scheduler's per-adapter queues (append/extend submit; len/bool/iter
    aggregate). Keeps direct-queue tests and callers working unchanged."""

    def __init__(self, engine: "ServeEngine"):
        self._engine = engine

    def append(self, req: Request) -> None:
        self._engine.scheduler.submit(req, tick=self._engine.ticks)

    def extend(self, reqs) -> None:
        for r in reqs:
            self.append(r)

    def __len__(self) -> int:
        return self._engine.scheduler.n_queued

    def __bool__(self) -> bool:
        return self._engine.scheduler.n_queued > 0

    def __iter__(self):
        return iter(self._engine.scheduler.queued_requests())

    def __getitem__(self, i):
        return self._engine.scheduler.queued_requests()[i]


class ServeEngine:
    """Continuous-batching decode engine over one compiled decode_step.

    ``params`` is the base model; with ``adapters`` set, decode additionally
    applies a per-slot TAD-LoRA adapter chosen at admission from
    ``Request.adapter``. Completed requests stay reachable via
    ``engine.requests[rid]`` after their slot is freed.

    Serving-core knobs: ``paged``/``page_size``/``n_pages`` switch global
    attention layers to page-pool KV (n_pages defaults to exactly enough
    for every slot at max_len, i.e. no contention; size it smaller to
    exercise preemption), ``prefill_chunk`` enables chunked prefill for
    prompts longer than one chunk, ``quotas`` maps adapter refs to
    `TenantQuota` limits, and ``scheduler`` swaps the whole policy.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 256, adapters=None, paged: bool = False,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefill_chunk: int = 0,
                 quotas: Optional[Dict] = None,
                 scheduler: Optional[Scheduler] = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.adapters = adapters
        self.paged = bool(paged)
        self.scheduler = scheduler if scheduler is not None \
            else Scheduler(quotas=quotas)
        if self.paged:
            self.page_size = int(page_size)
            # round the horizon up to whole pages: L = P * page_size is
            # what the gathered paged view sees, so it must cover max_len
            self.max_len = -(-max_len // self.page_size) * self.page_size
            self.pages_per_seq = self.max_len // self.page_size
            if n_pages is None:
                n_pages = 1 + n_slots * self.pages_per_seq
            self.page_pool = PagePool(n_pages)
            self.tables = BlockTables(n_slots, self.pages_per_seq)
            self.cache = tf.init_cache(cfg, n_slots, self.max_len,
                                       paging=(n_pages, self.page_size))
        else:
            self.page_size = 0
            self.max_len = max_len
            self.page_pool = None
            self.tables = None
            self.cache = tf.init_cache(cfg, n_slots, max_len)
        self.prefill = (ChunkedPrefill(params, cfg, prefill_chunk)
                        if prefill_chunk else None)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue = _QueueView(self)
        self.next_in = np.zeros((n_slots, 1), np.int32)
        # adapter row per slot; row 0 is the pool's base (zero) adapter
        self.slot_rows = np.zeros((n_slots,), np.int32)
        self.compile_count = 0           # traces of decode_step (== compiles)
        if adapters is None:
            def _step(p, c, t):
                self.compile_count += 1
                return tf.decode_step(p, cfg, t, c)
        else:
            def _step(p, c, t, lo):
                self.compile_count += 1
                return tf.decode_step(p, cfg, t, c, lora=lo)
        self._decode = jax.jit(_step)
        self._next_rid = 0
        self.ticks = 0
        self.device_steps = 0            # compiled-step invocations (idle
        #                                  ticks never reach the device)

    @property
    def requests(self) -> Dict[int, Request]:
        """rid -> Request registry (owned by the scheduler)."""
        return self.scheduler.requests

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32, eos_id: Optional[int] = None,
               adapter: Union[str, int, None] = None) -> int:
        """Queue a request; returns its rid (see ``engine.requests``).
        Raises `QuotaExceeded` past the adapter's ``max_queued`` and
        ValueError when a paged request could never fit the pool."""
        if adapter is not None and self.adapters is None:
            raise ValueError("engine built without an AdapterPool cannot "
                             "serve per-request adapters")
        if self.adapters is not None:
            self.adapters.row(adapter)   # unknown names fail HERE, not
            #                              mid-admission with a slot held
        prompt = np.asarray(prompt, np.int32)
        if self.paged:
            total = len(prompt) + max_new
            if total > self.max_len:
                raise ValueError(f"prompt+max_new = {total} exceeds the "
                                 f"paged horizon {self.max_len}")
            need = -(-total // self.page_size)
            if need > self.page_pool.capacity:
                # guarantees any single admitted request can always run to
                # completion (eviction has everyone else to evict but never
                # needs to evict the sole survivor)
                raise ValueError(
                    f"request needs {need} pages but the pool holds "
                    f"{self.page_pool.capacity}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, adapter=adapter)
        self.scheduler.submit(req, tick=self.ticks)
        return rid

    def set_frontend(self, frontend) -> None:
        """Fill the cross-attention KV caches from frontend embeddings
        (enc-dec / VLM archs), shared by every slot. Slot admission resets
        only positions and recurrent rows, so the cross KV survives
        request turnover; call again to change the context."""
        cfg = self.cfg
        mem = (tf._encoder_forward(self.params, cfg, frontend, None)
               if cfg.family == "encdec" else frontend)
        B = frontend.shape[0]

        def fill(attn_p):
            k = (mem @ attn_p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
            v = (mem @ attn_p["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
            return {"ck": k, "cv": v}

        for j, spec in enumerate(cfg.pattern):
            gp = self.params["groups"][j]
            target = gp.get("cross") or (gp["attn"] if spec.kind == "cross"
                                         else None)
            if target is None:
                continue
            for g in range(cfg.n_groups):
                pg = jax.tree.map(lambda x: x[g], target)
                cc = fill(pg)
                self.cache["groups"][j]["cross"] = jax.tree.map(
                    lambda buf, new, g=g: buf.at[g].set(new),
                    self.cache["groups"][j]["cross"], cc)

    # ------------------------------------------------------------------
    # Admission (scheduler pick -> page grant -> cache-row reset -> prefill)
    # ------------------------------------------------------------------
    def _active_counts(self) -> Dict:
        counts: Dict = {}
        for s in self.slots:
            if s.req is not None:
                counts[s.req.adapter] = counts.get(s.req.adapter, 0) + 1
        return counts

    def _reset_slot_cache(self, slots: list) -> None:
        """Zero the slots' position counters across every layer cache and
        recurrent state — admission is a per-row reset, nothing else.
        Takes ALL slots admitted this tick at once: one tree pass total
        instead of rebuilding the whole cache pytree per admitted slot."""
        rows = np.asarray(slots)

        def reset(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "t":
                return leaf.at[..., rows].set(0)
            if name in ("h", "c", "n", "m", "C", "conv"):
                # recurrent states: zero the slots' rows (axis after groups)
                axis = 1 if leaf.ndim >= 2 and any(
                    getattr(k, "key", None) == "groups" for k in path) else 0
                idx = [slice(None)] * leaf.ndim
                idx[axis] = rows
                return leaf.at[tuple(idx)].set(0)
            return leaf
        self.cache = jax.tree_util.tree_map_with_path(reset, self.cache)

    def _chunk_lora(self, row: int):
        return (None if self.adapters is None
                else self.adapters.serving_lora(np.asarray([row], np.int32)))

    def _push_table(self) -> None:
        self.cache["pages"]["table"] = jnp.asarray(self.tables.table)

    def _admit(self) -> None:
        placed: list = []                # (slot, req, row, seed, chunked)
        free = [i for i, s in enumerate(self.slots) if s.req is None]
        for i in free:
            req = self.scheduler.next_request(self._active_counts())
            if req is None:
                break
            # resolve the adapter BEFORE touching any engine state so a
            # bad name (possible via direct queue.append) cannot leave
            # a half-admitted slot behind
            row = (self.adapters.row(req.adapter)
                   if self.adapters is not None else 0)
            seed = np.asarray(req.prompt, np.int32)
            if req.tokens_out:
                # re-admission after preemption: teacher-force the already
                # generated tokens back in — bitwise the same cache state,
                # so the continuation is exactly what it would have been
                seed = np.concatenate(
                    [seed, np.asarray(req.tokens_out, np.int32)])
            chunked = self.prefill is not None and len(seed) > 1
            if self.paged:
                n_pre = len(seed) - 1 if chunked else 0
                # pages covering prefill positions + the next decode write
                if not self.tables.grow(i, n_pre // self.page_size,
                                        self.page_pool):
                    # admission never preempts running slots; try again
                    # next tick when completions return pages
                    self.scheduler.push_front(req)
                    break
            s = _Slot(req=req, seed=seed)
            self.slots[i] = s
            self.slot_rows[i] = row
            self.scheduler.mark_admitted(req, self.ticks)
            placed.append((i, s, row, chunked))
        if not placed:
            return
        self._reset_slot_cache([i for i, *_ in placed])
        if self.paged:
            self._push_table()           # chunk prefill reads the table
        for i, s, row, chunked in placed:
            if chunked:
                n_pre = len(s.seed) - 1
                self.cache = self.prefill.run(self.cache, s.seed, i,
                                              lora=self._chunk_lora(row))
                s.fed = len(s.seed)
                s.pos = n_pre
                self.next_in[i, 0] = s.seed[-1]
            else:
                s.fed = 1
                s.pos = 0
                self.next_in[i, 0] = s.seed[0]

    # ------------------------------------------------------------------
    # Page upkeep (decode growth + preemption-by-eviction)
    # ------------------------------------------------------------------
    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Latest-admitted active slot other than ``exclude`` — LIFO
        preemption keeps the oldest streams flowing."""
        best, best_tick = None, -1
        for j, s in enumerate(self.slots):
            if j == exclude or s.req is None:
                continue
            at = s.req.admit_tick if s.req.admit_tick is not None else 0
            if at >= best_tick:
                best, best_tick = j, at
        return best

    def _evict(self, victim: int) -> None:
        req = self.slots[victim].req
        self.tables.release(victim, self.page_pool)
        self.slots[victim] = _Slot()
        self.scheduler.requeue_front(req)

    def _ensure_decode_pages(self) -> None:
        """Every active slot writes cache position ``pos`` this tick —
        make sure its page exists, evicting latest-admitted slots when the
        pool is dry (submit-time capacity checks guarantee the last slot
        standing always fits)."""
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            while not self.tables.grow(i, s.pos // self.page_size,
                                       self.page_pool):
                victim = self._pick_victim(exclude=i)
                if victim is None:
                    raise RuntimeError(
                        "page pool exhausted with no evictable slot — "
                        "submit-time capacity checks should prevent this")
                self._evict(victim)

    def _free_slot(self, i: int) -> None:
        if self.paged:
            self.tables.release(i, self.page_pool)
        self.slots[i] = _Slot()

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One engine step. Returns number of active slots. Idle ticks
        (nothing queued or running) return 0 without touching the device."""
        self._admit()
        if self.paged:
            self._ensure_decode_pages()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            self.ticks += 1          # the clock advances; the device idles
            return 0
        if self.paged:
            self._push_table()
        tokens = jnp.asarray(self.next_in)
        if self.adapters is not None:
            # the pool tree is re-read every tick, so pool.update()/sync
            # between ticks hot-swaps weights with no engine involvement
            lora = self.adapters.serving_lora(self.slot_rows)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, lora)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens)
        self.device_steps += 1
        logits_np = np.asarray(logits[:, -1, :self.cfg.vocab_size])
        for i in active:
            s = self.slots[i]
            req = s.req
            s.pos += 1
            if s.fed < len(s.seed):
                # still prefilling: teacher-force the next prompt token
                self.next_in[i, 0] = s.seed[s.fed]
                s.fed += 1
                continue
            nxt = int(logits_np[i].argmax())
            req.tokens_out.append(nxt)
            self.scheduler.mark_first_token(req, self.ticks)
            self.next_in[i, 0] = nxt
            if (req.eos_id is not None and nxt == req.eos_id) or \
                    len(req.tokens_out) >= req.max_new:
                req.done = True
                self.scheduler.mark_done(req, self.ticks)
                self._free_slot(i)               # freed immediately
        self.ticks += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> None:
        """Tick until the queue and every slot drain. Returns immediately
        on an idle engine — no device steps are spent."""
        for _ in range(max_ticks):
            if not self.scheduler.n_queued and \
                    all(s.req is None for s in self.slots):
                return
            self.tick()
        raise RuntimeError("serve engine did not drain")

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Scheduler lifecycle aggregates + engine counters."""
        out = self.scheduler.summary()
        out["ticks"] = self.ticks
        out["device_steps"] = self.device_steps
        if self.paged:
            out["pages_used"] = self.page_pool.n_used
            out["pages_free"] = self.page_pool.n_free
        return out
