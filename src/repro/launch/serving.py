"""Batched serving runtime with slot management (continuous batching).

A fixed pool of ``n_slots`` decode slots shares ONE compiled decode_step.
Every engine tick advances every active slot by exactly one token:
slots still consuming their prompt are teacher-forced (prefill-by-decode),
slots past it consume their previously generated token. Finished sequences
(EOS / max_new) free their slot immediately and the next queued request is
admitted on the following tick — no batch-wide barrier, which is the
continuous-batching property.

Per-slot position counters in the KV cache ("t": (B,), models/attention)
make admission a pure cache-row reset: positions restart at 0 for the new
request and the per-row validity mask hides the previous occupant's stale
entries. No reallocation, no recompilation, ever.

(The decode_32k / long_500k dry-run shapes are exactly one engine tick at
production scale.)
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    tokens_out: list = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    fed: int = 0                         # prompt tokens consumed so far


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = tf.init_cache(cfg, n_slots, max_len)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: collections.deque[Request] = collections.deque()
        self.next_in = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, t, c))
        self._next_rid = 0
        self.ticks = 0

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new=max_new, eos_id=eos_id))
        return rid

    def _reset_slot_cache(self, slots: list[int]) -> None:
        """Zero the slots' position counters across every layer cache and
        recurrent state — admission is a per-row reset, nothing else.
        Takes ALL slots admitted this tick at once: one tree pass total
        instead of rebuilding the whole cache pytree per admitted slot."""
        rows = np.asarray(slots)

        def reset(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "t":
                return leaf.at[..., rows].set(0)
            if name in ("h", "c", "n", "m", "C", "conv"):
                # recurrent states: zero the slots' rows (axis after groups)
                axis = 1 if leaf.ndim >= 2 and any(
                    getattr(k, "key", None) == "groups" for k in path) else 0
                idx = [slice(None)] * leaf.ndim
                idx[axis] = rows
                return leaf.at[tuple(idx)].set(0)
            return leaf
        self.cache = jax.tree_util.tree_map_with_path(reset, self.cache)

    def _admit(self) -> None:
        admitted: list[int] = []
        for i, s in enumerate(self.slots):
            if s.req is None and self.queue:
                req = self.queue.popleft()
                s.req = req
                s.fed = 1
                self.next_in[i, 0] = req.prompt[0]
                admitted.append(i)
        if admitted:
            self._reset_slot_cache(admitted)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One engine step. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self.next_in))
        logits_np = np.asarray(logits[:, -1, :self.cfg.vocab_size])
        for i in active:
            s = self.slots[i]
            req = s.req
            if s.fed < len(req.prompt):
                # still prefilling: teacher-force the next prompt token
                self.next_in[i, 0] = req.prompt[s.fed]
                s.fed += 1
                continue
            nxt = int(logits_np[i].argmax())
            req.tokens_out.append(nxt)
            self.next_in[i, 0] = nxt
            if (req.eos_id is not None and nxt == req.eos_id) or \
                    len(req.tokens_out) >= req.max_new:
                req.done = True
                self.slots[i] = _Slot()          # freed immediately
        self.ticks += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            self.tick()
            if not self.queue and all(s.req is None for s in self.slots):
                return
        raise RuntimeError("serve engine did not drain")
