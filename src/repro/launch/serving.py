"""Batched serving runtime with slot management (continuous batching).

A fixed pool of ``n_slots`` decode slots shares ONE compiled decode_step.
Every engine tick advances every active slot by exactly one token:
slots still consuming their prompt are teacher-forced (prefill-by-decode,
the small-scale path — production prefill fills the cache from forward-pass
activations and joins here for the decode phase), slots past it consume
their previously generated token. Finished sequences (EOS / max_new) free
their slot immediately and the next queued request is admitted on the
following tick — no batch-wide barrier, which is the continuous-batching
property.

Per-slot position counters in the KV cache ("t": (B,), models/attention)
make admission a pure cache-row reset: positions restart at 0 for the new
request and the per-row validity mask hides the previous occupant's stale
entries. No reallocation, no recompilation, ever.

Multi-adapter serving: pass ``adapters`` (an object with ``row(name)`` and
``serving_lora(slot_rows)`` — repro.api.serving.AdapterPool) and each
request may name the TAD-LoRA adapter it wants. The engine keeps a per-slot
adapter-row map and hands decode_step a lora tree whose leaves carry the
whole stacked pool plus the (B,) slot map; adapter selection is DATA
(per-row gather in kernels.ops.slot_lora_matmul), so heterogeneous
adapters, hot-swapped weights, and retargeted slots all reuse the one
compiled step. ``compile_count`` counts traces and must stay at 1 for the
engine's lifetime (asserted by tests/test_serving.py and
benchmarks/serving.py).

(The decode_32k / long_500k dry-run shapes are exactly one engine tick at
production scale.)
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclass
class Request:
    """One generation request: prompt tokens, generation budget, and the
    (optional) name of the pool adapter that should serve it."""
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    adapter: Union[str, int, None] = None   # pool row / name; None = base
    tokens_out: list = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    fed: int = 0                         # prompt tokens consumed so far


class ServeEngine:
    """Continuous-batching decode engine over one compiled decode_step.

    ``params`` is the base model; with ``adapters`` set, decode additionally
    applies a per-slot TAD-LoRA adapter chosen at admission from
    ``Request.adapter``. Completed requests stay reachable via
    ``engine.requests[rid]`` after their slot is freed.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 256, adapters=None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.adapters = adapters
        self.cache = tf.init_cache(cfg, n_slots, max_len)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: collections.deque[Request] = collections.deque()
        self.requests: dict[int, Request] = {}
        self.next_in = np.zeros((n_slots, 1), np.int32)
        # adapter row per slot; row 0 is the pool's base (zero) adapter
        self.slot_rows = np.zeros((n_slots,), np.int32)
        self.compile_count = 0           # traces of decode_step (== compiles)
        if adapters is None:
            def _step(p, c, t):
                self.compile_count += 1
                return tf.decode_step(p, cfg, t, c)
        else:
            def _step(p, c, t, lo):
                self.compile_count += 1
                return tf.decode_step(p, cfg, t, c, lora=lo)
        self._decode = jax.jit(_step)
        self._next_rid = 0
        self.ticks = 0

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32, eos_id: Optional[int] = None,
               adapter: Union[str, int, None] = None) -> int:
        """Queue a request; returns its rid (see ``engine.requests``)."""
        if adapter is not None and self.adapters is None:
            raise ValueError("engine built without an AdapterPool cannot "
                             "serve per-request adapters")
        if self.adapters is not None:
            self.adapters.row(adapter)   # unknown names fail HERE, not
            #                              mid-admission with a slot held
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, eos_id=eos_id, adapter=adapter)
        self.queue.append(req)
        self.requests[rid] = req
        return rid

    def set_frontend(self, frontend) -> None:
        """Fill the cross-attention KV caches from frontend embeddings
        (enc-dec / VLM archs), shared by every slot. Slot admission resets
        only positions and recurrent rows, so the cross KV survives
        request turnover; call again to change the context."""
        cfg = self.cfg
        mem = (tf._encoder_forward(self.params, cfg, frontend, None)
               if cfg.family == "encdec" else frontend)
        B = frontend.shape[0]

        def fill(attn_p):
            k = (mem @ attn_p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
            v = (mem @ attn_p["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.hd)
            return {"ck": k, "cv": v}

        for j, spec in enumerate(cfg.pattern):
            gp = self.params["groups"][j]
            target = gp.get("cross") or (gp["attn"] if spec.kind == "cross"
                                         else None)
            if target is None:
                continue
            for g in range(cfg.n_groups):
                pg = jax.tree.map(lambda x: x[g], target)
                cc = fill(pg)
                self.cache["groups"][j]["cross"] = jax.tree.map(
                    lambda buf, new, g=g: buf.at[g].set(new),
                    self.cache["groups"][j]["cross"], cc)

    def _reset_slot_cache(self, slots: list[int]) -> None:
        """Zero the slots' position counters across every layer cache and
        recurrent state — admission is a per-row reset, nothing else.
        Takes ALL slots admitted this tick at once: one tree pass total
        instead of rebuilding the whole cache pytree per admitted slot."""
        rows = np.asarray(slots)

        def reset(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "t":
                return leaf.at[..., rows].set(0)
            if name in ("h", "c", "n", "m", "C", "conv"):
                # recurrent states: zero the slots' rows (axis after groups)
                axis = 1 if leaf.ndim >= 2 and any(
                    getattr(k, "key", None) == "groups" for k in path) else 0
                idx = [slice(None)] * leaf.ndim
                idx[axis] = rows
                return leaf.at[tuple(idx)].set(0)
            return leaf
        self.cache = jax.tree_util.tree_map_with_path(reset, self.cache)

    def _admit(self) -> None:
        admitted: list[int] = []
        for i, s in enumerate(self.slots):
            if s.req is None and self.queue:
                req = self.queue[0]
                # resolve the adapter BEFORE touching any engine state so a
                # bad name (possible via direct queue.append) cannot leave
                # a half-admitted slot behind
                row = (self.adapters.row(req.adapter)
                       if self.adapters is not None else 0)
                self.queue.popleft()
                s.req = req
                s.fed = 1
                self.next_in[i, 0] = req.prompt[0]
                self.slot_rows[i] = row
                admitted.append(i)
        if admitted:
            self._reset_slot_cache(admitted)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One engine step. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.next_in)
        if self.adapters is not None:
            # the pool tree is re-read every tick, so pool.update()/sync
            # between ticks hot-swaps weights with no engine involvement
            lora = self.adapters.serving_lora(self.slot_rows)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, lora)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens)
        logits_np = np.asarray(logits[:, -1, :self.cfg.vocab_size])
        for i in active:
            s = self.slots[i]
            req = s.req
            if s.fed < len(req.prompt):
                # still prefilling: teacher-force the next prompt token
                self.next_in[i, 0] = req.prompt[s.fed]
                s.fed += 1
                continue
            nxt = int(logits_np[i].argmax())
            req.tokens_out.append(nxt)
            self.next_in[i, 0] = nxt
            if (req.eos_id is not None and nxt == req.eos_id) or \
                    len(req.tokens_out) >= req.max_new:
                req.done = True
                self.slots[i] = _Slot()          # freed immediately
        self.ticks += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> None:
        """Tick until the queue and every slot drain."""
        for _ in range(max_ticks):
            self.tick()
            if not self.queue and all(s.req is None for s in self.slots):
                return
        raise RuntimeError("serve engine did not drain")
