"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — clients/FSDP on
"data", tensor parallel on "model". Multi-pod: (2, 16, 16) = 512 chips with
a leading "pod" axis that extends the client axis across the DCN boundary
(gossip between pods = the paper's inter-site links).

Functions, not module constants — importing this module never touches jax
device state. Meshes are built from a prefix of jax.devices() so a 512-way
forced host platform can carve both meshes.
"""
from __future__ import annotations

import math

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)}. "
            f"Set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"BEFORE importing jax (launch/dryrun.py does this).")
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for tests (requires forced device count >= prod(shape))."""
    n = math.prod(shape)
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


def client_count(mesh: Mesh) -> int:
    """Simulated DFL clients = product of client axes (pod × data)."""
    m = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        m *= mesh.shape["pod"]
    return m
