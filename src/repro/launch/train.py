"""End-to-end DFL LoRA fine-tuning driver.

Runs the paper's Algorithm 1 against any assigned architecture (reduced or
full) on whatever devices exist. On CPU this trains a reduced config for
real (examples/dfl_finetune.py uses it); on a pod, pass --full to train the
full config across the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
      --method tad --rounds 40 --interval 3 --p 0.1 --topology complete
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import SHAPES, get_config
from repro.core import (build_lora_tree, consensus_stats, make_dfl_round,
                        make_topology, optimal_switching_interval,
                        round_masks)
from repro.data.synthetic import lm_token_stream
from repro.dist import sharding as shd
from repro.models import transformer as tf
from repro.optim import AdamW


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--method", default="tad",
                    choices=("lora", "ffa", "rolora", "tad"))
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--interval", type=int, default=0,
                    help="switching interval T; 0 = topology-aware T*(rho)")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--p", type=float, default=0.2,
                    help="edge activation probability")
    ap.add_argument("--topology", default="complete",
                    choices=("complete", "ring", "erdos_renyi"))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) architecture config")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    m = args.clients

    topo = make_topology(args.topology, m, args.p, seed=args.seed)
    rho = topo.rho_estimate(100)
    T = args.interval or optimal_switching_interval(rho)
    print(f"arch={cfg.name} method={args.method} m={m} p={args.p} "
          f"rho≈{rho:.4f} T={T}{' (T*-selected)' if not args.interval else ''}")

    key = jax.random.key(args.seed)
    base = tf.init_params(key, cfg)
    lora = build_lora_tree(jax.random.key(args.seed + 1), base, cfg,
                           n_clients=m)
    opt = AdamW(lr=args.lr)
    opt_state = opt.init(lora)

    def loss_fn(bp, lo, micro):
        return tf.lm_loss(bp, cfg, micro["tokens"], micro["targets"],
                          frontend=micro.get("frontend"), lora=lo)[0]

    # donate=True: the loop rebinds lora/opt_state every round, so the
    # round updates them in place (no per-round copy of the client state)
    round_fn = make_dfl_round(loss_fn, opt, local_steps=args.local_steps,
                              donate=True)

    stream = lm_token_stream(cfg.vocab_size, args.batch * args.local_steps,
                             args.seq, n_clients=m, seed=args.seed)
    history = []
    t_start = time.time()
    for t in range(args.rounds):
        raw = next(stream)
        batch = {
            k: jnp.asarray(v.reshape(m, args.local_steps, args.batch,
                                     args.seq).swapaxes(0, 1))
            for k, v in raw.items()
        }
        if cfg.n_frontend_tokens:
            batch["frontend"] = jnp.zeros(
                (args.local_steps, m, args.batch, cfg.n_frontend_tokens,
                 cfg.d_model), jnp.float32)
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks(args.method, t, T).as_array()
        lora, opt_state, metrics = round_fn(base, lora, opt_state, batch,
                                            W, masks)
        if t % 5 == 0 or t == args.rounds - 1:
            stats = consensus_stats(lora)
            rec = {"round": t, "loss": float(metrics["loss"]),
                   "cross_norm": float(stats["cross_norm"]),
                   "delta_a_sq": float(stats["delta_a_sq"]),
                   "delta_b_sq": float(stats["delta_b_sq"])}
            history.append(rec)
            print(f"  round {t:4d} loss={rec['loss']:.4f} "
                  f"cross={rec['cross_norm']:.3e}")
    wall = time.time() - t_start
    print(f"trained {args.rounds} rounds in {wall:.1f}s "
          f"({wall / args.rounds:.2f}s/round)")

    if args.ckpt:
        save_pytree(args.ckpt, {"lora": lora})
        print(f"saved LoRA checkpoint -> {args.ckpt}")
    if args.log:
        os.makedirs(os.path.dirname(os.path.abspath(args.log)), exist_ok=True)
        with open(args.log, "w") as f:
            json.dump({"config": vars(args), "rho": rho, "T": T,
                       "history": history}, f, indent=1)


if __name__ == "__main__":
    main()
