"""End-to-end DFL LoRA fine-tuning driver.

Arg-parsing + `repro.api.Session`: builds a `DFLConfig` from the CLI,
runs the paper's Algorithm 1 against any assigned architecture (reduced
or full) on whatever devices exist. On CPU this trains a reduced config
for real; on a pod, pass --full to train the full config across the
production mesh (the Session's round is mesh-aware via repro.dist).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
      --method tad --rounds 40 --interval 3 --p 0.1 --topology complete
"""
from __future__ import annotations

import argparse
import json
import os

from repro.api import ConsoleLogger, DFLConfig, HistoryRecorder, Session


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--method", default="tad",
                    choices=("lora", "ffa", "rolora", "tad"))
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--interval", type=int, default=0,
                    help="switching interval T; 0 = topology-aware T*(rho)")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--p", type=float, default=0.2,
                    help="edge activation probability")
    ap.add_argument("--topology", default="complete",
                    choices=("complete", "ring", "erdos_renyi"))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--adaptive-t", action="store_true",
                    help="online T via the control plane's spectral "
                         "estimator (ControlConfig t_policy='adaptive')")
    ap.add_argument("--mix-flat-lowering", default="auto",
                    choices=("auto", "flat", "per_segment"))
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) architecture config")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    config = DFLConfig(
        model=args.arch, task="lm", reduced=not args.full,
        n_clients=args.clients, topology=args.topology, p=args.p,
        method=args.method, T=args.interval,
        control={"t_policy": "adaptive"} if args.adaptive_t else None,
        rounds=args.rounds, local_steps=args.local_steps,
        batch_size=args.batch, seq_len=args.seq, lr=args.lr,
        mix_flat_lowering=args.mix_flat_lowering, seed=args.seed,
        # the Session loop rebinds lora/opt_state every round, so the
        # round updates them in place (no per-round copy of client state)
        donate=True,
    )
    history = HistoryRecorder(every=5, consensus=True)
    # consensus on the console too: the RoundEvent memoizes the stats, so
    # the two callbacks share one computation per due round
    console = ConsoleLogger(every=5, consensus=True)
    session = Session(config, callbacks=[history, console])

    if args.adaptive_t:
        t_desc = f"T=adaptive (from T*={session.T})"
    else:
        t_desc = f"T={session.T}{'' if args.interval else ' (T*-selected)'}"
    print(f"arch={session.model_cfg.name} method={args.method} "
          f"m={args.clients} p={args.p} rho≈{session.rho:.4f} {t_desc}")

    result = session.run()
    print(f"trained {result.rounds} rounds in {result.wall_s:.1f}s "
          f"({result.wall_s / result.rounds:.2f}s/round)")

    if args.ckpt:
        session.save(args.ckpt)
        print(f"saved LoRA checkpoint -> {args.ckpt}")
    if args.log:
        os.makedirs(os.path.dirname(os.path.abspath(args.log)), exist_ok=True)
        with open(args.log, "w") as f:
            # result.T is the interval in force at run end (moves under
            # --adaptive-t); T_initial is the pre-run static selection
            json.dump({"config": vars(args), "dfl_config": config.to_dict(),
                       "rho": session.rho, "T": result.T,
                       "T_initial": session.T,
                       "history": history.history}, f, indent=1)


if __name__ == "__main__":
    main()
