"""Step functions + ShapeDtypeStruct input specs per (arch × shape).

Everything a dry-run / real launcher needs:
  train_4k    -> the DFL round (paper's technique): per-client local LoRA
                 AdamW steps + joint gossip mixing; clients sharded over the
                 mesh client axes.
  prefill_32k -> serving prefill (forward, last-position logits).
  decode_*    -> one-token serve_step against a seq_len KV cache.

``input_specs`` (spec'd in the task) returns weak-type-correct,
sharding-annotated ShapeDtypeStructs — no device allocation ever happens for
the full configs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api.rounds import build_round
from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.core.lora import lora_specs as lora_spec_tree
from repro.dist import sharding as shd
from repro.launch.mesh import client_count
from repro.models import transformer as tf
from repro.optim.adamw import AdamW, AdamWState


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def _ns(mesh: Mesh, shape, names, axis_map) -> NamedSharding:
    """NamedSharding from logical dim names with divisibility checks."""
    return NamedSharding(mesh, shd.spec_for(shape, names, mesh, axis_map))


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(spec_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), spec_tree, sharding_tree)


def lora_shardings(lora_tree, mesh: Mesh, axis_map):
    """Client axis at -3 over "clients"; matrix dims REPLICATED. LoRA
    factors are tiny (d×r); sharding them over "model" gave GSPMD an
    incentive to re-layout full activations instead (measured 19 GB f32
    all-gathers in the gemma3 dry-run — EXPERIMENTS.md §Perf)."""
    def one(leaf):
        names = [None] * leaf.ndim
        names[-3] = "clients"
        return _ns(mesh, leaf.shape, names, axis_map)
    return jax.tree.map(one, lora_tree)


def cache_shardings(cache_tree, mesh: Mesh, axis_map):
    """KV caches: batch over "batch", seq over "seq"; states: batch (+ width
    over "model"); scalars replicated."""
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        in_groups = any(getattr(k, "key", None) == "groups" for k in path)
        nd = leaf.ndim
        names = [None] * nd
        base = 1 if in_groups else 0   # leading scan-group axis unsharded
        if name == "t" or nd <= base:
            return _ns(mesh, leaf.shape, names, axis_map)
        names[base] = "batch"
        if name in ("k", "v") and nd >= base + 4:
            names[base + 1] = "seq"
        elif name in ("ck", "cv", "conv", "C", "n", "h", "c", "m") \
                and nd >= base + 2:
            names[-1] = "model" if name in ("h", "conv") else None
        return _ns(mesh, leaf.shape, names, axis_map)
    return jax.tree_util.tree_map_with_path(one, cache_tree)



def _needs_fsdp(cfg: ModelConfig, mesh: Mesh, dtype) -> bool:
    """TP-only must fit ~10 GB/device of weights (v5e has 16 GB); otherwise
    add FSDP sharding over "data" (mixtral-8x22b is the only assigned arch
    that needs it on a 16x16 pod)."""
    itemsize = jnp.dtype(dtype).itemsize
    model_n = mesh.shape["model"]
    return cfg.param_count() * itemsize / model_n > 10e9

# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------

def fl_geometry(mesh: Mesh, shape: InputShape,
                axis_map: Optional[dict] = None) -> tuple[int, int]:
    """(n_clients, per-client batch) for a training shape. Client count =
    product of the mesh axes the "clients" logical axis maps to (the
    client-parallel §Perf variant maps ALL axes -> m = chip count)."""
    if axis_map and axis_map.get("clients"):
        m = shd.axes_size(mesh, axis_map["clients"])
    else:
        m = client_count(mesh)
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    return m, shape.global_batch // m


# ---------------------------------------------------------------------------
# TRAIN (the DFL round)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, local_steps: int = 1,
                    lr: float = 2e-4, mix_impl: str = "planned",
                    mix_flat_lowering: Optional[str] = None):
    opt = AdamW(lr=lr)

    def loss_fn(base_params, lo, micro):
        return tf.lm_loss(base_params, cfg, micro["tokens"],
                          micro["targets"], frontend=micro.get("frontend"),
                          lora=lo)[0]

    round_fn = build_round(loss_fn, opt, local_steps=local_steps,
                           mix_impl=mix_impl,
                           mix_flat_lowering=mix_flat_lowering)
    return round_fn, opt


def train_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                      local_steps: int = 1, dtype=jnp.bfloat16,
                      axis_map: Optional[dict] = None):
    """(base_params, lora, opt_state, batch, W, masks) specs w/ shardings."""
    axis_map = axis_map or shd.current_axis_map() or shd.DEFAULT_AXIS_MAP
    m, b = fl_geometry(mesh, shape, axis_map)
    S = shape.seq_len

    base_specs = tf.param_specs(cfg, dtype)
    base_sh = shd.param_shardings(base_specs, mesh, axis_map,
                                  fsdp=_needs_fsdp(cfg, mesh, dtype))
    base = _with_shardings(base_specs, base_sh)

    lora_raw = lora_spec_tree(base_specs, cfg, n_clients=m, dtype=jnp.float32)
    lora_sh = lora_shardings(lora_raw, mesh, axis_map)
    lora = _with_shardings(lora_raw, lora_sh)

    opt_state = AdamWState(
        step=_sds((), jnp.int32, NamedSharding(mesh, P())),
        mu=lora, nu=jax.tree.map(lambda x: x, lora))

    batch = {
        "tokens": _sds((local_steps, m, b, S), jnp.int32,
                       _ns(mesh, (local_steps, m, b, S),
                           (None, "clients", None, None), axis_map)),
        "targets": _sds((local_steps, m, b, S), jnp.int32,
                        _ns(mesh, (local_steps, m, b, S),
                            (None, "clients", None, None), axis_map)),
    }
    if cfg.n_frontend_tokens:
        fshape = (local_steps, m, b, cfg.n_frontend_tokens, cfg.d_model)
        batch["frontend"] = _sds(
            fshape, dtype,
            _ns(mesh, fshape, (None, "clients", None, None, "model"),
                axis_map))

    W = _sds((m, m), jnp.float32, NamedSharding(mesh, P()))
    masks = _sds((4,), jnp.float32, NamedSharding(mesh, P()))
    return (base, lora, opt_state, batch, W, masks)


# ---------------------------------------------------------------------------
# PREFILL
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def step(params, tokens, frontend=None):
        return tf.prefill(params, cfg, tokens, frontend=frontend)
    return step


def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                        dtype=jnp.bfloat16,
                        axis_map: Optional[dict] = None):
    axis_map = axis_map or shd.current_axis_map() or shd.DEFAULT_AXIS_MAP
    B, S = shape.global_batch, shape.seq_len
    base_specs = tf.param_specs(cfg, dtype)
    base_sh = shd.param_shardings(base_specs, mesh, axis_map,
                                  fsdp=_needs_fsdp(cfg, mesh, dtype))
    base = _with_shardings(base_specs, base_sh)
    tokens = _sds((B, S), jnp.int32,
                  _ns(mesh, (B, S), ("batch", None), axis_map))
    args = [base, tokens]
    if cfg.n_frontend_tokens:
        fshape = (B, cfg.n_frontend_tokens, cfg.d_model)
        args.append(_sds(fshape, dtype,
                         _ns(mesh, fshape, ("batch", None, "model"),
                             axis_map)))
    return tuple(args)


# ---------------------------------------------------------------------------
# DECODE (serve_step)
# ---------------------------------------------------------------------------

def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return tf.decode_step(params, cfg, tokens, cache)
    return serve_step


def decode_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                       dtype=jnp.bfloat16,
                       axis_map: Optional[dict] = None):
    axis_map = axis_map or shd.current_axis_map() or shd.DEFAULT_AXIS_MAP
    B = shape.global_batch
    base_specs = tf.param_specs(cfg, dtype)
    base_sh = shd.param_shardings(base_specs, mesh, axis_map,
                                  fsdp=_needs_fsdp(cfg, mesh, dtype))
    base = _with_shardings(base_specs, base_sh)

    cache_raw = tf.init_cache(cfg, B, shape.seq_len, dtype, specs_only=True)
    cache_sh = cache_shardings(cache_raw, mesh, axis_map)
    cache = _with_shardings(cache_raw, cache_sh)

    tokens = _sds((B, 1), jnp.int32,
                  _ns(mesh, (B, 1), ("batch", None), axis_map))
    return (base, cache, tokens)


# ---------------------------------------------------------------------------
# unified dispatch (the dry-run's entry point)
# ---------------------------------------------------------------------------

def build(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
          local_steps: int = 1, dtype=jnp.bfloat16,
          axis_map: Optional[dict] = None, mix_impl: str = "planned",
          mix_flat_lowering: Optional[str] = None):
    """Returns (step_fn, input_specs, n_tokens, training_flag)."""
    if shape.kind == "train":
        step, _ = make_train_step(cfg, local_steps=local_steps,
                                  mix_impl=mix_impl,
                                  mix_flat_lowering=mix_flat_lowering)
        specs = train_input_specs(cfg, shape, mesh,
                                  local_steps=local_steps, dtype=dtype,
                                  axis_map=axis_map)
        n_tokens = local_steps * shape.global_batch * shape.seq_len
        return step, specs, n_tokens, True
    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        specs = prefill_input_specs(cfg, shape, mesh, dtype=dtype,
                                    axis_map=axis_map)
        return step, specs, shape.global_batch * shape.seq_len, False
    if shape.kind == "decode":
        step = make_decode_step(cfg)
        specs = decode_input_specs(cfg, shape, mesh, dtype=dtype,
                                   axis_map=axis_map)
        return step, specs, shape.global_batch, False
    raise ValueError(shape.kind)
