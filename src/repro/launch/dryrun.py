import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, extract memory/cost/collective analyses, emit roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single --out results/dryrun.json

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached per-combo in the output JSON; finished combos are
skipped, so the sweep is resumable. The device-count override above MUST
precede any jax import (jax locks the backend on first init) — that is why
these are the first two lines of the file.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.dist import sharding as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (HW, collective_bytes_from_hlo,
                                     jaxpr_cost, model_flops,
                                     roofline_report)


def run_combo(arch: str, shape_name: str, multi_pod: bool, *,
              local_steps: int = 1, axis_map=None,
              mix_impl: str = "planned", mix_flat_lowering: str = "flat",
              moe_dispatch: str = "dense",
              seq_parallel: bool = False,
              client_parallel: bool = False) -> dict:
    # mix_flat_lowering defaults to "flat" here (not "auto"): the dry-run
    # simulates production TPU meshes on CPU host devices, so "auto" would
    # analyze the off-TPU per-segment path instead of the pod's real one
    from repro.models import moe as moe_mod
    moe_mod.set_dispatch(moe_dispatch)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    amap = axis_map or (shd.MULTIPOD_AXIS_MAP if multi_pod
                        else shd.DEFAULT_AXIS_MAP)
    if seq_parallel:
        amap = {**amap, "seq_act": ("model",)}
    if client_parallel:
        # small-model mode: one client per (data, model) chip pair; no
        # tensor parallelism (weights replicated), collectives = gossip
        # only. On the multi-pod mesh the "pod" axis replicates (client
        # count is bounded by the global batch of 256).
        amap = {"clients": ("data", "model"), "batch": ("data", "model"),
                "fsdp": (), "model": (), "seq": ()}
    shd.set_mesh(mesh, amap)
    n_chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "multi" if multi_pod else "single",
                 "chips": n_chips, "local_steps": local_steps}
    try:
        step, specs, n_tokens, training = steps_mod.build(
            cfg, shape, mesh, local_steps=local_steps, axis_map=amap,
            mix_impl=mix_impl, mix_flat_lowering=mix_flat_lowering)

        t0 = time.time()
        lowered = jax.jit(step).lower(*specs)
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        # --- memory analysis (proves it fits) ---
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
            }

        # --- XLA cost analysis (reference; scan bodies counted once) ---
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0))}

        # --- jaxpr cost (scan-aware, global) ---
        jxp = jax.make_jaxpr(step)(*specs)
        jc = jaxpr_cost(jxp)
        rec["jaxpr_cost"] = jc

        # --- collectives from partitioned HLO (per-device) ---
        coll = collective_bytes_from_hlo(compiled.as_text())
        rec["collectives"] = coll

        mf = model_flops(cfg, n_tokens, training=training)
        rec["roofline"] = roofline_report(
            flops=jc["flops"], hbm_bytes=jc["bytes"],
            coll_bytes_per_device=coll["total"], n_chips=n_chips,
            model_fl=mf)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — sweep must survive one failure
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    finally:
        shd.clear_mesh()
    return rec


def _combo_key(arch, shape, mesh_name, local_steps, mix_impl, tag="",
               mix_flat_lowering="flat"):
    # mix_impl is part of the key (cached per_leaf results must not be
    # served as planned ones); other variant flags go through --tag
    k = f"{arch}|{shape}|{mesh_name}|ls{local_steps}|mix:{mix_impl}"
    if mix_flat_lowering != "flat":
        k += f"|mfl:{mix_flat_lowering}"
    return k + (f"|{tag}" if tag else "")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--tag", default="", help="cache-key suffix for variants")
    ap.add_argument("--mix-impl", default="planned",
                    choices=("planned", "per_leaf", "concat"))
    ap.add_argument("--mix-flat-lowering", default="flat",
                    choices=("auto", "flat", "per_segment"),
                    help="planned-path buffer lowering to analyze "
                         "(default: the pod's flat path)")
    ap.add_argument("--moe-dispatch", default="dense",
                    choices=("dense", "fused"))
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--client-parallel", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = ARCH_IDS if args.all else [args.arch]
    shapes = tuple(SHAPES) if args.all else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape, mp in combos:
        mesh_name = "multi" if mp else "single"
        key = _combo_key(arch, shape, mesh_name, args.local_steps,
                         args.mix_impl, args.tag,
                         mix_flat_lowering=args.mix_flat_lowering)
        if key in results and results[key].get("status") in ("ok", "skipped") \
                and not args.force:
            print(f"[cached] {key}: {results[key]['status']}")
            continue
        print(f"[run] {key} ...", flush=True)
        t0 = time.time()
        rec = run_combo(arch, shape, mp, local_steps=args.local_steps,
                        mix_impl=args.mix_impl,
                        mix_flat_lowering=args.mix_flat_lowering,
                        moe_dispatch=args.moe_dispatch,
                        seq_parallel=args.seq_parallel,
                        client_parallel=args.client_parallel)
        rec["wall_s"] = round(time.time() - t0, 1)
        results[key] = rec
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" bottleneck={r['bottleneck']} "
                     f"c={r['compute_s']:.3g}s m={r['memory_s']:.3g}s "
                     f"x={r['collective_s']:.3g}s")
        elif status == "error":
            extra = " " + rec["error"][:160]
        print(f"[done] {key}: {status}{extra} ({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
