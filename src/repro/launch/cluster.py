"""Cluster entrypoint: multi-process DFL execution on the repro.dist grid.

Two modes in one module:

*Worker* (the default): join the process grid via the ``REPRO_*`` env
protocol (or explicit ``--coordinator/--num-processes/--process-id``),
build a `DFLConfig`, run a `repro.api.ClusterSession`, optionally save a
checkpoint / JSON result (rank 0 only). On a real cluster every node runs
this with its own ``REPRO_PROCESS_ID``.

*Parent* (``--simulate N``): spawn N local worker processes on the
portable CPU backend (gloo collectives), forward the remaining CLI args to
each, stream rank 0's output, and exit non-zero if any worker fails. This
is how CI exercises the whole multi-process path headless:

  PYTHONPATH=src python -m repro.launch.cluster --simulate 2 \\
      --preset classifier --rounds 6 --clients 4 --json out.json

The worker JSON records the cluster perf surface: rounds/s, the
per-round gossip payload measured from the live session's plans
(`comm_bytes_per_round` — the exact bytes each process *receives* from
the collectives the round actually issues, with the dense and sparse
figures both reported for comparison), and the final loss, so tests and
``benchmarks/multihost.py`` share one measurement path.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time
from typing import Optional, Sequence

# NOTE: jax / repro.api imports happen inside worker_main(), AFTER
# multihost.initialize() — the grid must exist before the backend is used.

PRESETS = ("classifier", "lm")


def _preset_config(args) -> dict:
    """A DFLConfig dict from the CLI knobs (small enough for CI)."""
    if args.preset == "classifier":
        cfg = dict(model="encoder", task="sst2",
                   model_kw={"n_layers": 1, "d_model": 32, "n_heads": 2,
                             "d_ff": 64, "vocab_size": 256},
                   batch_size=args.batch or 8)
    else:
        cfg = dict(model=args.arch, task="lm", reduced=True,
                   batch_size=args.batch or 2, seq_len=args.seq)
    cfg.update(n_clients=args.clients, topology=args.topology, p=args.p,
               scenario=args.scenario, method=args.method, T=args.interval,
               rounds=args.rounds, local_steps=args.local_steps,
               lr=args.lr, seed=args.seed, mix_comm=args.mix_comm,
               mix_quant=args.mix_quant)
    if args.weight_policy != "metropolis" or args.t_policy != "fixed":
        if args.weight_policy == "fmmc" and args.scenario == "gossip":
            # the default scenario's pairwise sampler has no weight
            # matrix for FMMC to rewire; picking the policy implies a
            # weighted schedule
            cfg["scenario"] = "edge_activation"
        cfg["control"] = dict(weight_policy=args.weight_policy,
                              t_policy=args.t_policy)
    return cfg


def _comm_bytes(session) -> dict:
    """Per-round gossip payload a process RECEIVES, measured from the
    live session's plans — the MixPlan of the actual LoRA tree and the
    CommPlan of the actual exchange — i.e. the exact payloads of the
    collectives the round issues, not an analytic estimate. Reports the
    active mode's figure plus both alternatives for comparison; all 0 on
    a single-process grid."""
    import jax
    from repro.core import mixing
    from repro.dist import comm
    from repro.scenarios.schedule import schedule_support

    plan = mixing.get_mix_plan(session.lora)
    cp = session.comm_plan
    if cp is None:      # dense run: compile the plan it WOULD use
        cp = comm.build_comm_plan(
            schedule_support(session.topo_schedule),
            n_shards=jax.device_count())
    dense_b = comm.dense_recv_bytes(cp.m, cp.n_shards, plan.cols)
    sparse_b = cp.sparse_recv_bytes(plan.cols)
    quant_b = cp.sparse_recv_bytes_quant(plan.cols)
    link_b = cp.link_bytes(plan.cols)
    mode = session.config.mix_comm
    quant = session.config.mix_quant
    active = dense_b if mode == "dense" else \
        (quant_b if quant != "off" else sparse_b)
    return {
        "mix_comm": mode,
        "mix_quant": quant,
        "comm_bytes_per_round": active,
        "dense_comm_bytes_per_round": dense_b,
        "sparse_comm_bytes_per_round": sparse_b,
        "sparse_quant_comm_bytes_per_round": quant_b,
        # per-link surface: what the control plane's FMMC cost term sees
        "cross_links": cp.cross_edges,
        "max_link_bytes_per_round": float(link_b.max()),
    }


def worker_main(args) -> int:
    from repro.dist import multihost
    multihost.initialize(coordinator=args.coordinator,
                         num_processes=args.num_processes,
                         process_id=args.process_id)

    import jax
    from repro.api import ClusterSession, ConsoleLogger, DFLConfig

    if args.config:
        with open(args.config) as f:
            config = DFLConfig.from_dict(json.load(f))
    else:
        config = DFLConfig(**_preset_config(args))

    callbacks = []
    if multihost.is_primary() and not args.quiet:
        # loss is a fully-replicated scalar — float() is a local read, so
        # rank-gating this callback breaks no collective lockstep
        callbacks.append(ConsoleLogger(every=max(1, config.rounds // 10)))
    session = ClusterSession(config, callbacks=callbacks)

    if args.restore:
        at = session.restore(args.restore)
        if multihost.is_primary():
            print(f"restored {args.restore} at round {at}", flush=True)

    rounds = args.run_rounds or None
    if args.warmup:
        # compile + first rounds untimed: rounds_per_s then measures the
        # steady-state round, not jit/partitioner/gloo startup
        session.run(args.warmup)
        jax.block_until_ready(session.lora)
    t0 = time.perf_counter()
    result = session.run(rounds)
    wall = time.perf_counter() - t0

    if args.ckpt:
        session.save(args.ckpt)
    eval_res = None
    if args.eval:
        # a collective: every rank computes, rank 0 reports
        eval_res = session.evaluate(n=64)
    if multihost.is_primary():
        m = config.n_clients
        n_proc = jax.process_count()
        payload = {
            "n_processes": n_proc,
            "n_devices": jax.device_count(),
            "m": m,
            "clients_per_process": m // n_proc,
            "rounds": result.rounds,
            "wall_s": round(wall, 4),
            "rounds_per_s": round(result.rounds / wall, 2),
            "final_loss": result.final_loss,
            "final_round": session.t,
            **_comm_bytes(session),
        }
        if eval_res is not None:
            payload["eval_acc"] = eval_res["acc"]
        print(f"[cluster] {json.dumps(payload)}", flush=True)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
    multihost.sync("cluster-exit")
    multihost.shutdown()
    return 0


# ---------------------------------------------------------------------------
# --simulate N: the local process-grid spawner (CI / laptop path)
# ---------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_simulated(n: int, worker_args: Sequence[str], *,
                    timeout: float = 900.0,
                    extra_env: Optional[dict] = None):
    """Spawn ``python -m repro.launch.cluster`` × n as a local grid.

    Returns a list of (returncode, combined_output) per rank. Workers run
    on the portable CPU backend with gloo collectives; the repro source
    tree is put on each worker's PYTHONPATH so the spawner works from a
    plain checkout.
    """
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    coord = f"127.0.0.1:{_free_port()}"
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if extra_env:
        env.update(extra_env)
    procs = []
    for i in range(n):
        env_i = dict(env)
        env_i["REPRO_COORDINATOR"] = coord
        env_i["REPRO_NUM_PROCESSES"] = str(n)
        env_i["REPRO_PROCESS_ID"] = str(i)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.cluster", *worker_args],
            env=env_i, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True))
    out = []
    deadline = time.monotonic() + timeout
    for p in procs:
        left = max(1.0, deadline - time.monotonic())
        try:
            stdout, _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            stdout, _ = p.communicate()
            stdout += "\n[spawner] TIMEOUT"
        out.append((p.returncode, stdout))
    return out


def failed_ranks(results) -> list:
    """[(rank, formatted report)] for every non-zero worker exit — the one
    place spawn failures are shaped for humans (bench, tests, CLI)."""
    return [(rank, f"--- rank {rank} (exit {code}) ---\n{out}")
            for rank, (code, out) in enumerate(results) if code != 0]


def _parser() -> argparse.ArgumentParser:
    # allow_abbrev=False: a prefix spelling like "--sim 2" must NOT parse
    # as --simulate while evading the worker-args filter below — workers
    # re-spawning as parents would fork-bomb the machine
    ap = argparse.ArgumentParser(
        description="multi-process DFL (worker, or --simulate N parent)",
        allow_abbrev=False)
    ap.add_argument("--simulate", type=int, default=0, metavar="N",
                    help="spawn N local worker processes and wait (parent "
                         "mode); 0 = run as a worker")
    # grid (worker mode; REPRO_* env is the usual source)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    # experiment
    ap.add_argument("--config", default="",
                    help="JSON DFLConfig dict (overrides the preset knobs)")
    ap.add_argument("--preset", default="classifier", choices=PRESETS)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--method", default="tad",
                    choices=("lora", "ffa", "rolora", "tad"))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=0,
                    help="per-client per-step batch (0 = preset default)")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--topology", default="complete")
    ap.add_argument("--scenario", default="gossip")
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--interval", type=int, default=2,
                    help="switching interval T (static)")
    ap.add_argument("--mix-comm", default="dense",
                    choices=("dense", "sparse", "sparse_overlap"),
                    help="gossip comm lowering (DFLConfig.mix_comm)")
    ap.add_argument("--mix-quant", default="off",
                    choices=("off", "int8", "fp8"),
                    help="compressed gossip: quantize the sparse halo "
                         "exchange (DFLConfig.mix_quant)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weight-policy", default="metropolis",
                    choices=("metropolis", "fmmc"),
                    help="closed-loop mixing weights "
                         "(ControlConfig.weight_policy)")
    ap.add_argument("--t-policy", default="fixed",
                    choices=("fixed", "adaptive"),
                    help="closed-loop T retuning (ControlConfig.t_policy)")
    # run control / artifacts
    ap.add_argument("--run-rounds", type=int, default=0,
                    help="rounds to run now (0 = config.rounds)")
    ap.add_argument("--warmup", type=int, default=0,
                    help="untimed leading rounds (compile excluded from "
                         "rounds_per_s; they still advance the session)")
    ap.add_argument("--restore", default="")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--eval", action="store_true",
                    help="session.evaluate() after training (classifier "
                         "presets; reported in the result JSON)")
    ap.add_argument("--json", default="",
                    help="rank-0 result JSON (rounds/s, collective bytes)")
    ap.add_argument("--quiet", action="store_true")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    args = _parser().parse_args(argv)
    if args.simulate:
        worker_args, skip = [], False
        for a in argv:
            if skip:
                skip = False
            elif a == "--simulate":
                skip = True
            elif not a.startswith("--simulate="):
                worker_args.append(a)
        results = spawn_simulated(args.simulate, worker_args)
        failed = failed_ranks(results)
        bad = {rank for rank, _ in failed}
        for rank, (code, outp) in enumerate(results):
            if rank == 0 and rank not in bad:
                sys.stdout.write(f"--- rank 0 (exit {code}) ---\n{outp}\n")
        for _, report in failed:
            sys.stdout.write(report + "\n")
        if failed:
            print(f"[simulate] FAILED ranks: {sorted(bad)}", file=sys.stderr)
            return 1
        return 0
    return worker_main(args)


if __name__ == "__main__":
    sys.exit(main())
