"""The paper's primary contribution: TAD-LoRA — topology-aware decentralized
alternating LoRA (Algorithm 1) plus baselines, topologies, and theory
diagnostics."""
from repro.core.alternating import (METHODS, RoundMasks, phase_is_a,
                                    round_masks, schedule)
from repro.core.diagnostics import consensus_stats, effective_update_norm
from repro.core.fedtrain import make_dfl_round, make_microbatches
from repro.core.lora import (build_lora_tree, client_mean, client_slice,
                             lora_specs, merge_lora, param_count,
                             shard_lora_tree, target_names)
from repro.core.mixing import (MixPlan, build_mix_plan, flat_lowering_mode,
                               get_mix_plan, mix_leaf, mix_tree,
                               mix_tree_concat, mix_tree_planned,
                               plan_builds, set_flat_lowering,
                               use_flat_lowering)
from repro.core.topology import (Topology, make_topology,
                                 optimal_switching_interval,
                                 optimal_switching_interval_edge_activation,
                                 sample_mixing_matrix, lambda2)

__all__ = [
    "METHODS", "RoundMasks", "phase_is_a", "round_masks", "schedule",
    "consensus_stats", "effective_update_norm",
    "make_dfl_round", "make_microbatches",
    "build_lora_tree", "client_mean", "client_slice", "lora_specs",
    "merge_lora", "param_count", "shard_lora_tree", "target_names",
    "MixPlan", "build_mix_plan", "flat_lowering_mode", "get_mix_plan",
    "mix_leaf", "mix_tree", "mix_tree_concat", "mix_tree_planned",
    "plan_builds", "set_flat_lowering", "use_flat_lowering",
    "Topology", "make_topology", "optimal_switching_interval",
    "optimal_switching_interval_edge_activation", "sample_mixing_matrix",
    "lambda2",
]
