"""Alternating phase schedule + the four methods as (update, mix) masks.

Algorithm 1: round t is a **B-phase** when ⌊t/T⌋ is even (B is updated, A
frozen), else an **A-phase**. A method is fully described by four 0/1
scalars per round:

            update_a update_b   mix_a mix_b
  LORA         1        1         1     1    (joint training, FedAvg gossip)
  FFA-LORA     0        1         1     1    (A frozen at shared init)
  ROLORA      ph       1-ph      ph    1-ph  (alternate; mix ACTIVE only)
  TAD-LORA    ph       1-ph       1     1    (alternate; JOINT mixing) ← ours

with ph = 1 in an A-phase, 0 in a B-phase. Masks are traced scalars — one
compiled DFL round serves every method, phase, and topology sample.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

METHODS = ("lora", "ffa", "rolora", "tad")


def phase_is_a(t: int | jnp.ndarray, T: int):
    """True in an A-phase (paper: B-phase when ⌊t/T⌋ even)."""
    return ((t // T) % 2) == 1


@dataclass(frozen=True)
class RoundMasks:
    update_a: float
    update_b: float
    mix_a: float
    mix_b: float

    def as_array(self):
        return jnp.array([self.update_a, self.update_b,
                          self.mix_a, self.mix_b], jnp.float32)


def round_masks(method: str, t: int, T: int) -> RoundMasks:
    ph = 1.0 if bool(np.asarray(phase_is_a(t, T))) else 0.0
    if method == "lora":
        return RoundMasks(1.0, 1.0, 1.0, 1.0)
    if method == "ffa":
        return RoundMasks(0.0, 1.0, 1.0, 1.0)
    if method == "rolora":
        return RoundMasks(ph, 1.0 - ph, ph, 1.0 - ph)
    if method == "tad":
        return RoundMasks(ph, 1.0 - ph, 1.0, 1.0)
    raise ValueError(f"unknown method {method!r}; known: {METHODS}")


def schedule(method: str, rounds: int, T: int) -> list[RoundMasks]:
    return [round_masks(method, t, T) for t in range(rounds)]
