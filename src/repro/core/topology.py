"""Communication topologies and time-varying mixing matrices W_t.

Paper §IV-A / Appendix A-J: clients gossip through doubly-stochastic W_t
with mean-square contraction E||W_t − (1/m)11ᵀ||² ≤ ρ². The experimental
topology is Erdős–Rényi *edge activation*: each edge of an underlying graph
fires independently with probability p each round, and every activated edge
performs pairwise averaging (Lemma A.10) — giving 1−ρ ≥ c_mix·p·λ2(L).

Implemented here:
  * underlying graphs: complete (paper's main setting), ring (Table V),
    arbitrary adjacency;
  * per-round W_t sampling via sequential pairwise averaging in random order
    (exactly Lemma A.10's model, so W_t is doubly stochastic by
    construction);
  * spectral diagnostics: λ2(L), ρ estimation (both the exact
    ||E[WᵀW] − J||₂ route and Monte-Carlo), effective spectral gap.

W_t is *data*, not code — the compiled DFL round consumes it as an input
array, so dynamic graphs never trigger recompilation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Underlying graphs
# ---------------------------------------------------------------------------

def complete_graph(m: int) -> np.ndarray:
    a = np.ones((m, m)) - np.eye(m)
    return a


def ring_graph(m: int) -> np.ndarray:
    a = np.zeros((m, m))
    for i in range(m):
        a[i, (i + 1) % m] = a[(i + 1) % m, i] = 1.0
    return a


def erdos_renyi_graph(m: int, q: float, rng: np.random.Generator) -> np.ndarray:
    """Static ER graph with edge prob q (used as an underlying graph)."""
    u = rng.random((m, m))
    a = np.triu((u < q).astype(float), k=1)
    return a + a.T


def laplacian(adj: np.ndarray) -> np.ndarray:
    return np.diag(adj.sum(1)) - adj


def lambda2(adj: np.ndarray) -> float:
    """Algebraic connectivity λ2(L)."""
    ev = np.linalg.eigvalsh(laplacian(adj))
    return float(ev[1]) if len(ev) > 1 else 0.0


# ---------------------------------------------------------------------------
# Edge-activation gossip (Lemma A.10)
# ---------------------------------------------------------------------------

def _edges(adj: np.ndarray) -> np.ndarray:
    iu = np.triu_indices(adj.shape[0], k=1)
    mask = adj[iu] > 0
    return np.stack([iu[0][mask], iu[1][mask]], axis=1)


def sample_mixing_matrix(adj: np.ndarray, p: float,
                         rng: np.random.Generator) -> np.ndarray:
    """One round's W_t: every edge activates w.p. p; activated edges apply
    pairwise averaging in uniformly-random order (Lemma A.10). The product
    of symmetric doubly-stochastic pairwise averagers is doubly stochastic."""
    m = adj.shape[0]
    W = np.eye(m)
    edges = _edges(adj)
    if len(edges) == 0:
        return W
    fired = edges[rng.random(len(edges)) < p]
    if len(fired) == 0:
        return W
    order = rng.permutation(len(fired))
    for idx in order:
        i, j = fired[idx]
        We = np.eye(m)
        We[i, i] = We[j, j] = 0.5
        We[i, j] = We[j, i] = 0.5
        W = We @ W
    return W


@dataclass
class Topology:
    """A sampled-communication environment for one DFL run."""
    adj: np.ndarray
    p: float
    seed: int = 0

    def __post_init__(self):
        self.m = self.adj.shape[0]
        self._rng = np.random.default_rng(self.seed)

    def sample(self) -> np.ndarray:
        return sample_mixing_matrix(self.adj, self.p, self._rng)

    def matrices(self, rounds: int) -> Iterator[np.ndarray]:
        for _ in range(rounds):
            yield self.sample()

    # ---- spectral diagnostics -------------------------------------------
    def lambda2(self) -> float:
        return lambda2(self.adj)

    def rho_estimate(self, n_samples: int = 200) -> float:
        """Monte-Carlo estimate of ρ with E||W − J||₂² ≤ ρ²: uses the
        top singular value of (W − J) per sample and averages the square
        (the assumption is mean-square, Appendix A-A)."""
        m = self.m
        J = np.ones((m, m)) / m
        rng = np.random.default_rng(self.seed + 12345)
        vals = []
        for _ in range(n_samples):
            W = sample_mixing_matrix(self.adj, self.p, rng)
            s = np.linalg.norm(W - J, ord=2)
            vals.append(s * s)
        return float(np.sqrt(np.mean(vals)))

    def spectral_gap(self, n_samples: int = 200) -> float:
        return 1.0 - self.rho_estimate(n_samples)


def make_topology(kind: str, m: int, p: float, seed: int = 0,
                  er_q: float = 0.5) -> Topology:
    if kind == "complete":
        adj = complete_graph(m)
    elif kind == "ring":
        adj = ring_graph(m)
    elif kind == "erdos_renyi":
        adj = erdos_renyi_graph(m, er_q, np.random.default_rng(seed + 777))
    else:
        raise ValueError(kind)
    return Topology(adj=adj, p=p, seed=seed)


# ---------------------------------------------------------------------------
# Topology-aware switching interval (the paper's headline formula)
# ---------------------------------------------------------------------------

def optimal_switching_interval(rho: float, *, c: float = 1.0,
                               t_min: int = 1, t_max: int = 64) -> int:
    """T*(ρ) ≍ c/√(1−ρ)  (Theorem V.3 / Corollary A.9)."""
    gap = max(1.0 - rho, 1e-6)
    t = int(round(c / np.sqrt(gap)))
    return int(np.clip(t, t_min, t_max))


def optimal_switching_interval_edge_activation(
        p: float, lam2: float, *, c: float = 1.0, c_mix: float = 0.5,
        t_min: int = 1, t_max: int = 64) -> int:
    """T*(p, L) ≍ c/√(p·λ2(L))  (Corollary A.11): 1−ρ ≥ c_mix·p·λ2(L)."""
    gap = max(c_mix * p * lam2, 1e-6)
    t = int(round(c / np.sqrt(gap)))
    return int(np.clip(t, t_min, t_max))
