"""Communication topologies and time-varying mixing matrices W_t.

Paper §IV-A / Appendix A-J: clients gossip through doubly-stochastic W_t
with mean-square contraction E||W_t − (1/m)11ᵀ||² ≤ ρ². The experimental
topology is Erdős–Rényi *edge activation*: each edge of an underlying graph
fires independently with probability p each round, and every activated edge
performs pairwise averaging (Lemma A.10) — giving 1−ρ ≥ c_mix·p·λ2(L).

Implemented here:
  * underlying graphs: complete (paper's main setting), ring (Table V),
    static Erdős–Rényi, exponential/hypercube, 2-D torus, Watts–Strogatz
    small-world — the families DeCAF / decentralized-LoRA evaluate on —
    plus arbitrary adjacency;
  * per-round W_t sampling via sequential pairwise averaging in random order
    (exactly Lemma A.10's model, so W_t is doubly stochastic by
    construction), Metropolis–Hastings weights (symmetric doubly
    stochastic, the scenario library's constructor), and fastest-mixing
    (FMMC) weights by projected subgradient — the control plane's
    bandwidth-aware alternative;
  * spectral diagnostics: λ2(L), ρ estimation (both the ||E[WᵀW] − J||₂
    gram route and per-sample Monte-Carlo), effective spectral gap, and
    the Lemma A.10 contraction lower bound 1−ρ ≥ c_mix·p·λ2(L).

W_t is *data*, not code — the compiled DFL round consumes it as an input
array, so dynamic graphs never trigger recompilation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Underlying graphs
# ---------------------------------------------------------------------------

def complete_graph(m: int) -> np.ndarray:
    a = np.ones((m, m)) - np.eye(m)
    return a


def ring_graph(m: int) -> np.ndarray:
    a = np.zeros((m, m))
    for i in range(m):
        a[i, (i + 1) % m] = a[(i + 1) % m, i] = 1.0
    return a


def erdos_renyi_graph(m: int, q: float, rng: np.random.Generator) -> np.ndarray:
    """Static ER graph with edge prob q (used as an underlying graph)."""
    u = rng.random((m, m))
    a = np.triu((u < q).astype(float), k=1)
    return a + a.T


def exponential_graph(m: int) -> np.ndarray:
    """Exponential graph: node i links to (i ± 2^k) mod m for all 2^k < m.
    For m = 2^d this is the d-dimensional hypercube's standard surrogate in
    decentralized SGD — O(log m) degree with λ2(L) = Θ(degree)."""
    a = np.zeros((m, m))
    k = 1
    while k < m:
        for i in range(m):
            j = (i + k) % m
            if j != i:
                a[i, j] = a[j, i] = 1.0
        k *= 2
    return a


def torus_dims(m: int) -> tuple[int, int]:
    """Most-square (rows, cols) factorization of m, rows <= cols."""
    r = int(np.sqrt(m))
    while m % r:
        r -= 1
    return r, m // r


def torus_graph(m: int, rows: int = 0, cols: int = 0) -> np.ndarray:
    """2-D torus C_rows x C_cols (rows*cols = m). Defaults to the
    most-square factorization; a 1 x m torus degenerates to the ring."""
    if not rows or not cols:
        rows, cols = torus_dims(m)
    if rows * cols != m:
        raise ValueError(f"torus {rows}x{cols} != m={m}")
    a = np.zeros((m, m))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for j in (((r + 1) % rows) * cols + c,
                      r * cols + (c + 1) % cols):
                if j != i:
                    a[i, j] = a[j, i] = 1.0
    return a


def watts_strogatz_graph(m: int, k: int = 4, beta: float = 0.2,
                         rng: Optional[np.random.Generator] = None,
                         ) -> np.ndarray:
    """Watts–Strogatz small world: ring lattice with k neighbors per node
    (k/2 each side), each lattice edge rewired w.p. beta to a uniformly
    random non-neighbor. Resamples (up to 32 draws, advancing the rng) in
    the rare event rewiring disconnects the graph."""
    if rng is None:
        rng = np.random.default_rng(0)
    k = min(k, m - 1)
    half = max(k // 2, 1)
    for _ in range(32):
        a = np.zeros((m, m))
        for i in range(m):
            for d in range(1, half + 1):
                a[i, (i + d) % m] = a[(i + d) % m, i] = 1.0
        for i in range(m):
            for d in range(1, half + 1):
                j = (i + d) % m
                if a[i, j] and rng.random() < beta:
                    free = np.flatnonzero(a[i] == 0)
                    free = free[free != i]
                    if len(free):
                        a[i, j] = a[j, i] = 0.0
                        jn = int(rng.choice(free))
                        a[i, jn] = a[jn, i] = 1.0
        if lambda2(a) > 1e-9:            # connected
            return a
    return a                              # last draw (k>=2 is near-surely ok)


def hierarchical_graph(m: int, n_silos: int = 0, intra: str = "complete",
                       inter: str = "ring", seed: int = 0) -> np.ndarray:
    """Two-tier cross-silo topology: m clients split into `n_silos`
    near-equal contiguous silos, each silo internally wired by the
    `intra` family (dense by default), and silo *gateways* (the first
    node of each silo) wired by the `inter` family over silos (sparse by
    default) — the hierarchical intra-silo-dense / inter-silo-sparse
    setting of cross-silo FL, composed from the existing graph families.

    `n_silos=0` picks ~sqrt(m) silos. Both tier families accept any
    non-hierarchical `GRAPH_FAMILIES` member."""
    if n_silos <= 0:
        n_silos = max(2, int(np.sqrt(m)))
    if not 2 <= n_silos <= m:
        raise ValueError(f"n_silos={n_silos} must be in [2, m={m}]")
    if "hierarchical" in (intra, inter):
        raise ValueError("hierarchical tiers cannot nest")
    groups = np.array_split(np.arange(m), n_silos)
    a = np.zeros((m, m))
    for g in groups:
        if len(g) > 1:
            a[np.ix_(g, g)] = underlying_graph(intra, len(g), seed)
    gateways = [int(g[0]) for g in groups]
    top = underlying_graph(inter, n_silos, seed + 1)
    for s in range(n_silos):
        for s2 in range(s + 1, n_silos):
            if top[s, s2]:
                i, j = gateways[s], gateways[s2]
                a[i, j] = a[j, i] = 1.0
    return a


def laplacian(adj: np.ndarray) -> np.ndarray:
    return np.diag(adj.sum(1)) - adj


def lambda2(adj: np.ndarray) -> float:
    """Algebraic connectivity λ2(L)."""
    ev = np.linalg.eigvalsh(laplacian(adj))
    return float(ev[1]) if len(ev) > 1 else 0.0


def _check_adjacency(a: np.ndarray, who: str) -> np.ndarray:
    """Validate a weight-construction adjacency: square, finite, symmetric
    support. Returns the 0/1 support with an empty diagonal."""
    a = np.asarray(a, dtype=float)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"{who}: adjacency must be a square matrix, "
                         f"got shape {a.shape}")
    if not np.isfinite(a).all():
        raise ValueError(f"{who}: adjacency must be finite")
    s = (a > 0).astype(float)
    np.fill_diagonal(s, 0.0)
    if not np.array_equal(s, s.T):
        raise ValueError(f"{who}: adjacency support must be symmetric "
                         f"(gossip edges are undirected)")
    return s


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings mixing matrix of a graph: W[i,j] =
    1/(1+max(d_i,d_j)) on edges, diagonal = 1 − row sum. Symmetric, doubly
    stochastic, non-negative for any validated adjacency — including graphs
    with isolated nodes, whose rows degenerate to e_i (the identity row/col
    "repair" the churn/straggler scenarios rely on), and the all-zero
    adjacency, which yields the identity. Raises ValueError on non-square,
    non-finite, or asymmetric-support input instead of silently producing a
    non-stochastic W."""
    a = _check_adjacency(adj, "metropolis_weights")
    deg = a.sum(1)
    inv = 1.0 / (1.0 + np.maximum(deg[:, None], deg[None, :]))
    W = a * inv
    np.fill_diagonal(W, 1.0 - W.sum(1))
    return W


def fastest_mixing_weights(adj: np.ndarray,
                           link_cost: Optional[np.ndarray] = None, *,
                           iters: int = 120, step: float = 0.4,
                           cost_weight: float = 0.0) -> np.ndarray:
    """Fastest-mixing symmetric weights (Boyd–Diaconis–Xiao FMMC) by
    projected subgradient — no solver dependency.

    Minimizes μ(W) = ||W − J||₂ over W = I − Σ_e w_e (e_i−e_j)(e_i−e_j)ᵀ
    with w ≥ 0 and per-node Σ_{e∋i} w_e ≤ 1 (so W stays elementwise
    non-negative: a subfamily of the FMMC feasible set that every gossip
    predicate in this repo assumes). The subgradient of μ at the active
    eigenvector u is ∂μ/∂w_e = ∓(u_i − u_j)²; projection is a clip plus a
    per-node edge-sum repair. Deterministic: initialized at
    `metropolis_weights` and tracking the best iterate, so the returned
    spectral gap is never worse than Metropolis (when cost_weight = 0).

    `link_cost` is an optional (m, m) per-link cost (e.g. bytes moved per
    round from `CommPlan.link_bytes`); with cost_weight > 0 the objective
    gains `cost_weight · Σ_e c_e w_e` (costs normalized to mean 1 over
    edges), trading spectral gap against traffic on expensive links.
    """
    a = _check_adjacency(adj, "fastest_mixing_weights")
    m = a.shape[0]
    ii, jj = np.triu_indices(m, k=1)
    on = a[ii, jj] > 0
    ii, jj = ii[on], jj[on]
    if len(ii) == 0:
        return np.eye(m)
    if link_cost is not None:
        c = np.asarray(link_cost, dtype=float)
        if c.shape != (m, m):
            raise ValueError(f"fastest_mixing_weights: link_cost shape "
                             f"{c.shape} != adjacency shape {(m, m)}")
        c = np.maximum(c[ii, jj], 0.0)
        c = c / c.mean() if c.mean() > 0 else np.zeros_like(c)
    else:
        c = np.zeros(len(ii))
    J = np.ones((m, m)) / m

    def build(w: np.ndarray) -> np.ndarray:
        W = np.zeros((m, m))
        W[ii, jj] = w
        W = W + W.T
        np.fill_diagonal(W, 1.0 - W.sum(1))
        return W

    def objective(w: np.ndarray) -> float:
        return float(np.linalg.norm(build(w) - J, ord=2)
                     + cost_weight * (c @ w))

    w = metropolis_weights(a)[ii, jj].copy()
    best_w, best_obj = w.copy(), objective(w)
    for k in range(max(int(iters), 0)):
        evals, evecs = np.linalg.eigh(build(w) - J)
        if evals[-1] >= -evals[0]:          # μ attained at λ_max(W − J)
            u = evecs[:, -1]
            g = -((u[ii] - u[jj]) ** 2)
        else:                               # μ attained at −λ_min(W − J)
            u = evecs[:, 0]
            g = (u[ii] - u[jj]) ** 2
        w = np.clip(w - (step / np.sqrt(k + 1.0)) * (g + cost_weight * c),
                    0.0, None)
        for _ in range(8):                  # per-node edge-sum ≤ 1 repair
            s = np.zeros(m)
            np.add.at(s, ii, w)
            np.add.at(s, jj, w)
            over = s > 1.0
            if not over.any():
                break
            f = np.where(over, 1.0 / np.maximum(s, 1e-12), 1.0)
            w = w * np.minimum(f[ii], f[jj])
        obj = objective(w)
        if obj < best_obj - 1e-12:
            best_obj, best_w = obj, w.copy()
    return build(best_w)


def rho_sq_from_samples(Ws) -> float:
    """Mean-square contraction from W samples via the gram route:
    ρ² = ||E[WᵀW] − J||₂ (tight for the Appendix A-A assumption
    E||Wx − x̄||² ≤ ρ²||x − x̄||², unlike averaging per-sample norms)."""
    Ws = list(Ws)
    m = Ws[0].shape[0]
    G = np.zeros((m, m))
    for W in Ws:
        G += W.T @ W
    G /= len(Ws)
    return float(np.linalg.norm(G - np.ones((m, m)) / m, ord=2))


def lemma_a10_gap_bound(adj: np.ndarray, p: float,
                        c_mix: float = 0.5) -> float:
    """Lemma A.10's spectral-gap lower bound 1−ρ ≥ c_mix·p·λ2(L) for
    edge-activation gossip on `adj` (capped at 1: the gap cannot exceed
    1). Conformance tests check measured gaps against this with a
    conservative empirical c_mix."""
    return float(min(c_mix * p * lambda2(adj), 1.0))


# ---------------------------------------------------------------------------
# Edge-activation gossip (Lemma A.10)
# ---------------------------------------------------------------------------

def _edges(adj: np.ndarray) -> np.ndarray:
    iu = np.triu_indices(adj.shape[0], k=1)
    mask = adj[iu] > 0
    return np.stack([iu[0][mask], iu[1][mask]], axis=1)


def sample_mixing_matrix(adj: np.ndarray, p: float,
                         rng: np.random.Generator) -> np.ndarray:
    """One round's W_t: every edge activates w.p. p; activated edges apply
    pairwise averaging in uniformly-random order (Lemma A.10). The product
    of symmetric doubly-stochastic pairwise averagers is doubly stochastic."""
    m = adj.shape[0]
    W = np.eye(m)
    edges = _edges(adj)
    if len(edges) == 0:
        return W
    fired = edges[rng.random(len(edges)) < p]
    if len(fired) == 0:
        return W
    order = rng.permutation(len(fired))
    for idx in order:
        i, j = fired[idx]
        We = np.eye(m)
        We[i, i] = We[j, j] = 0.5
        We[i, j] = We[j, i] = 0.5
        W = We @ W
    return W


@dataclass
class Topology:
    """A sampled-communication environment for one DFL run."""
    adj: np.ndarray
    p: float
    seed: int = 0

    def __post_init__(self):
        self.m = self.adj.shape[0]
        self._rng = np.random.default_rng(self.seed)

    def sample(self) -> np.ndarray:
        return sample_mixing_matrix(self.adj, self.p, self._rng)

    def matrices(self, rounds: int) -> Iterator[np.ndarray]:
        for _ in range(rounds):
            yield self.sample()

    # ---- spectral diagnostics -------------------------------------------
    def lambda2(self) -> float:
        return lambda2(self.adj)

    def rho_estimate(self, n_samples: int = 200) -> float:
        """Monte-Carlo estimate of ρ with E||W − J||₂² ≤ ρ²: uses the
        top singular value of (W − J) per sample and averages the square
        (the assumption is mean-square, Appendix A-A)."""
        m = self.m
        J = np.ones((m, m)) / m
        rng = np.random.default_rng(self.seed + 12345)
        vals = []
        for _ in range(n_samples):
            W = sample_mixing_matrix(self.adj, self.p, rng)
            s = np.linalg.norm(W - J, ord=2)
            vals.append(s * s)
        return float(np.sqrt(np.mean(vals)))

    def spectral_gap(self, n_samples: int = 200) -> float:
        return 1.0 - self.rho_estimate(n_samples)


GRAPH_FAMILIES = ("complete", "ring", "erdos_renyi", "exponential",
                  "torus", "small_world", "hierarchical")


def underlying_graph(kind: str, m: int, seed: int = 0, *, er_q: float = 0.5,
                     torus_rows: int = 0, torus_cols: int = 0,
                     ws_k: int = 4, ws_beta: float = 0.2,
                     hier_silos: int = 0, hier_intra: str = "complete",
                     hier_inter: str = "ring") -> np.ndarray:
    """Adjacency of a named graph family (the scenario library's graph
    constructor; graph randomness derives from `seed`, not a shared rng)."""
    if kind == "complete":
        return complete_graph(m)
    if kind == "ring":
        return ring_graph(m)
    if kind == "erdos_renyi":
        return erdos_renyi_graph(m, er_q, np.random.default_rng(seed + 777))
    if kind == "exponential":
        return exponential_graph(m)
    if kind == "torus":
        return torus_graph(m, torus_rows, torus_cols)
    if kind == "small_world":
        return watts_strogatz_graph(m, ws_k, ws_beta,
                                    np.random.default_rng(seed + 777))
    if kind == "hierarchical":
        return hierarchical_graph(m, hier_silos, hier_intra, hier_inter,
                                  seed)
    raise ValueError(f"unknown graph family {kind!r}; "
                     f"known: {GRAPH_FAMILIES}")


def make_topology(kind: str, m: int, p: float, seed: int = 0,
                  er_q: float = 0.5, **graph_kw) -> Topology:
    adj = underlying_graph(kind, m, seed, er_q=er_q, **graph_kw)
    return Topology(adj=adj, p=p, seed=seed)


# ---------------------------------------------------------------------------
# Topology-aware switching interval (the paper's headline formula)
# ---------------------------------------------------------------------------

def optimal_switching_interval(rho: float, *, c: float = 1.0,
                               t_min: int = 1, t_max: int = 64) -> int:
    """T*(ρ) ≍ c/√(1−ρ)  (Theorem V.3 / Corollary A.9)."""
    gap = max(1.0 - rho, 1e-6)
    t = int(round(c / np.sqrt(gap)))
    return int(np.clip(t, t_min, t_max))


def optimal_switching_interval_edge_activation(
        p: float, lam2: float, *, c: float = 1.0, c_mix: float = 0.5,
        t_min: int = 1, t_max: int = 64) -> int:
    """T*(p, L) ≍ c/√(p·λ2(L))  (Corollary A.11): 1−ρ ≥ c_mix·p·λ2(L)."""
    gap = max(c_mix * p * lam2, 1e-6)
    t = int(round(c / np.sqrt(gap)))
    return int(np.clip(t, t_min, t_max))
