"""LoRA parameter trees (the paper's adaptation recipe).

ΔW = a @ b with a: (d_in, r) ~ N(0, 1/d_in), b: (r, d_out) = 0
(so ΔW = 0 at init), scale = alpha / r. Target leaves (paper: attention Q/V;
extended per DESIGN.md §4 to the recurrent blocks' projections):

  wq, wv          — attention / cross-attention / mLSTM q,v projections
  w_in_x, w_out   — RG-LRU in/out projections
  w_gates         — sLSTM gate projection

A LoRA tree mirrors the params tree at targeted leaves only. With
``n_clients`` set, every a/b leaf gains a client axis at position -3:
  group-stacked leaves  (G, d_in, d_out)  ->  a: (G, m, d_in, r)
  plain leaves          (d_in, d_out)     ->  a: (m, d_in, r)
so gossip mixing is uniformly an einsum over axis -3 (core.mixing).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical

_RECURRENT_TARGETS = ("w_in_x", "w_out", "w_gates")


def target_names(cfg: ModelConfig) -> frozenset[str]:
    return frozenset(cfg.lora_targets) | frozenset(_RECURRENT_TARGETS)


def build_lora_tree(key, params, cfg: ModelConfig,
                    n_clients: Optional[int] = None,
                    dtype=jnp.float32) -> dict:
    """LoRA tree mirroring ``params`` at targeted leaves."""
    targets = target_names(cfg)
    r = cfg.lora_rank
    counter = [0]

    def make_ab(leaf):
        d_in, d_out = leaf.shape[-2:]
        lead = leaf.shape[:-2]
        m = (n_clients,) if n_clients else ()
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        a = jax.random.normal(k, (*lead, d_in, r)) / jnp.sqrt(d_in)
        if m:
            # identical init across clients (shared global starting point)
            a = jnp.broadcast_to(a[..., None, :, :],
                                 (*lead, *m, d_in, r)).copy()
        b = jnp.zeros((*lead, *m, r, d_out))
        return {"a": a.astype(dtype), "b": b.astype(dtype)}

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, (dict, list, tuple)):
                    sub = walk(v)
                    if sub is not None:
                        out[k] = sub
                elif k in targets and hasattr(v, "ndim") and v.ndim >= 2:
                    out[k] = make_ab(v)
            return out or None
        if isinstance(node, (list, tuple)):
            subs = [walk(v) for v in node]
            return list(subs) if any(s is not None for s in subs) else None
        return None

    tree = walk(params)
    return tree if tree is not None else {}


def lora_specs(params_specs, cfg: ModelConfig,
               n_clients: Optional[int] = None, dtype=jnp.float32):
    """ShapeDtypeStruct LoRA tree (dry-run, no allocation)."""
    return jax.eval_shape(
        lambda: build_lora_tree(jax.random.key(0), params_specs, cfg,
                                n_clients, dtype))


def param_count(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))


def shard_lora_tree(lora):
    """Apply logical sharding constraints: client axis over "clients",
    d_in/d_out over "model" (rank never sharded)."""
    def one(leaf):
        if leaf.ndim == 4:        # (G, m, d, r) or (G, m, r, d)
            names = (None, "clients", "model", None) if leaf.shape[-1] <= 64 \
                else (None, "clients", None, "model")
        elif leaf.ndim == 3:      # (m, d, r) / (m, r, d)
            names = ("clients", "model", None) if leaf.shape[-1] <= 64 \
                else ("clients", None, "model")
        else:
            names = (None,) * leaf.ndim
        return logical(leaf, *names)
    return jax.tree.map(one, lora)


def client_slice(lora, i: int):
    """Extract client i's LoRA tree (client axis at -3)."""
    return jax.tree.map(lambda x: x[..., i, :, :], lora)


def client_mean(lora):
    """Average over the client axis (the ideal 'consensus model')."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=-3), lora)


def merge_lora(params, lora, cfg: ModelConfig):
    """Fold ΔW = scale * a@b into the base weights (single-client tree).
    Returns a new params tree; used for serving a fine-tuned model."""
    scale = cfg.lora_alpha / cfg.lora_rank

    def walk(p_node, l_node):
        if l_node is None:
            return p_node
        if isinstance(p_node, dict):
            out = {}
            for k, v in p_node.items():
                lk = l_node.get(k) if isinstance(l_node, dict) else None
                if (isinstance(lk, dict) and "a" in lk and "b" in lk
                        and not isinstance(v, dict)):
                    delta = jnp.einsum("...dr,...rf->...df", lk["a"], lk["b"])
                    out[k] = (v + scale * delta).astype(v.dtype)
                elif isinstance(v, (dict, list)):
                    out[k] = walk(v, lk)
                else:
                    out[k] = v
            return out
        if isinstance(p_node, list):
            ln = l_node if isinstance(l_node, list) else [None] * len(p_node)
            return [walk(v, ln[i] if i < len(ln) else None)
                    for i, v in enumerate(p_node)]
        return p_node

    return walk(params, lora)
