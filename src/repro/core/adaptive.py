"""Beyond-paper: ONLINE topology-aware switching-interval selection.

The paper selects T in hindsight and names adaptive selection as future
work (§VII: "adaptive switching policies that adjust T online based on
communication conditions"). This module closes that gap with two
estimators that need no oracle access:

1. **Spectral estimator** — each round the realized mixing matrix W_t is
   known to every client's runtime (it is the communication schedule that
   actually executed). Maintain an EWMA of ||W_t − J||₂² → ρ̂², and set
   T ← clip(c/√(1−ρ̂)) at phase boundaries (Theorem V.3).

2. **Consensus-probe estimator** — when W_t itself is not observable
   (e.g. lossy links), track the contraction of the *frozen block's*
   disagreement Δ² between consecutive rounds: Lemma A.4 says the frozen
   block contracts at exactly ρ² per round, so the measured ratio is an
   unbiased ρ̂² probe that costs one norm per round.

Both update T only at phase boundaries (changing T mid-phase would
desynchronize clients' phase calendars — the instability the paper's
Alg. 1 exists to avoid).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def spectral_rho_sq_update(rho_sq: float, W: np.ndarray,
                           ewma: float) -> float:
    """One spectral-estimator step: EWMA of ||W_t − J||₂² into ρ̂².
    Shared by `AdaptiveTController.observe_mixing_matrix` and the control
    plane's `SpectralRho` (repro.control.estimators) so both routes are
    float-identical."""
    m = W.shape[0]
    J = np.ones((m, m)) / m
    s2 = float(np.linalg.norm(W - J, ord=2) ** 2)
    return (1 - ewma) * rho_sq + ewma * s2


def contraction_rho_sq_update(rho_sq: float, delta_sq_prev: float,
                              delta_sq_now: float, ewma: float) -> float:
    """One consensus-probe step (Lemma A.4): the frozen block's Δ²
    contracts at ρ² per round, so the clipped ratio of consecutive Δ² is
    a ρ̂² sample. A vanishing previous Δ² (consensus already reached, or
    the probe just reset) carries no signal — the estimate is returned
    unchanged."""
    if delta_sq_prev > 1e-12:
        ratio = min(max(delta_sq_now / delta_sq_prev, 0.0), 1.0)
        return (1 - ewma) * rho_sq + ewma * ratio
    return rho_sq


@dataclass
class AdaptiveTController:
    c: float = 1.0                  # T*(ρ) = c/√(1−ρ)
    ewma: float = 0.2               # smoothing for ρ̂²
    t_min: int = 1
    t_max: int = 32
    T: int = 1                      # current interval
    rho_sq: float = 0.5             # running estimate of ρ²
    _round_in_phase: int = field(default=0, repr=False)
    _phase_parity: int = field(default=0, repr=False)

    # -- estimators ---------------------------------------------------------
    def observe_mixing_matrix(self, W: np.ndarray) -> None:
        """Spectral estimator: ρ̂² ← EWMA of ||W_t − J||₂²."""
        self.rho_sq = spectral_rho_sq_update(self.rho_sq, W, self.ewma)

    def observe_frozen_contraction(self, delta_sq_prev: float,
                                   delta_sq_now: float) -> None:
        """Consensus-probe estimator (Lemma A.4): frozen-block Δ² contracts
        at ρ² per gossip round."""
        self.rho_sq = contraction_rho_sq_update(
            self.rho_sq, delta_sq_prev, delta_sq_now, self.ewma)

    # -- schedule -----------------------------------------------------------
    def target_T(self) -> int:
        gap = max(1.0 - np.sqrt(self.rho_sq), 1e-6)
        return int(np.clip(round(self.c / np.sqrt(gap)),
                           self.t_min, self.t_max))

    def step(self) -> tuple[bool, int]:
        """Advance one round. Returns (is_A_phase, current_T). T updates
        ONLY at phase boundaries (paper Alg. 1: B-phase first)."""
        if self._round_in_phase >= self.T:
            self._phase_parity ^= 1
            self._round_in_phase = 0
            self.T = self.target_T()
        self._round_in_phase += 1
        return bool(self._phase_parity), self.T


def adaptive_round_masks(ctrl: AdaptiveTController, method: str = "tad"):
    """RoundMasks from the controller (drop-in for alternating.round_masks)."""
    from repro.core.alternating import RoundMasks
    is_a, _ = ctrl.step()
    ph = 1.0 if is_a else 0.0
    if method == "tad":
        return RoundMasks(ph, 1.0 - ph, 1.0, 1.0)
    if method == "rolora":
        return RoundMasks(ph, 1.0 - ph, ph, 1.0 - ph)
    raise ValueError(f"adaptive schedule only applies to alternating "
                     f"methods, got {method!r}")
