"""Gossip mixing of stacked client LoRA states (Algorithm 1, lines 7-9).

Every LoRA leaf carries the client axis at position -3 (see core.lora), so
mixing is uniformly  x'_i = Σ_j (W_t)_ij x_j  — an einsum contracting that
axis. Under the production mesh the client axis is sharded over
("pod","data"), so this einsum *is* the paper's communication step, lowered
by GSPMD to collectives over the client axis.

``mix_masks`` lets one compiled step express all four paper methods: a leaf
is mixed when its mask is 1, left untouched when 0 (traced scalars, so the
method/phase never triggers recompilation).

Four lowerings, equal numerics (bit-for-bit at binary masks):
  mix_tree         — per-leaf einsum + blend (the oracle; one collective
                     per leaf under GSPMD).
  mix_tree_concat  — legacy fused variant: re-derives the flatten layout
                     from tree paths on every call.
  mix_tree_planned — the default fast path: a MixPlan (built once per
                     treedef/shape signature, cached) precomputes per-leaf
                     offsets, the padded (m, P) layout aligned to the
                     gossip_mix kernel's bp stripe, and the a/b column
                     segment indicator, so the per-round work is one
                     gather into the flat buffer, ONE gossip_mix_seg call
                     (one collective under GSPMD, unequal masks folded
                     into the per-segment W_eff), and one unflatten — no
                     per-round Python tree traversal.
  mix_tree_sparse  — the cluster communication lowering
                     (`mix_comm="sparse"/"sparse_overlap"`): the same
                     MixPlan flat layout, but the cross-process exchange
                     moves ONLY the rows the topology's support couples
                     (a `repro.dist.comm.CommPlan`), inside one
                     shard_map region — one small halo all-gather per
                     round instead of per-leaf full-axis all-gathers.
                     Missing rows stay zero and meet exact-zero W
                     entries, so the sparse result equals the dense
                     contraction bit-for-bit. With ``lora_prev`` the
                     off-diagonal terms read the PREVIOUS round's state
                     (one-round-delayed/overlapped gossip, DeCAF-style):
                     the halo has no data dependency on this round's
                     local steps, so XLA can overlap communication with
                     compute; only the diagonal stays fresh, making the
                     semantics independent of the process count.

Compressed gossip (``quant`` on the sparse lowerings): the exchanged
source rows are quantized per row to int8 (or fp8) with one f32 scale per
row — the halo then moves ~1/4 of the fp32 bytes — while each client's own
diagonal contribution stays full precision. A per-client error-feedback
accumulator (EF21-style) carries the quantization residual into the next
round's payload, e_j' = (x_j + e_j) − Q(x_j + e_j), so the compression
noise stays summable and the consensus contraction survives (asserted
against the Lemma A.10 budget in the conformance tier). Quantization is
per-row and the degenerate path quantizes ALL off-diagonal sources, so
single- and multi-process runs still agree bit-for-bit.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def mix_leaf(W: jax.Array, leaf: jax.Array) -> jax.Array:
    """leaf: (..., m, d0, d1); W: (m, m)."""
    return jnp.einsum("ij,...jdr->...idr", W.astype(leaf.dtype), leaf)


def _leaf_mask_name(path) -> str:
    """The a/b factor name of a LoRA leaf path. Any other leaf name is a
    malformed tree — silently mixing it with mask_b (the historical
    fallback) hid real bugs, so it raises instead."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    if name not in ("a", "b"):
        raise ValueError(
            f"LoRA leaf {jax.tree_util.keystr(path)!r} is named {name!r}; "
            f"gossip mixing is defined for 'a'/'b' factor leaves only")
    return name


def mix_tree(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Gossip-mix the a-leaves with weight mask_a and b-leaves with mask_b.

    mask=1 -> fully mixed; mask=0 -> untouched (frozen-block no-mix, i.e.
    the RoLoRA baseline behaviour); fractional values interpolate (used by
    the beyond-paper damped-mixing variant).
    """
    def one(path, leaf):
        mask = mask_a if _leaf_mask_name(path) == "a" else mask_b
        mixed = mix_leaf(W, leaf)
        return (mask * mixed + (1.0 - mask) * leaf).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, lora)


def mix_tree_concat(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Beyond-paper lowering variant (§Perf): flatten all leaves into one
    (m, P) buffer, mix with a single matmul (one collective), then unflatten.
    Numerically identical to mix_tree when masks are equal; with unequal
    masks it falls back to per-leaf masking after the fused mix."""
    leaves, treedef = jax.tree_util.tree_flatten(lora)
    m = leaves[0].shape[-3]

    def to2d(x):
        # (..., m, d0, d1) -> (m, prod(lead)*d0*d1)
        x = jnp.moveaxis(x, -3, 0)
        return x.reshape(m, -1)

    flat = jnp.concatenate([to2d(x) for x in leaves], axis=1)
    mixed_flat = W.astype(flat.dtype) @ flat

    out, off = [], 0
    paths = jax.tree_util.tree_flatten_with_path(lora)[0]
    for (path, leaf) in paths:
        n = leaf.size // m
        chunk = mixed_flat[:, off:off + n]
        off += n
        lead = leaf.shape[:-3]
        restored = chunk.reshape(m, *lead, *leaf.shape[-2:])
        restored = jnp.moveaxis(restored, 0, len(lead))
        mask = mask_a if _leaf_mask_name(path) == "a" else mask_b
        out.append((mask * restored + (1.0 - mask) * leaf).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ===========================================================================
# Planned fused mixing (the default fast path)
# ===========================================================================

_KERNEL_BP = 512    # gossip_mix stripe width the flat buffer is padded to


@dataclass(frozen=True)
class _LeafSlot:
    """Static placement of one LoRA leaf inside the flat (m, P) buffer."""
    offset: int          # first column
    cols: int            # columns per client (= leaf.size / m)
    lead: tuple          # leading (group-stack) dims before the client axis
    tail: tuple          # trailing (d0, d1) dims
    is_a: bool           # "a" leaf -> mask_a segment, else mask_b


@dataclass(frozen=True)
class MixPlan:
    """Precomputed flatten plan for one LoRA tree structure.

    Built once per (treedef, leaf shapes/dtypes, bp) signature — see
    ``get_mix_plan`` — and reused for every round on that structure, so
    the per-round path never walks tree paths or re-derives offsets.
    ``a_indicator`` is the (1, padded) column-segment constant that folds
    unequal a/b masks into the kernel's per-segment W_eff.
    """
    m: int               # clients
    cols: int            # total columns per client (unpadded)
    padded: int          # cols rounded up to a multiple of bp
    bp: int
    slots: tuple         # tuple[_LeafSlot, ...] in tree-flatten order
    treedef: Any
    a_indicator: np.ndarray   # (1, padded) float32; 1.0 on "a" columns

    def segment_mask(self, mask_a, mask_b):
        """(1, padded) per-column blend mask from the two scalar masks."""
        ind = self.a_indicator
        return mask_a * ind + mask_b * (1.0 - ind)


# LRU-bounded plan cache: keyed on treedef/shape signatures, which a
# long-lived serving process can churn through indefinitely (every new
# adapter-pool layout is a fresh key) — unbounded growth was a leak.
_PLAN_CACHE: "OrderedDict" = OrderedDict()
_PLAN_CACHE_MAX = 64
_PLAN_BUILDS = [0]


def plan_builds() -> int:
    """How many MixPlans have been constructed (test/diagnostic hook)."""
    return _PLAN_BUILDS[0]


def clear_mix_plans() -> None:
    """Drop every cached MixPlan (long-lived processes, tests)."""
    _PLAN_CACHE.clear()


def build_mix_plan(lora, *, bp: int = _KERNEL_BP) -> MixPlan:
    """Walk the tree ONCE: record each leaf's slot and the a/b segments."""
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(lora)
    if not leaves_p:
        raise ValueError("empty LoRA tree")
    m = leaves_p[0][1].shape[-3]
    slots, ind_parts = [], []
    off = 0
    for path, leaf in leaves_p:
        name = _leaf_mask_name(path)
        cols = math.prod(leaf.shape) // m
        slots.append(_LeafSlot(offset=off, cols=cols,
                               lead=tuple(leaf.shape[:-3]),
                               tail=tuple(leaf.shape[-2:]),
                               is_a=(name == "a")))
        ind_parts.append(np.full(cols, 1.0 if name == "a" else 0.0,
                                 np.float32))
        off += cols
    padded = off + ((-off) % bp)
    if padded > off:
        ind_parts.append(np.zeros(padded - off, np.float32))
    _PLAN_BUILDS[0] += 1
    return MixPlan(m=m, cols=off, padded=padded, bp=bp, slots=tuple(slots),
                   treedef=treedef,
                   a_indicator=np.concatenate(ind_parts)[None, :])


def get_mix_plan(lora, *, bp: int = _KERNEL_BP) -> MixPlan:
    """Cached ``build_mix_plan`` keyed on the tree's static signature."""
    leaves, treedef = jax.tree_util.tree_flatten(lora)
    key = (treedef, bp,
           tuple((tuple(x.shape), jnp.dtype(x.dtype).name) for x in leaves))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _PLAN_CACHE[key] = build_mix_plan(lora, bp=bp)
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)      # evict least-recently-used
    else:
        _PLAN_CACHE.move_to_end(key)
    return plan


_FLAT_LOWERING_MODES = ("auto", "flat", "per_segment")
_flat_lowering_mode = "auto"


def set_flat_lowering(mode: str) -> str:
    """Set the process-default flat-lowering mode; returns the previous.

    "flat"        — always flatten into the single (m, P) gossip_mix buffer
    "per_segment" — always keep the plan's per-slot W_eff dots
    "auto"        — flat on TPU backends only (default). GSPMD emits an
                    involuntary-full-remat warning on the chunk reshape of
                    the flat buffer (ROADMAP open item), and off-TPU the
                    two full-buffer copies dominate the cache-resident
                    per-slot dots (~4x, BENCH_mixing.json) — so the flat
                    path is gated to TPU meshes by default.
    """
    global _flat_lowering_mode
    if mode not in _FLAT_LOWERING_MODES:
        raise ValueError(f"unknown flat-lowering mode {mode!r}; "
                         f"known: {_FLAT_LOWERING_MODES}")
    prev, _flat_lowering_mode = _flat_lowering_mode, mode
    return prev


def flat_lowering_mode() -> str:
    return _flat_lowering_mode


def use_flat_lowering(mode: Optional[str] = None) -> bool:
    """Resolve a mode (None -> the process default) to a concrete choice."""
    mode = mode if mode is not None else _flat_lowering_mode
    if mode == "flat":
        return True
    if mode == "per_segment":
        return False
    if mode != "auto":
        raise ValueError(f"unknown flat-lowering mode {mode!r}; "
                         f"known: {_FLAT_LOWERING_MODES}")
    return jax.default_backend() == "tpu"


# backwards-compat alias (benchmarks/tests of earlier PRs)
_use_flat_lowering = use_flat_lowering


def mix_tree_planned(W: jax.Array, lora, mask_a, mask_b, *,
                     plan: Optional[MixPlan] = None,
                     flat_lowering: Optional[str] = None):
    """Plan-cached fused mixing (the default fast path).

    Masks are folded into per-segment effective mixing matrices
    W_eff = mask·W + (1−mask)·I — the blend never touches the (m, P)
    payload as a separate pass. Under a mesh (or on TPU) the whole tree is
    mixed by ONE gossip_mix_seg kernel call / ONE collective on the
    plan's padded flat layout; otherwise each slot is a single dot with
    its segment's W_eff. Numerically equal to mix_tree for all masks and
    bit-for-bit at equal masks (W_eff reduces to W exactly).

    ``flat_lowering`` pins the buffer lowering for this call ("flat" /
    "per_segment" / "auto"); None defers to ``set_flat_lowering``'s
    process default (auto: flat on TPU only).
    """
    plan = plan if plan is not None else get_mix_plan(lora)
    leaves = jax.tree_util.tree_leaves(lora)
    m = plan.m

    if use_flat_lowering(flat_lowering):
        parts = [jnp.moveaxis(x, -3, 0).reshape(m, -1) for x in leaves]
        if plan.padded > plan.cols:
            parts.append(jnp.zeros((m, plan.padded - plan.cols),
                                   parts[0].dtype))
        flat = jnp.concatenate(parts, axis=1)
        seg = plan.segment_mask(mask_a, mask_b).astype(flat.dtype)
        mixed = ops.gossip_mix_seg(W.astype(flat.dtype), flat, seg)
        out = []
        for slot, leaf in zip(plan.slots, leaves):
            chunk = mixed[:, slot.offset:slot.offset + slot.cols]
            restored = chunk.reshape(m, *slot.lead, *slot.tail)
            restored = jnp.moveaxis(restored, 0, len(slot.lead))
            out.append(restored.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(plan.treedef, out)

    # cache-local lowering: two (m, m) W_eff folds per round, then one
    # blend-free dot per slot (is_a is plan-static — no path inspection)
    eye = jnp.eye(m, dtype=W.dtype)
    w_a = mask_a * W + (1.0 - mask_a) * eye
    w_b = mask_b * W + (1.0 - mask_b) * eye
    out = [
        jnp.einsum("ij,...jdr->...idr",
                   (w_a if slot.is_a else w_b).astype(leaf.dtype),
                   leaf).astype(leaf.dtype)
        for slot, leaf in zip(plan.slots, leaves)
    ]
    return jax.tree_util.tree_unflatten(plan.treedef, out)


# ===========================================================================
# Sparse (neighbor-only) gossip lowering — repro.dist.comm.CommPlan
# ===========================================================================

def sparse_use_flat(mode: Optional[str] = None) -> bool:
    """Resolve the contraction lowering for the SPARSE comm path.

    Explicit "flat"/"per_segment" pin it; "auto"/None follow the dense
    planned path's backend heuristic (flat on TPU meshes, per-segment
    dots elsewhere). The plausible counter-argument — the sparse path
    assembles the flat (m, cols) buffer anyway for the halo exchange, so
    one fused (rows, m) @ (m, cols) dot should win everywhere — was
    MEASURED FALSE on CPU: the per-column seg blend of the flat
    contraction costs more than it saves over per-slot dots with scalar
    blends (~110us vs ~70us at the bench shape,
    BENCH_multihost.json's `sparse_lowering` probe), and inside a real
    distributed round either choice is <0.1% of round wall time. Pinned
    by tests/test_comm.py::test_sparse_lowering_auto_pins_flat (flat
    exactly where the fused gossip kernel lives — TPU).
    """
    mode = mode if mode is not None else flat_lowering_mode()
    if mode == "flat":
        return True
    if mode == "per_segment":
        return False
    if mode != "auto":
        raise ValueError(f"unknown flat-lowering mode {mode!r}; "
                         f"known: {_FLAT_LOWERING_MODES}")
    return jax.default_backend() == "tpu"


def _flat_buffer(leaves, m: int):
    """(m, cols) unpadded flat view of the stacked tree (plan layout).
    The sparse path skips the bp padding — it contracts with plain dots,
    not the stripe-aligned gossip_mix kernel, and the halo exchange
    should not ship padding bytes."""
    return jnp.concatenate(
        [jnp.moveaxis(x, -3, 0).reshape(m, -1) for x in leaves], axis=1)


# ---------------------------------------------------------------------------
# compressed gossip: per-row quantization + error feedback
# ---------------------------------------------------------------------------

MIX_QUANT_MODES = ("off", "int8", "fp8")


def _quant_spec(quant: str):
    """(payload dtype, max representable magnitude) of a quant mode."""
    if quant == "int8":
        return jnp.int8, 127.0
    if quant == "fp8":
        return jnp.float8_e4m3fn, 448.0
    raise ValueError(f"unknown mix quant mode {quant!r}; "
                     f"known: {MIX_QUANT_MODES}")


def quantize_rows(x: jax.Array, quant: str):
    """Per-row scaled quantization of a (rows, cols) buffer.

    Returns (q, scale): q is int8 (round-to-nearest, clipped symmetric)
    or fp8 (e4m3) with one f32 ``scale`` per row chosen so the row's max
    magnitude maps to the top of the representable range. All-zero rows
    quantize to zeros under scale 1 (no 0/0). Row-independent by
    construction, so per-shard quantization of a block equals the global
    quantization of those rows — the property the bitwise grid-parity of
    `mix_tree_sparse` rests on.
    """
    dtype, qmax = _quant_spec(quant)
    x32 = x.astype(jnp.float32)
    rowmax = jnp.max(jnp.abs(x32), axis=1, keepdims=True)
    scale = jnp.where(rowmax > 0.0, rowmax / qmax, 1.0)
    y = x32 / scale
    if dtype == jnp.int8:
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = y.astype(dtype)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """f32 reconstruction of `quantize_rows` output: q * scale."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def _split_diag(w_rows, row0):
    """(w_off_rows, w_diag) of mixing rows [row0, row0+r): the diagonal
    coefficient per row, and the rows with the diagonal zeroed. Shared by
    the degenerate and shard_map paths so both reduce identically."""
    r, m = w_rows.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (r, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, m), 1)
    eye = (col == row + row0).astype(w_rows.dtype)
    w_diag = jnp.sum(w_rows * eye, axis=1, keepdims=True)
    return w_rows * (1.0 - eye), w_diag


def _sparse_contract(w_rows, x_rows, z, mask_a, mask_b, plan: MixPlan,
                     use_flat: bool, w_diag=None):
    """Blend-mixed rows from the exchanged source buffer.

    w_rows: (r, m) mixing rows (diagonal zeroed when w_diag is given);
    x_rows: (r, cols) fresh locally-owned rows; z: (m, cols) source rows
    (fresh for plain sparse, previous-round for overlap; rows outside the
    support are zero and meet exact-zero W entries). w_diag: (r, 1)
    diagonal coefficients applied to the FRESH rows (overlap mode).
    """
    if use_flat:
        mixed = w_rows @ z
        if w_diag is not None:
            mixed = w_diag * x_rows + mixed
        seg = plan.segment_mask(mask_a, mask_b)[:, :plan.cols]
        seg = seg.astype(x_rows.dtype)
        return seg * mixed + (1.0 - seg) * x_rows
    outs = []
    for slot in plan.slots:
        sl = slice(slot.offset, slot.offset + slot.cols)
        mask = mask_a if slot.is_a else mask_b
        mixed = w_rows @ z[:, sl]
        if w_diag is not None:
            mixed = w_diag * x_rows[:, sl] + mixed
        outs.append(mask * mixed + (1.0 - mask) * x_rows[:, sl])
    return jnp.concatenate(outs, axis=1)


def _sparse_contract_quant(w_off, x_rows, zq, zscale, mask_a, mask_b,
                           plan: MixPlan, use_flat: bool, w_diag):
    """Blend-mixed rows from a QUANTIZED source buffer.

    w_off: (r, m) mixing rows with the diagonal zeroed; x_rows: (r, cols)
    fresh full-precision local rows; zq/zscale: the (m, cols)/(m, 1)
    quantized source rows + per-row scales (rows outside the support are
    zero and meet exact-zero W entries); w_diag: (r, 1) diagonal
    coefficients applied to the FRESH rows — the local contribution never
    pays quantization noise. The flat lowering fuses the dequantize into
    the `gossip_mix_quant` kernel sweep; per-segment dequantizes once and
    reuses the per-slot dots.
    """
    if use_flat:
        seg = plan.segment_mask(mask_a, mask_b)[:, :plan.cols]
        seg = jnp.asarray(seg).astype(x_rows.dtype)
        return ops.gossip_mix_quant(w_off, zq, zscale, x_rows, w_diag, seg)
    z = dequantize_rows(zq, zscale).astype(x_rows.dtype)
    return _sparse_contract(w_off, x_rows, z, mask_a, mask_b, plan,
                            use_flat=False, w_diag=w_diag)


def mix_tree_sparse(W: jax.Array, lora, mask_a, mask_b, *, comm_plan,
                    lora_prev=None, plan: Optional[MixPlan] = None,
                    flat_lowering: Optional[str] = None,
                    quant: str = "off", ef: Optional[jax.Array] = None):
    """Neighbor-only gossip mixing on the MixPlan flat layout.

    Without a bound multi-device mesh (or with a 1-shard ``comm_plan``)
    this is the degenerate local contraction — bit-for-bit what the
    distributed path computes, so single- and multi-process runs agree
    exactly. Under a bound cluster mesh whose size matches
    ``comm_plan.n_shards``, one shard_map region per round: each shard
    gathers its export rows, ONE all-gather moves the (n, k, cols) halo,
    rows scatter into a zero (m, cols) source buffer, and the shard's W
    rows contract against it. W entries outside the support are exact
    zeros (Metropolis construction), so zero-filled missing rows never
    contribute a bit of difference.

    ``lora_prev`` switches on one-round-delayed (overlapped) mixing: the
    exchanged/off-diagonal source rows come from the ROUND-INPUT state
    while each client's own (diagonal) contribution stays fresh —
    y_i = seg·(W_ii·post_i + Σ_{j≠i} W_ij·pre_j) + (1−seg)·post_i.
    The halo then has no data dependency on this round's local steps
    (XLA overlaps it with compute), and the semantics are independent of
    the process count — the staleness penalty is bounded against Lemma
    A.10 in the conformance tier, not swept under parity.

    ``quant`` ("off" | "int8" | "fp8") compresses the exchanged rows:
    every OFF-diagonal contribution reads the per-row-quantized source
    Q(src + ef) while the diagonal keeps the fresh full-precision rows,
    and ``ef`` — the (m, cols) f32 error-feedback accumulator, required
    when quant is on — is updated to the new residual. Quantized calls
    return ``(mixed_tree, ef_new)`` instead of the tree alone. The
    degenerate and distributed paths quantize identically (per-row), so
    grid parity stays bitwise.
    """
    from repro.dist import sharding as _sharding
    plan = plan if plan is not None else get_mix_plan(lora)
    leaves = jax.tree_util.tree_leaves(lora)
    m = plan.m
    use_flat = sparse_use_flat(flat_lowering)
    if quant not in MIX_QUANT_MODES:
        raise ValueError(f"unknown mix quant mode {quant!r}; "
                         f"known: {MIX_QUANT_MODES}")
    if quant != "off" and ef is None:
        raise ValueError("quantized mixing needs the (m, cols) f32 "
                         "error-feedback accumulator (ef=...)")

    flat = _flat_buffer(leaves, m)
    prev_flat = None
    if lora_prev is not None:
        prev_flat = _flat_buffer(jax.tree_util.tree_leaves(lora_prev), m)

    mesh = _sharding.current_mesh()
    ef_new = None
    if mesh is not None and mesh.size > 1 and comm_plan is not None:
        # a mesh/plan mismatch used to fall through to the degenerate
        # local contraction: parity held but every byte saving silently
        # vanished — refuse instead of degrading
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"mix_tree_sparse: the sparse comm lowering needs a 1-D "
                f"mesh over the client axis; bound mesh has axes "
                f"{mesh.axis_names}")
        if comm_plan.n_shards != mesh.size:
            raise ValueError(
                f"mix_tree_sparse: comm_plan was compiled for "
                f"{comm_plan.n_shards} shards but the bound mesh has "
                f"{mesh.size} devices — rebuild the CommPlan for this "
                f"grid (the degenerate fallback would silently all-gather "
                f"nothing and drop the sparse savings)")
        distributed = True
    else:
        distributed = False
    if distributed:
        res = _exchange_and_mix(W, flat, prev_flat, mask_a, mask_b,
                                plan, comm_plan, mesh, use_flat,
                                quant=quant, ef=ef)
        mixed, ef_new = res if quant != "off" else (res, None)
    else:
        w_rows = W.astype(flat.dtype)
        if quant != "off":
            src = prev_flat if prev_flat is not None else flat
            s = src.astype(jnp.float32) + ef
            q, scale = quantize_rows(s, quant)
            ef_new = s - dequantize_rows(q, scale)
            w_off, w_diag = _split_diag(w_rows, 0)
            mixed = _sparse_contract_quant(w_off, flat, q, scale, mask_a,
                                           mask_b, plan, use_flat, w_diag)
        elif prev_flat is not None:
            w_rows, w_diag = _split_diag(w_rows, 0)
            mixed = _sparse_contract(w_rows, flat, prev_flat, mask_a,
                                     mask_b, plan, use_flat, w_diag)
        else:
            mixed = _sparse_contract(w_rows, flat, flat, mask_a, mask_b,
                                     plan, use_flat)

    out = []
    for slot, leaf in zip(plan.slots, leaves):
        chunk = mixed[:, slot.offset:slot.offset + slot.cols]
        restored = chunk.reshape(m, *slot.lead, *slot.tail)
        restored = jnp.moveaxis(restored, 0, len(slot.lead))
        out.append(restored.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(plan.treedef, out)
    if quant != "off":
        return tree, ef_new
    return tree


def _exchange_and_mix(W, flat, prev_flat, mask_a, mask_b, plan: MixPlan,
                      cp, mesh, use_flat: bool, *, quant: str = "off",
                      ef=None):
    """The distributed body: halo exchange + contraction in ONE shard_map
    region, so the per-process divergent intermediates (export rows, the
    reconstruction buffer) never exist as replicated-but-different global
    arrays. Output rows are client-sharded, matching the round's layout.

    With ``quant`` on, each shard quantizes its source block (src + ef,
    per row) BEFORE the exchange: the halo all-gather moves the 1-byte
    payload rows plus one f32 scale per row — the wire compression — and
    every shard dequantizes the reconstruction buffer identically. The
    fresh local rows feed only the diagonal term. Returns
    (mixed, ef_new_block) when quantizing, both client-sharded."""
    axis = mesh.axis_names[0]
    n, m, m_loc, k = cp.n_shards, cp.m, cp.m_loc, cp.k
    exp_local = jnp.asarray(cp.export_local)      # (n, k) int32
    exp_global = jnp.asarray(cp.export_global)    # (n*k,) int32
    overlap = prev_flat is not None
    quantized = quant != "off"

    def body(w, x_blk, ma, mb, *rest):
        pid = jax.lax.axis_index(axis)
        rest = list(rest)
        src_blk = rest.pop(0) if overlap else x_blk  # rows this shard offers
        cols = x_blk.shape[-1]
        w_rows = jax.lax.dynamic_slice(w, (pid * m_loc, 0), (m_loc, m))
        if quantized:
            ef_blk = rest.pop(0)
            s_blk = src_blk.astype(jnp.float32) + ef_blk
            q_blk, sc_blk = quantize_rows(s_blk, quant)
            ef_new = s_blk - dequantize_rows(q_blk, sc_blk)
            zq = jnp.zeros((m, cols), q_blk.dtype)
            zs = jnp.zeros((m, 1), jnp.float32)
            if k > 0:
                # the compressed wire payload: 1-byte rows + f32 scales
                halo_q = jax.lax.all_gather(
                    jnp.take(q_blk, exp_local[pid], axis=0), axis)
                halo_s = jax.lax.all_gather(
                    jnp.take(sc_blk, exp_local[pid], axis=0), axis)
                zq = zq.at[exp_global].set(halo_q.reshape(n * k, -1))
                zs = zs.at[exp_global].set(halo_s.reshape(n * k, 1))
            zq = jax.lax.dynamic_update_slice(zq, q_blk, (pid * m_loc, 0))
            zs = jax.lax.dynamic_update_slice(zs, sc_blk, (pid * m_loc, 0))
            w_off, w_diag = _split_diag(w_rows, pid * m_loc)
            mixed = _sparse_contract_quant(w_off, x_blk, zq, zs, ma, mb,
                                           plan, use_flat, w_diag)
            return mixed, ef_new
        z = jnp.zeros((m, cols), x_blk.dtype)
        if k > 0:
            exp = jnp.take(src_blk, exp_local[pid], axis=0)   # (k, cols)
            halo = jax.lax.all_gather(exp, axis)              # (n, k, cols)
            z = z.at[exp_global].set(halo.reshape(n * k, -1))
        z = jax.lax.dynamic_update_slice(z, src_blk, (pid * m_loc, 0))
        w_diag = None
        if overlap:
            w_rows, w_diag = _split_diag(w_rows, pid * m_loc)
        return _sparse_contract(w_rows, x_blk, z, ma, mb, plan, use_flat,
                                w_diag)

    in_specs = [P(), P(axis, None), P(), P()]
    args = [W.astype(flat.dtype), flat, mask_a, mask_b]
    if overlap:
        in_specs.append(P(axis, None))
        args.append(prev_flat)
    if quantized:
        in_specs.append(P(axis, None))
        args.append(ef)
    out_specs = (P(axis, None), P(axis, None)) if quantized \
        else P(axis, None)
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=out_specs, check_rep=False)
    return fn(*args)
