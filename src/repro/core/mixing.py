"""Gossip mixing of stacked client LoRA states (Algorithm 1, lines 7-9).

Every LoRA leaf carries the client axis at position -3 (see core.lora), so
mixing is uniformly  x'_i = Σ_j (W_t)_ij x_j  — an einsum contracting that
axis. Under the production mesh the client axis is sharded over
("pod","data"), so this einsum *is* the paper's communication step, lowered
by GSPMD to collectives over the client axis.

``mix_masks`` lets one compiled step express all four paper methods: a leaf
is mixed when its mask is 1, left untouched when 0 (traced scalars, so the
method/phase never triggers recompilation).

Three lowerings, equal numerics:
  mix_tree         — per-leaf einsum + blend (the oracle; one collective
                     per leaf under GSPMD).
  mix_tree_concat  — legacy fused variant: re-derives the flatten layout
                     from tree paths on every call.
  mix_tree_planned — the default fast path: a MixPlan (built once per
                     treedef/shape signature, cached) precomputes per-leaf
                     offsets, the padded (m, P) layout aligned to the
                     gossip_mix kernel's bp stripe, and the a/b column
                     segment indicator, so the per-round work is one
                     gather into the flat buffer, ONE gossip_mix_seg call
                     (one collective under GSPMD, unequal masks folded
                     into the per-segment W_eff), and one unflatten — no
                     per-round Python tree traversal.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops


def mix_leaf(W: jax.Array, leaf: jax.Array) -> jax.Array:
    """leaf: (..., m, d0, d1); W: (m, m)."""
    return jnp.einsum("ij,...jdr->...idr", W.astype(leaf.dtype), leaf)


def mix_tree(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Gossip-mix the a-leaves with weight mask_a and b-leaves with mask_b.

    mask=1 -> fully mixed; mask=0 -> untouched (frozen-block no-mix, i.e.
    the RoLoRA baseline behaviour); fractional values interpolate (used by
    the beyond-paper damped-mixing variant).
    """
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        mask = mask_a if name == "a" else mask_b
        mixed = mix_leaf(W, leaf)
        return (mask * mixed + (1.0 - mask) * leaf).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, lora)


def mix_tree_concat(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Beyond-paper lowering variant (§Perf): flatten all leaves into one
    (m, P) buffer, mix with a single matmul (one collective), then unflatten.
    Numerically identical to mix_tree when masks are equal; with unequal
    masks it falls back to per-leaf masking after the fused mix."""
    leaves, treedef = jax.tree_util.tree_flatten(lora)
    m = leaves[0].shape[-3]

    def to2d(x):
        # (..., m, d0, d1) -> (m, prod(lead)*d0*d1)
        x = jnp.moveaxis(x, -3, 0)
        return x.reshape(m, -1)

    flat = jnp.concatenate([to2d(x) for x in leaves], axis=1)
    mixed_flat = W.astype(flat.dtype) @ flat

    out, off = [], 0
    paths = jax.tree_util.tree_flatten_with_path(lora)[0]
    for (path, leaf) in paths:
        n = leaf.size // m
        chunk = mixed_flat[:, off:off + n]
        off += n
        lead = leaf.shape[:-3]
        restored = chunk.reshape(m, *lead, *leaf.shape[-2:])
        restored = jnp.moveaxis(restored, 0, len(lead))
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        mask = mask_a if name == "a" else mask_b
        out.append((mask * restored + (1.0 - mask) * leaf).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ===========================================================================
# Planned fused mixing (the default fast path)
# ===========================================================================

_KERNEL_BP = 512    # gossip_mix stripe width the flat buffer is padded to


@dataclass(frozen=True)
class _LeafSlot:
    """Static placement of one LoRA leaf inside the flat (m, P) buffer."""
    offset: int          # first column
    cols: int            # columns per client (= leaf.size / m)
    lead: tuple          # leading (group-stack) dims before the client axis
    tail: tuple          # trailing (d0, d1) dims
    is_a: bool           # "a" leaf -> mask_a segment, else mask_b


@dataclass(frozen=True)
class MixPlan:
    """Precomputed flatten plan for one LoRA tree structure.

    Built once per (treedef, leaf shapes/dtypes, bp) signature — see
    ``get_mix_plan`` — and reused for every round on that structure, so
    the per-round path never walks tree paths or re-derives offsets.
    ``a_indicator`` is the (1, padded) column-segment constant that folds
    unequal a/b masks into the kernel's per-segment W_eff.
    """
    m: int               # clients
    cols: int            # total columns per client (unpadded)
    padded: int          # cols rounded up to a multiple of bp
    bp: int
    slots: tuple         # tuple[_LeafSlot, ...] in tree-flatten order
    treedef: Any
    a_indicator: np.ndarray   # (1, padded) float32; 1.0 on "a" columns

    def segment_mask(self, mask_a, mask_b):
        """(1, padded) per-column blend mask from the two scalar masks."""
        ind = self.a_indicator
        return mask_a * ind + mask_b * (1.0 - ind)


_PLAN_CACHE: dict = {}
_PLAN_BUILDS = [0]


def plan_builds() -> int:
    """How many MixPlans have been constructed (test/diagnostic hook)."""
    return _PLAN_BUILDS[0]


def build_mix_plan(lora, *, bp: int = _KERNEL_BP) -> MixPlan:
    """Walk the tree ONCE: record each leaf's slot and the a/b segments."""
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(lora)
    if not leaves_p:
        raise ValueError("empty LoRA tree")
    m = leaves_p[0][1].shape[-3]
    slots, ind_parts = [], []
    off = 0
    for path, leaf in leaves_p:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        cols = math.prod(leaf.shape) // m
        slots.append(_LeafSlot(offset=off, cols=cols,
                               lead=tuple(leaf.shape[:-3]),
                               tail=tuple(leaf.shape[-2:]),
                               is_a=(name == "a")))
        ind_parts.append(np.full(cols, 1.0 if name == "a" else 0.0,
                                 np.float32))
        off += cols
    padded = off + ((-off) % bp)
    if padded > off:
        ind_parts.append(np.zeros(padded - off, np.float32))
    _PLAN_BUILDS[0] += 1
    return MixPlan(m=m, cols=off, padded=padded, bp=bp, slots=tuple(slots),
                   treedef=treedef,
                   a_indicator=np.concatenate(ind_parts)[None, :])


def get_mix_plan(lora, *, bp: int = _KERNEL_BP) -> MixPlan:
    """Cached ``build_mix_plan`` keyed on the tree's static signature."""
    leaves, treedef = jax.tree_util.tree_flatten(lora)
    key = (treedef, bp,
           tuple((tuple(x.shape), jnp.dtype(x.dtype).name) for x in leaves))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _PLAN_CACHE[key] = build_mix_plan(lora, bp=bp)
    return plan


_FLAT_LOWERING_MODES = ("auto", "flat", "per_segment")
_flat_lowering_mode = "auto"


def set_flat_lowering(mode: str) -> str:
    """Set the process-default flat-lowering mode; returns the previous.

    "flat"        — always flatten into the single (m, P) gossip_mix buffer
    "per_segment" — always keep the plan's per-slot W_eff dots
    "auto"        — flat on TPU backends only (default). GSPMD emits an
                    involuntary-full-remat warning on the chunk reshape of
                    the flat buffer (ROADMAP open item), and off-TPU the
                    two full-buffer copies dominate the cache-resident
                    per-slot dots (~4x, BENCH_mixing.json) — so the flat
                    path is gated to TPU meshes by default.
    """
    global _flat_lowering_mode
    if mode not in _FLAT_LOWERING_MODES:
        raise ValueError(f"unknown flat-lowering mode {mode!r}; "
                         f"known: {_FLAT_LOWERING_MODES}")
    prev, _flat_lowering_mode = _flat_lowering_mode, mode
    return prev


def flat_lowering_mode() -> str:
    return _flat_lowering_mode


def use_flat_lowering(mode: Optional[str] = None) -> bool:
    """Resolve a mode (None -> the process default) to a concrete choice."""
    mode = mode if mode is not None else _flat_lowering_mode
    if mode == "flat":
        return True
    if mode == "per_segment":
        return False
    if mode != "auto":
        raise ValueError(f"unknown flat-lowering mode {mode!r}; "
                         f"known: {_FLAT_LOWERING_MODES}")
    return jax.default_backend() == "tpu"


# backwards-compat alias (benchmarks/tests of earlier PRs)
_use_flat_lowering = use_flat_lowering


def mix_tree_planned(W: jax.Array, lora, mask_a, mask_b, *,
                     plan: Optional[MixPlan] = None,
                     flat_lowering: Optional[str] = None):
    """Plan-cached fused mixing (the default fast path).

    Masks are folded into per-segment effective mixing matrices
    W_eff = mask·W + (1−mask)·I — the blend never touches the (m, P)
    payload as a separate pass. Under a mesh (or on TPU) the whole tree is
    mixed by ONE gossip_mix_seg kernel call / ONE collective on the
    plan's padded flat layout; otherwise each slot is a single dot with
    its segment's W_eff. Numerically equal to mix_tree for all masks and
    bit-for-bit at equal masks (W_eff reduces to W exactly).

    ``flat_lowering`` pins the buffer lowering for this call ("flat" /
    "per_segment" / "auto"); None defers to ``set_flat_lowering``'s
    process default (auto: flat on TPU only).
    """
    plan = plan if plan is not None else get_mix_plan(lora)
    leaves = jax.tree_util.tree_leaves(lora)
    m = plan.m

    if use_flat_lowering(flat_lowering):
        parts = [jnp.moveaxis(x, -3, 0).reshape(m, -1) for x in leaves]
        if plan.padded > plan.cols:
            parts.append(jnp.zeros((m, plan.padded - plan.cols),
                                   parts[0].dtype))
        flat = jnp.concatenate(parts, axis=1)
        seg = plan.segment_mask(mask_a, mask_b).astype(flat.dtype)
        mixed = ops.gossip_mix_seg(W.astype(flat.dtype), flat, seg)
        out = []
        for slot, leaf in zip(plan.slots, leaves):
            chunk = mixed[:, slot.offset:slot.offset + slot.cols]
            restored = chunk.reshape(m, *slot.lead, *slot.tail)
            restored = jnp.moveaxis(restored, 0, len(slot.lead))
            out.append(restored.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(plan.treedef, out)

    # cache-local lowering: two (m, m) W_eff folds per round, then one
    # blend-free dot per slot (is_a is plan-static — no path inspection)
    eye = jnp.eye(m, dtype=W.dtype)
    w_a = mask_a * W + (1.0 - mask_a) * eye
    w_b = mask_b * W + (1.0 - mask_b) * eye
    out = [
        jnp.einsum("ij,...jdr->...idr",
                   (w_a if slot.is_a else w_b).astype(leaf.dtype),
                   leaf).astype(leaf.dtype)
        for slot, leaf in zip(plan.slots, leaves)
    ]
    return jax.tree_util.tree_unflatten(plan.treedef, out)
