"""Gossip mixing of stacked client LoRA states (Algorithm 1, lines 7-9).

Every LoRA leaf carries the client axis at position -3 (see core.lora), so
mixing is uniformly  x'_i = Σ_j (W_t)_ij x_j  — an einsum contracting that
axis. Under the production mesh the client axis is sharded over
("pod","data"), so this einsum *is* the paper's communication step, lowered
by GSPMD to collectives over the client axis.

``mix_masks`` lets one compiled step express all four paper methods: a leaf
is mixed when its mask is 1, left untouched when 0 (traced scalars, so the
method/phase never triggers recompilation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mix_leaf(W: jax.Array, leaf: jax.Array) -> jax.Array:
    """leaf: (..., m, d0, d1); W: (m, m)."""
    return jnp.einsum("ij,...jdr->...idr", W.astype(leaf.dtype), leaf)


def mix_tree(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Gossip-mix the a-leaves with weight mask_a and b-leaves with mask_b.

    mask=1 -> fully mixed; mask=0 -> untouched (frozen-block no-mix, i.e.
    the RoLoRA baseline behaviour); fractional values interpolate (used by
    the beyond-paper damped-mixing variant).
    """
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        mask = mask_a if name == "a" else mask_b
        mixed = mix_leaf(W, leaf)
        return (mask * mixed + (1.0 - mask) * leaf).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, lora)


def mix_tree_concat(W: jax.Array, lora, mask_a: jax.Array, mask_b: jax.Array):
    """Beyond-paper lowering variant (§Perf): flatten all leaves into one
    (m, P) buffer, mix with a single matmul (one collective), then unflatten.
    Numerically identical to mix_tree when masks are equal; with unequal
    masks it falls back to per-leaf masking after the fused mix."""
    leaves, treedef = jax.tree_util.tree_flatten(lora)
    m = leaves[0].shape[-3]

    def to2d(x):
        # (..., m, d0, d1) -> (m, prod(lead)*d0*d1)
        x = jnp.moveaxis(x, -3, 0)
        return x.reshape(m, -1)

    flat = jnp.concatenate([to2d(x) for x in leaves], axis=1)
    mixed_flat = W.astype(flat.dtype) @ flat

    out, off = [], 0
    paths = jax.tree_util.tree_flatten_with_path(lora)[0]
    for (path, leaf) in paths:
        n = leaf.size // m
        chunk = mixed_flat[:, off:off + n]
        off += n
        lead = leaf.shape[:-3]
        restored = chunk.reshape(m, *lead, *leaf.shape[-2:])
        restored = jnp.moveaxis(restored, 0, len(lead))
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        mask = mask_a if name == "a" else mask_b
        out.append((mask * restored + (1.0 - mask) * leaf).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
