"""Theory diagnostics (§V / Appendix A): consensus errors, cross term.

For every adapted module with stacked client factors a_i, b_i:
  Δ_A² = (1/m) Σ_i ||a_i − ā||_F²        (block disagreement, Appx A-A)
  Δ_B² = (1/m) Σ_i ||b_i − b̄||_F²
  C    = (1/m) Σ_i (a_i − ā)(b_i − b̄)    (cross term, Appx A-D; our storage
                                          order ΔW = a@b)
  ||C||_F ≤ ||Δ_A||·||Δ_B||              (Cauchy–Schwarz bound — asserted
                                          in tests as a property)

These power the paper-validation experiments: frozen-block contraction at
rate ρ² (Lemma A.4), cycle-averaged cross-term ~ η²/(T(1−ρ)) (Prop. A.5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _iter_ab(lora):
    """Yield (path, a, b) for each adapted module."""
    def walk(node, path):
        if isinstance(node, dict):
            if "a" in node and "b" in node and hasattr(node["a"], "ndim"):
                yield path, node["a"], node["b"]
                return
            for k, v in node.items():
                yield from walk(v, path + (k,))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                yield from walk(v, path + (i,))
    yield from walk(lora, ())


def _fro_sq(x, axes):
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axes)


def consensus_stats(lora) -> dict:
    """Aggregate Δ_A², Δ_B², ||C||_F, and the Cauchy–Schwarz bound over all
    adapted modules (client axis at -3; possible group axis leads)."""
    da_sq = 0.0
    db_sq = 0.0
    cross = 0.0
    bound = 0.0
    for _, a, b in _iter_ab(lora):
        a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
        abar = jnp.mean(a32, axis=-3, keepdims=True)
        bbar = jnp.mean(b32, axis=-3, keepdims=True)
        da = a32 - abar
        db = b32 - bbar
        # per-module scalars (mean over clients, summed over group axes)
        da2 = jnp.sum(jnp.mean(_fro_sq(da, (-2, -1)), axis=-1))
        db2 = jnp.sum(jnp.mean(_fro_sq(db, (-2, -1)), axis=-1))
        C = jnp.mean(jnp.einsum("...dr,...rf->...df", da, db), axis=-3)
        cn = jnp.sum(jnp.sqrt(jnp.sum(jnp.square(C), axis=(-2, -1))))
        da_sq = da_sq + da2
        db_sq = db_sq + db2
        cross = cross + cn
        bound = bound + jnp.sqrt(
            jnp.sum(jnp.mean(_fro_sq(da, (-2, -1)), axis=-1)) *
            jnp.sum(jnp.mean(_fro_sq(db, (-2, -1)), axis=-1)))
    return {"delta_a_sq": da_sq, "delta_b_sq": db_sq,
            "cross_norm": cross, "cs_bound": bound}


consensus_stats_jit = jax.jit(consensus_stats)


def effective_update_norm(lora) -> jax.Array:
    """||mean_i a_i @ b_i||_F — magnitude of the consensus LoRA update."""
    total = 0.0
    for _, a, b in _iter_ab(lora):
        w = jnp.mean(jnp.einsum("...dr,...rf->...df",
                                a.astype(jnp.float32),
                                b.astype(jnp.float32)), axis=-3)
        total = total + jnp.sqrt(jnp.sum(jnp.square(w)))
    return total
