"""The decentralized FL round (Algorithm 1), compiled once for all methods.

One round = ``local_steps`` per-client AdamW updates on the active LoRA
block + one gossip mixing step. Clients are *stacked* (axis -3 of every LoRA
leaf) and sharded over the mesh's client axes; local updates are batched
einsums, mixing is the W_t contraction (core.mixing).

Method/phase enter ONLY through the 4-scalar ``masks`` input
(core.alternating.RoundMasks), and the topology through the W_t input
array — so a single jit-compiled round serves every (method, phase, graph
sample). Per-client AdamW falls out of elementwise moments on the stacked
tree; the (1/m) loss scaling from averaging over clients cancels inside
AdamW's mu/sqrt(nu) normalization (scale invariance, eps aside).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import mixing
from repro.core.lora import shard_lora_tree
from repro.dist.sharding import gather_clients, replicated
from repro.optim.adamw import AdamW, AdamWState


def _ab_mask(masks):
    """Per-leaf update mask: 'a' leaves -> masks[0], 'b' leaves -> masks[1]."""
    def fn(path):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        return masks[0] if name == "a" else masks[1]
    return fn


_MIX_IMPLS = {
    "planned": mixing.mix_tree_planned,    # default: plan-cached fused path
    "per_leaf": mixing.mix_tree,           # the oracle
    "concat": mixing.mix_tree_concat,      # legacy fused (no plan cache)
}

MIX_COMM_MODES = ("dense", "sparse", "sparse_overlap")


def make_dfl_round(loss_fn: Callable, optimizer: AdamW, *,
                   local_steps: int = 1,
                   mix_impl: str = "planned",
                   mix_flat_lowering: Optional[str] = None,
                   mix_gather: bool = False,
                   mix_comm: str = "dense",
                   mix_quant: str = "off",
                   comm_plan=None,
                   donate: bool = False):
    """Build the jit-able round function.

    loss_fn(base_params, lora, microbatch) -> scalar loss, or
      (scalar loss, per_client_vec) — the vector (shard-local entries)
      is surfaced as metrics["loss_per_client"] for grid-invariant loss
      reporting; scalar-only loss_fns report through a length-1 vector.
      microbatch carries the per-client batch (leading client axis matching
      the LoRA client axis).

    Returns round_fn(base_params, lora, opt_state, batch, W, masks)
      -> (lora, opt_state, metrics)
    ``batch`` leaves have a leading (local_steps, ...) axis.

    mix_impl "planned" (default) mixes through a cached MixPlan: one fused
    gossip_mix_seg sweep, one collective under GSPMD. "per_leaf" is the
    bit-for-bit oracle (at equal masks); "concat" the legacy fused variant.
    ``mix_flat_lowering`` ("auto"/"flat"/"per_segment", None = process
    default) pins the planned path's buffer lowering — "auto" gates the
    flat (m, P) buffer to TPU backends (SPMD full-remat warning on the
    chunk reshape under GSPMD; per-segment dots win off-TPU).
    With ``mix_gather`` the stacked LoRA state is constrained fully
    replicated BEFORE the mixing contraction: under a cluster mesh
    (repro.dist.multihost) this pins the communication step to one
    all-gather of the client axis + a replicated contraction, whose
    arithmetic is bitwise equal to the single-process round (GSPMD is
    otherwise free to pick a psum decomposition with a different
    reduction order). Off-mesh it is a no-op.
    ``mix_comm`` selects the cluster communication lowering of the mixing
    step: "dense" keeps the full-support contraction (optionally behind
    the ``mix_gather`` all-gather); "sparse" exchanges only the rows the
    topology's support couples (``comm_plan`` — a
    `repro.dist.comm.CommPlan` — is required under a multi-device mesh),
    bit-for-bit equal to dense; "sparse_overlap" additionally feeds the
    off-diagonal terms the ROUND-INPUT state (one-round-delayed gossip),
    so the halo exchange overlaps with the local steps.
    ``mix_quant`` ("off" | "int8" | "fp8") compresses the sparse halo
    exchange: off-diagonal source rows ship as a quantized payload + one
    f32 per-row scale, with the per-client quantization residual carried
    as error feedback. When on, the round signature changes to
    ``round_fn(base, lora, opt_state, batch, W, masks, ef)
    -> (lora, opt_state, metrics, ef_new)`` where ``ef`` is the (m, P)
    f32 error-feedback buffer of the MixPlan flat layout. "off" keeps the
    exact unquantized round function (same signature, same jaxpr).
    With ``donate`` the returned function is jitted with the lora/opt_state
    buffers donated (in-place round at production scale) — callers must
    then treat the passed-in trees as consumed.
    """
    if mix_comm not in MIX_COMM_MODES:
        raise ValueError(f"unknown mix_comm {mix_comm!r}; "
                         f"known: {MIX_COMM_MODES}")
    if mix_comm != "dense" and mix_impl != "planned":
        raise ValueError("sparse mix_comm lowers through the MixPlan flat "
                         "layout; it requires mix_impl='planned'")
    if mix_quant not in mixing.MIX_QUANT_MODES:
        raise ValueError(f"unknown mix_quant {mix_quant!r}; "
                         f"known: {mixing.MIX_QUANT_MODES}")
    if mix_quant != "off" and mix_comm == "dense":
        raise ValueError("mix_quant compresses the sparse halo exchange; "
                         "it requires mix_comm='sparse' or 'sparse_overlap'")
    mix = _MIX_IMPLS[mix_impl]
    if mix_impl == "planned":
        mix = partial(mixing.mix_tree_planned,
                      flat_lowering=mix_flat_lowering)

    def _local_phase(base_params, lora, opt_state, batch, masks):
        """The local-steps scan — shared between the plain and the
        quantized round functions (identical ops, identical jaxpr)."""
        mask_fn = _ab_mask(masks)

        def local_step(carry, micro):
            lo, opt = carry

            def objective(l):
                # loss_fn may return (scalar, per_client_vec); the vector
                # rides along as aux so the loss can be re-reduced in a
                # grid-invariant order on host (scalar-only loss_fns get
                # a length-1 vector — reporting then equals the scalar)
                out = loss_fn(base_params, l, micro)
                if isinstance(out, tuple):
                    return out
                return out, jnp.reshape(out, (1,))

            (loss, per), grads = jax.value_and_grad(
                objective, has_aux=True)(lo)
            lo, opt = optimizer.update(grads, opt, lo, update_mask=mask_fn)
            lo = shard_lora_tree(lo)
            return (lo, opt), (loss, per)

        return jax.lax.scan(local_step, (lora, opt_state), batch)

    def _metrics(losses, per_client):
        # loss_per_client (local_steps, n) is replicated so every process
        # can host-read it: the session reduces it in ONE fixed order, so
        # the reported loss is bitwise identical across process grids
        # (the in-graph scalars may reduce in a grid-dependent order)
        return {"loss": jnp.mean(losses), "loss_per_step": losses,
                "loss_per_client": replicated(per_client)}

    def round_fn(base_params, lora, opt_state: AdamWState, batch, W, masks):
        (lora_new, opt_new), (losses, per_client) = _local_phase(
            base_params, lora, opt_state, batch, masks)

        # Joint mixing (Algorithm 1 lines 7–9): masks select per method.
        if mix_comm == "dense":
            if mix_gather:
                lora_new = gather_clients(lora_new)
            lora_new = mix(W, lora_new, masks[2], masks[3])
        else:
            # overlap feeds the ROUND-INPUT state to the off-diagonal
            # terms: its exchange is independent of the local-steps scan
            lora_new = mixing.mix_tree_sparse(
                W, lora_new, masks[2], masks[3], comm_plan=comm_plan,
                lora_prev=(lora if mix_comm == "sparse_overlap" else None),
                flat_lowering=mix_flat_lowering)
        lora_new = shard_lora_tree(lora_new)
        metrics = _metrics(losses, per_client)
        return lora_new, opt_new, metrics

    def round_fn_quant(base_params, lora, opt_state: AdamWState, batch, W,
                       masks, ef):
        (lora_new, opt_new), (losses, per_client) = _local_phase(
            base_params, lora, opt_state, batch, masks)

        lora_new, ef_new = mixing.mix_tree_sparse(
            W, lora_new, masks[2], masks[3], comm_plan=comm_plan,
            lora_prev=(lora if mix_comm == "sparse_overlap" else None),
            flat_lowering=mix_flat_lowering, quant=mix_quant, ef=ef)
        lora_new = shard_lora_tree(lora_new)
        metrics = _metrics(losses, per_client)
        return lora_new, opt_new, metrics, ef_new

    if mix_quant != "off":
        if donate:
            return jax.jit(round_fn_quant, donate_argnums=(1, 2, 6))
        return round_fn_quant
    if donate:
        return jax.jit(round_fn, donate_argnums=(1, 2))
    return round_fn


def make_microbatches(batch, local_steps: int):
    """Reshape a round's batch (m, local_steps*b, ...) ->
    (local_steps, m, b, ...) for the scan."""
    def one(x):
        m, tb = x.shape[:2]
        b = tb // local_steps
        return jnp.moveaxis(x.reshape(m, local_steps, b, *x.shape[2:]), 1, 0)
    return jax.tree.map(one, batch)
