"""`repro.scenarios` — the communication-scenario library.

Topology schedules (static / edge activation / churn / stragglers —
including persistent per-client speed ratios — / mid-run cold joins /
phase switching over any `repro.core.topology` graph family, incl. the
hierarchical two-tier cross-silo composition) behind one
`TopologySchedule` protocol, the named `SCENARIO_MATRIX` the conformance
test tier and `benchmarks/scenarios.py` sweep, and the `DFLConfig` →
schedule factory `Session` uses. W_t is always plain (m, m) data, so every
scenario reuses one compiled round.
"""
from repro.scenarios.library import (SCENARIO_MATRIX, SCENARIO_NAMES,
                                     SCENARIOS, Scenario, estimate_rho_sq,
                                     get_scenario, schedule_from_config)
from repro.scenarios.schedule import (BroadcastSchedule, ClientChurn,
                                      ColdJoin, EdgeActivation,
                                      GossipSchedule, PersistentStraggler,
                                      PhaseSwitch, StaticGraph,
                                      StragglerDropout, TopologySchedule,
                                      schedule_support)

__all__ = [
    "TopologySchedule", "GossipSchedule", "StaticGraph", "EdgeActivation",
    "ClientChurn", "StragglerDropout", "PersistentStraggler", "ColdJoin",
    "PhaseSwitch", "BroadcastSchedule",
    "Scenario", "SCENARIO_MATRIX", "SCENARIO_NAMES", "SCENARIOS",
    "schedule_from_config", "estimate_rho_sq", "get_scenario",
    "schedule_support",
]
