"""The scenario library: named communication conditions + config wiring.

A `Scenario` bundles (underlying graph family, topology schedule kind,
activation/churn parameters) into one named object that can be (a) turned
into `DFLConfig` field overrides (`config_kw()`), (b) built standalone as a
`TopologySchedule` (`build()`), and (c) interrogated for the spectral
reference quantities the theory-conformance tier checks against Lemma A.10
(`probes()` → per-phase (adjacency, effective p, schedule factory)).

`SCENARIO_MATRIX` is the canonical matrix: every entry is exercised by
`tests/test_conformance.py` (double stochasticity/symmetry, contraction
bound, consensus decay, single-compilation through `Session`) and timed by
`benchmarks/scenarios.py` → BENCH_scenarios.json.

`schedule_from_config(cfg)` is the `Session` hook: scenario "gossip" keeps
the paper's Lemma A.10 pairwise sampler (bit-for-bit the pre-scenario
behavior); every other value selects a Metropolis-based schedule. W_t stays
*data* in all cases — switching scenarios never recompiles the round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional

import numpy as np

from repro.core.topology import (Topology, make_topology,
                                 rho_sq_from_samples, underlying_graph)
from repro.scenarios.schedule import (ClientChurn, ColdJoin, EdgeActivation,
                                      GossipSchedule, PersistentStraggler,
                                      PhaseSwitch, StaticGraph,
                                      StragglerDropout, TopologySchedule)

SCENARIOS = ("gossip", "static", "edge_activation", "churn", "straggler",
             "phase_switch", "persistent_straggler", "cold_join")

# phase_switch scenario_kw defaults (second = the degraded phase)
_PHASE_DEFAULTS = dict(switch_round=10, weak_graph="ring", weak_p=0.1)


def _as_dict(kw) -> dict:
    return dict(kw) if not isinstance(kw, Mapping) else dict(kw.items())


def schedule_from_config(cfg, topology: Optional[Topology] = None,
                         ) -> TopologySchedule:
    """Build the TopologySchedule a `DFLConfig` describes. For the legacy
    "gossip" scenario an existing core `Topology` may be passed so the
    schedule shares its RNG stream (Session does this to stay bit-for-bit
    with pre-scenario runs)."""
    tkw = _as_dict(cfg.topology_kw)
    skw = _as_dict(cfg.scenario_kw)
    if cfg.scenario == "gossip":
        topo = topology if topology is not None else make_topology(
            cfg.topology, cfg.n_clients, cfg.p, seed=cfg.seed, **tkw)
        return GossipSchedule(topo)
    adj = underlying_graph(cfg.topology, cfg.n_clients, cfg.seed, **tkw)
    try:
        if cfg.scenario == "static":
            return StaticGraph(adj)
        if cfg.scenario == "edge_activation":
            return EdgeActivation(adj, cfg.p, cfg.seed, **skw)
        if cfg.scenario == "churn":
            return ClientChurn(adj, cfg.p, cfg.seed, **skw)
        if cfg.scenario == "straggler":
            return StragglerDropout(adj, cfg.p, cfg.seed, **skw)
        if cfg.scenario == "persistent_straggler":
            return PersistentStraggler(adj, cfg.p, cfg.seed, **skw)
        if cfg.scenario == "cold_join":
            return ColdJoin(adj, cfg.p, cfg.seed, **skw)
        if cfg.scenario == "phase_switch":
            kw = {**_PHASE_DEFAULTS, **skw}
            weak_adj = underlying_graph(kw["weak_graph"], cfg.n_clients,
                                        cfg.seed)
            return PhaseSwitch(
                EdgeActivation(adj, cfg.p, cfg.seed),
                EdgeActivation(weak_adj, kw["weak_p"], cfg.seed + 1),
                kw["switch_round"])
    except TypeError as e:
        raise ValueError(
            f"bad scenario_kw for scenario {cfg.scenario!r}: {e}") from e
    raise ValueError(f"unknown scenario {cfg.scenario!r}; "
                     f"known: {SCENARIOS}")


def estimate_rho_sq(schedule: TopologySchedule, rounds: int = 150,
                    burn_in: int = 0) -> float:
    """Time-averaged mean-square contraction ρ² = ||avg_t WᵀW − J||₂ over
    `rounds` consecutive W_t of a (fresh) schedule. `burn_in` discards the
    leading rounds (churn starts all-active; the stationary regime is the
    honest reference)."""
    Ws = [schedule.next_w(t) for t in range(burn_in + rounds)]
    return rho_sq_from_samples(Ws[burn_in:])


# ---------------------------------------------------------------------------
# the scenario matrix
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scenario:
    """One named communication condition of the conformance matrix."""
    name: str
    topology: str
    scenario: str
    p: float = 0.5
    topology_kw: tuple = ()
    scenario_kw: tuple = ()
    # conformance knobs: rho burn-in and consensus-decay target (scenarios
    # with offline nodes mix slower; decay over the probe horizon differs)
    burn_in: int = 0
    decay_target: float = 0.05

    def config_kw(self) -> dict:
        """DFLConfig field overrides selecting this scenario."""
        return dict(topology=self.topology, scenario=self.scenario,
                    p=self.p, topology_kw=dict(self.topology_kw),
                    scenario_kw=dict(self.scenario_kw))

    def _cfg(self, m: int, seed: int):
        from repro.api.config import DFLConfig
        return DFLConfig(n_clients=m, seed=seed, **self.config_kw())

    def build(self, m: int, seed: int = 0) -> TopologySchedule:
        return schedule_from_config(self._cfg(m, seed))

    def probes(self, m: int, seed: int = 0):
        """Per-phase (label, adjacency, p_eff, schedule_factory) for the
        Lemma A.10 bound check. p_eff is the effective per-edge activation
        probability: p scaled by the probability both endpoints participate
        (churn: stationary active fraction; straggler: 1−drop)."""
        tkw = _as_dict(self.topology_kw)
        skw = _as_dict(self.scenario_kw)
        adj = underlying_graph(self.topology, m, seed, **tkw)
        if self.scenario == "phase_switch":
            kw = {**_PHASE_DEFAULTS, **skw}
            weak_adj = underlying_graph(kw["weak_graph"], m, seed)
            return [
                ("strong", adj, self.p,
                 lambda: EdgeActivation(adj, self.p, seed)),
                ("weak", weak_adj, kw["weak_p"],
                 lambda: EdgeActivation(weak_adj, kw["weak_p"], seed + 1)),
            ]
        p_eff = 1.0 if self.scenario == "static" else self.p
        if self.scenario == "churn":
            kw = {**dict(leave=0.1, rejoin=0.5), **skw}
            a = kw["rejoin"] / (kw["leave"] + kw["rejoin"])
            p_eff *= a * a
        elif self.scenario == "straggler":
            up = 1.0 - skw.get("drop", 0.2)
            p_eff *= up * up
        elif self.scenario == "persistent_straggler":
            # minimum per-edge activation: edges touching a slow client
            # fire only on wake rounds (all slow clients wake together,
            # so no edge is worse than p/period) — the mean availability
            # overstates the gap because the worst-mixed direction
            # concentrates on the slow clients
            frac = skw.get("frac", 0.3)
            period = skw.get("period", 4)
            if round(frac * m) > 0:
                p_eff /= period
        elif self.scenario == "cold_join":
            # stationary regime (the phase the rho estimate's burn_in
            # skips) = everyone joined = plain edge activation at p
            pass
        return [("", adj, p_eff, lambda: self.build(m, seed))]


SCENARIO_MATRIX = (
    Scenario("complete-static", "complete", "static"),
    Scenario("complete-gossip", "complete", "gossip", p=0.2),
    Scenario("ring-edge", "ring", "edge_activation", p=0.5,
             decay_target=0.1),
    Scenario("exponential-edge", "exponential", "edge_activation", p=0.4),
    Scenario("torus-edge", "torus", "edge_activation", p=0.4),
    Scenario("smallworld-edge", "small_world", "edge_activation", p=0.4,
             topology_kw=(("ws_k", 4), ("ws_beta", 0.2))),
    Scenario("er-edge", "erdos_renyi", "edge_activation", p=0.4,
             topology_kw=(("er_q", 0.6),)),
    Scenario("complete-churn", "complete", "churn", p=0.3,
             scenario_kw=(("leave", 0.15), ("rejoin", 0.5)),
             burn_in=20),
    Scenario("torus-straggler", "torus", "straggler", p=0.6,
             scenario_kw=(("drop", 0.25),)),
    Scenario("phase-strong-weak", "complete", "phase_switch", p=0.5,
             scenario_kw=(("switch_round", 8), ("weak_p", 0.15))),
    Scenario("complete-persistent-straggler", "complete",
             "persistent_straggler", p=0.4,
             scenario_kw=(("frac", 0.3), ("period", 3)),
             decay_target=0.1),
    Scenario("hier-cold-join", "hierarchical", "cold_join", p=0.6,
             topology_kw=(("hier_silos", 3),),
             scenario_kw=(("joiners", 2), ("join_round", 6)),
             burn_in=6, decay_target=0.2),
    Scenario("hier-edge", "hierarchical", "edge_activation", p=0.5,
             topology_kw=(("hier_silos", 3), ("hier_inter", "ring"))),
)

SCENARIO_NAMES = tuple(s.name for s in SCENARIO_MATRIX)


def get_scenario(name: str) -> Scenario:
    for s in SCENARIO_MATRIX:
        if s.name == name:
            return s
    raise KeyError(f"unknown scenario {name!r}; known: {SCENARIO_NAMES}")
