"""`TopologySchedule` — one protocol for every communication condition.

Mirrors `repro.api.schedule.MaskSchedule`: the compiled DFL round consumes
an (m, m) float W_t as *data*, so what varies across scenarios is only how
W_t evolves over rounds. `next_w(t)` must be called with consecutive round
indices 0, 1, 2, … — schedules may hold RNG/Markov state, and checkpoint
resume replays them by re-calling `next_w` from a freshly constructed
schedule (the same contract `Session.restore` applies to mask schedules).

Implementations:
  * `GossipSchedule`   — the paper's Lemma A.10 sampler (sequential pairwise
                         averaging on activated edges), wrapping a core
                         `Topology`; doubly stochastic, not symmetric.
  * `StaticGraph`      — constant Metropolis W of the underlying graph.
  * `EdgeActivation`   — per-round edge firing w.p. p, Metropolis weights on
                         the fired subgraph (symmetric doubly stochastic).
  * `ClientChurn`      — persistent node on/off Markov chain (leave/rejoin);
                         offline nodes' W rows/cols collapse to identity,
                         which preserves double stochasticity exactly.
  * `StragglerDropout` — i.i.d. per-round node dropout, same identity-row
                         repair.
  * `PhaseSwitch`      — strong→weak (or any) schedule change at a fixed
                         round boundary.
  * `BroadcastSchedule`— process-grid agreement wrapper: rank 0 draws,
                         everyone mixes with the broadcast W_t
                         (`ClusterSession` wraps every schedule in it).

All Metropolis-based schedules emit symmetric W_t (`symmetric=True`);
`GossipSchedule` emits products of pairwise averagers (`symmetric=False`),
still doubly stochastic by construction.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.topology import Topology, metropolis_weights


@runtime_checkable
class TopologySchedule(Protocol):
    """Anything that maps a round index to this round's mixing matrix."""

    m: int
    symmetric: bool

    def next_w(self, t: int) -> np.ndarray:
        ...


class GossipSchedule:
    """The legacy default: Lemma A.10 sequential pairwise averaging via a
    core `Topology`. Wraps (and shares the RNG of) the Topology object, so
    a Session that owns both sees the identical W_t stream the pre-scenario
    code produced."""

    symmetric = False

    def __init__(self, topology: Topology):
        self.topology = topology
        self.m = topology.m

    def next_w(self, t: int) -> np.ndarray:
        return self.topology.sample()


class StaticGraph:
    """Constant W: the Metropolis weights of the underlying graph."""

    symmetric = True

    def __init__(self, adj: np.ndarray, **_ignored):
        self.adj = np.asarray(adj, float)
        self.m = self.adj.shape[0]
        self._W = metropolis_weights(self.adj)

    def next_w(self, t: int) -> np.ndarray:
        return self._W


class EdgeActivation:
    """Each edge of the underlying graph fires independently w.p. p every
    round; W_t is the Metropolis matrix of the fired subgraph."""

    symmetric = True

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0):
        self.adj = (np.asarray(adj, float) > 0).astype(float)
        np.fill_diagonal(self.adj, 0.0)
        self.m = self.adj.shape[0]
        self.p = p
        self._rng = np.random.default_rng(seed)
        iu = np.triu_indices(self.m, k=1)
        keep = self.adj[iu] > 0
        self._edges = (iu[0][keep], iu[1][keep])

    def _fired_adj(self) -> np.ndarray:
        ii, jj = self._edges
        fire = self._rng.random(len(ii)) < self.p
        a = np.zeros((self.m, self.m))
        a[ii[fire], jj[fire]] = 1.0
        return a + a.T

    def next_w(self, t: int) -> np.ndarray:
        return metropolis_weights(self._fired_adj())


class ClientChurn(EdgeActivation):
    """Clients leave and rejoin: a per-node on/off Markov chain (P(leave) =
    `leave`, P(rejoin) = `rejoin`, all nodes start active). Only edges whose
    BOTH endpoints are active can fire; an offline node's W row/col is e_i
    (it keeps its own state), which is exactly the repair that keeps W_t
    doubly stochastic. At least `min_active` nodes are kept online by
    reactivating lowest-index offline nodes."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 leave: float = 0.1, rejoin: float = 0.5,
                 min_active: int = 2):
        super().__init__(adj, p, seed)
        self.leave = leave
        self.rejoin = rejoin
        self.min_active = min(min_active, self.m)
        self.active = np.ones(self.m, bool)

    def _step_membership(self) -> None:
        u = self._rng.random(self.m)
        flip_off = self.active & (u < self.leave)
        flip_on = ~self.active & (u < self.rejoin)
        self.active = (self.active & ~flip_off) | flip_on
        short = self.min_active - int(self.active.sum())
        if short > 0:
            self.active[np.flatnonzero(~self.active)[:short]] = True

    def next_w(self, t: int) -> np.ndarray:
        self._step_membership()
        a = self._fired_adj()
        a *= self.active[:, None] * self.active[None, :]
        return metropolis_weights(a)


class StragglerDropout(EdgeActivation):
    """Each node independently straggles (skips communication) w.p. `drop`
    every round — memoryless, unlike `ClientChurn`. Stragglers get the same
    identity row/col repair."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 drop: float = 0.2):
        super().__init__(adj, p, seed)
        self.drop = drop

    def next_w(self, t: int) -> np.ndarray:
        up = self._rng.random(self.m) >= self.drop
        a = self._fired_adj()
        a *= up[:, None] * up[None, :]
        return metropolis_weights(a)


class BroadcastSchedule:
    """Process-grid agreement wrapper: rank 0's W_t is the only draw that
    counts. `ClusterSession` wraps every schedule in this so all processes
    mix with the same matrix even when the inner schedule's host RNG or
    Markov state could drift (user-supplied schedules, non-deterministic
    sources). Config-derived schedules are already deterministic per seed,
    so the broadcast is a safety net there — but the paper's setting has
    exactly one realized W_t per round, and under a cluster that realization
    must be owned by one process.

    Single-process this is an exact passthrough (same dtype, same RNG
    stream). Multi-process, the inner schedule only *advances* on rank 0;
    other ranks receive the broadcast value bit-exactly, widened to
    float64 (exact for every schedule dtype) so downstream full-precision
    consumers — `AdaptiveSchedule`'s spectral estimator, checkpoint
    replay — observe the same values a single-process run would, not a
    float32 shadow. Checkpoint replay calls `next_w` sequentially on
    every process, so the broadcast replays in lockstep.
    """

    def __init__(self, inner: TopologySchedule):
        self.inner = inner
        self.m = inner.m
        self.symmetric = inner.symmetric

    def next_w(self, t: int) -> np.ndarray:
        from repro.dist import multihost
        if not multihost.is_distributed():
            return self.inner.next_w(t)
        if multihost.is_primary():
            W = np.asarray(self.inner.next_w(t), np.float64)
        else:
            W = np.zeros((self.m, self.m), np.float64)
        return multihost.broadcast_from_primary(W)


class PhaseSwitch:
    """Switches between two schedules at round `switch_round` (the paper's
    strong→weak stress: connectivity degrades mid-run). Sub-schedule RNGs
    advance only while their phase is live, so sequential replay is exact."""

    def __init__(self, first: TopologySchedule, second: TopologySchedule,
                 switch_round: int):
        if first.m != second.m:
            raise ValueError("phase schedules must share m")
        self.first = first
        self.second = second
        self.switch_round = switch_round
        self.m = first.m
        self.symmetric = first.symmetric and second.symmetric

    def next_w(self, t: int) -> np.ndarray:
        sched = self.first if t < self.switch_round else self.second
        return sched.next_w(t)
