"""`TopologySchedule` — one protocol for every communication condition.

Mirrors `repro.api.schedule.MaskSchedule`: the compiled DFL round consumes
an (m, m) float W_t as *data*, so what varies across scenarios is only how
W_t evolves over rounds. `next_w(t)` must be called with consecutive round
indices 0, 1, 2, … — schedules may hold RNG/Markov state, and checkpoint
resume replays them by re-calling `next_w` from a freshly constructed
schedule (the same contract `Session.restore` applies to mask schedules).

Implementations:
  * `GossipSchedule`   — the paper's Lemma A.10 sampler (sequential pairwise
                         averaging on activated edges), wrapping a core
                         `Topology`; doubly stochastic, not symmetric.
  * `StaticGraph`      — constant Metropolis W of the underlying graph.
  * `EdgeActivation`   — per-round edge firing w.p. p, Metropolis weights on
                         the fired subgraph (symmetric doubly stochastic).
  * `ClientChurn`      — persistent node on/off Markov chain (leave/rejoin);
                         offline nodes' W rows/cols collapse to identity,
                         which preserves double stochasticity exactly.
  * `StragglerDropout` — i.i.d. per-round node dropout, same identity-row
                         repair.
  * `PersistentStraggler` — a seeded persistent subset of clients is
                         permanently slow and only communicates every
                         `period`-th round (per-client speed ratios,
                         unlike the memoryless dropout).
  * `ColdJoin`         — clients absent until `join_round`, then joining
                         with cold adapters; exposes `join_events(t)` so
                         the Session warm-starts joiners from neighbor
                         state (the adapter-initialization half of the
                         identity-row repair).
  * `PhaseSwitch`      — strong→weak (or any) schedule change at a fixed
                         round boundary.
  * `BroadcastSchedule`— process-grid agreement wrapper: rank 0 draws,
                         everyone mixes with the broadcast W_t
                         (`ClusterSession` wraps every schedule in it).

All Metropolis-based schedules emit symmetric W_t (`symmetric=True`);
`GossipSchedule` emits products of pairwise averagers (`symmetric=False`),
still doubly stochastic by construction.

Weight policies: every Metropolis-based schedule exposes a
``set_weights(policy)`` hook — ``policy(underlying_adj)`` returns the
per-round weight function ``fired_adj -> W`` (default: Metropolis). The
control plane (repro.control) installs its FMMC policy through this hook,
so *which edges fire* stays the scenario's business while *how fired
edges are weighted* becomes the control plane's. `GossipSchedule` has no
hook: the pairwise sampler owns no weight matrix (DFLConfig rejects
weight_policy='fmmc' on the gossip scenario).

Two optional traits the cluster/sparse-comm layer reads (absent on
user-supplied schedules -> conservative defaults):

  * ``deterministic`` — True when a freshly constructed schedule replays
    the identical W_t stream on every process (all config-derived
    library schedules: their randomness is a seeded ``default_rng``).
    `ClusterSession` skips the per-round `BroadcastSchedule` round-trip
    for deterministic schedules — the draw agrees by construction.
  * ``support_adjacency()`` — the (m, m) bool union support of every
    W_t the schedule can emit (incl. diagonal). `repro.dist.comm`
    compiles it into the sparse exchange's `CommPlan`. Metropolis-based
    schedules support exactly adj + I; `GossipSchedule`'s within-round
    *products* of pairwise averagers can chain along paths, so its
    support is the transitive closure of the graph — sparse comm wins
    nothing on a connected gossip scenario (use the Metropolis
    scenarios for sparse grids).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.topology import Topology, metropolis_weights


@runtime_checkable
class TopologySchedule(Protocol):
    """Anything that maps a round index to this round's mixing matrix."""

    m: int
    symmetric: bool

    def next_w(self, t: int) -> np.ndarray:
        ...


def _with_diag(adj: np.ndarray) -> np.ndarray:
    sup = (np.asarray(adj) != 0).copy()
    np.fill_diagonal(sup, True)
    return sup


def _transitive_closure(adj: np.ndarray) -> np.ndarray:
    """Boolean reachability closure (connected-component blocks)."""
    sup = _with_diag(adj)
    while True:
        nxt = sup | (sup @ sup)
        if (nxt == sup).all():
            return sup
        sup = nxt


def schedule_support(schedule: TopologySchedule) -> np.ndarray:
    """The (m, m) bool union support of a schedule's W_t stream.

    Delegates to the schedule's ``support_adjacency()``; schedules
    without one (user-supplied objects) cannot be compiled into a sparse
    `CommPlan` — mix with ``mix_comm="dense"`` or implement the method.
    """
    fn = getattr(schedule, "support_adjacency", None)
    if fn is None:
        raise ValueError(
            f"{type(schedule).__name__} exposes no support_adjacency(); "
            f"sparse gossip comm (mix_comm='sparse'/'sparse_overlap') "
            f"needs the union support of W_t — use mix_comm='dense' or "
            f"implement support_adjacency() on the schedule")
    return _with_diag(fn())


class GossipSchedule:
    """The legacy default: Lemma A.10 sequential pairwise averaging via a
    core `Topology`. Wraps (and shares the RNG of) the Topology object, so
    a Session that owns both sees the identical W_t stream the pre-scenario
    code produced."""

    symmetric = False
    deterministic = True    # seeded Topology RNG: same stream per seed

    def __init__(self, topology: Topology):
        self.topology = topology
        self.m = topology.m

    def next_w(self, t: int) -> np.ndarray:
        return self.topology.sample()

    def support_adjacency(self) -> np.ndarray:
        """Within one round the sampler multiplies pairwise averagers, so
        state can propagate along activated paths — the union support is
        the transitive closure of the graph, not adj + I. On a connected
        graph that is the full component: gossip scenarios gain nothing
        from sparse comm (the Metropolis scenarios do)."""
        return _transitive_closure(self.topology.adj)


class StaticGraph:
    """Constant W: the Metropolis weights of the underlying graph."""

    symmetric = True
    deterministic = True

    def __init__(self, adj: np.ndarray, **_ignored):
        self.adj = np.asarray(adj, float)
        self.m = self.adj.shape[0]
        self._W = metropolis_weights(self.adj)

    def set_weights(self, policy) -> None:
        """Install a weight policy (control plane hook): `policy(adj)`
        yields the weight function, evaluated once on the static graph."""
        self._W = policy(self.adj)(self.adj)

    def next_w(self, t: int) -> np.ndarray:
        return self._W

    def support_adjacency(self) -> np.ndarray:
        return _with_diag(self.adj)


class EdgeActivation:
    """Each edge of the underlying graph fires independently w.p. p every
    round; W_t is the Metropolis matrix of the fired subgraph."""

    symmetric = True
    deterministic = True    # seeded default_rng: same stream per seed

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0):
        self.adj = (np.asarray(adj, float) > 0).astype(float)
        np.fill_diagonal(self.adj, 0.0)
        self.m = self.adj.shape[0]
        self.p = p
        self._rng = np.random.default_rng(seed)
        self._weights = metropolis_weights
        iu = np.triu_indices(self.m, k=1)
        keep = self.adj[iu] > 0
        self._edges = (iu[0][keep], iu[1][keep])

    def set_weights(self, policy) -> None:
        """Install a weight policy (control plane hook): `policy` sees the
        UNDERLYING adjacency once and returns the per-round weight
        function applied to each fired subgraph. Edge *selection* (this
        schedule's RNG) is untouched — replay contracts hold under any
        policy."""
        self._weights = policy(self.adj)

    def _fired_adj(self) -> np.ndarray:
        ii, jj = self._edges
        fire = self._rng.random(len(ii)) < self.p
        a = np.zeros((self.m, self.m))
        a[ii[fire], jj[fire]] = 1.0
        return a + a.T

    def next_w(self, t: int) -> np.ndarray:
        return self._weights(self._fired_adj())

    def support_adjacency(self) -> np.ndarray:
        """Fired subgraphs are subgraphs: Metropolis support ⊆ adj + I.
        Holds for the churn/straggler subclasses too (they only *remove*
        edges via the identity row/col repair)."""
        return _with_diag(self.adj)


class ClientChurn(EdgeActivation):
    """Clients leave and rejoin: a per-node on/off Markov chain (P(leave) =
    `leave`, P(rejoin) = `rejoin`, all nodes start active). Only edges whose
    BOTH endpoints are active can fire; an offline node's W row/col is e_i
    (it keeps its own state), which is exactly the repair that keeps W_t
    doubly stochastic. At least `min_active` nodes are kept online by
    reactivating lowest-index offline nodes."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 leave: float = 0.1, rejoin: float = 0.5,
                 min_active: int = 2):
        super().__init__(adj, p, seed)
        self.leave = leave
        self.rejoin = rejoin
        self.min_active = min(min_active, self.m)
        self.active = np.ones(self.m, bool)

    def _step_membership(self) -> None:
        u = self._rng.random(self.m)
        flip_off = self.active & (u < self.leave)
        flip_on = ~self.active & (u < self.rejoin)
        self.active = (self.active & ~flip_off) | flip_on
        short = self.min_active - int(self.active.sum())
        if short > 0:
            self.active[np.flatnonzero(~self.active)[:short]] = True

    def next_w(self, t: int) -> np.ndarray:
        self._step_membership()
        a = self._fired_adj()
        a *= self.active[:, None] * self.active[None, :]
        return self._weights(a)


class StragglerDropout(EdgeActivation):
    """Each node independently straggles (skips communication) w.p. `drop`
    every round — memoryless, unlike `ClientChurn`. Stragglers get the same
    identity row/col repair."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 drop: float = 0.2):
        super().__init__(adj, p, seed)
        self.drop = drop

    def next_w(self, t: int) -> np.ndarray:
        up = self._rng.random(self.m) >= self.drop
        a = self._fired_adj()
        a *= up[:, None] * up[None, :]
        return self._weights(a)


class PersistentStraggler(EdgeActivation):
    """Stragglers with *persistent* per-client speed ratios: a seeded
    `frac` of clients is permanently slow and communicates only every
    `period`-th round (all slow clients surface together at
    t % period == 0 — a barrier-style straggler, so slow–slow edges
    still fire and the Lemma A.10 bound survives with the *minimum*
    per-edge activation p_eff = p/period: heterogeneous edge rates make
    the worst-mixed direction concentrate on the slow clients, so the
    mean availability overstates the gap — the per-edge minimum is the
    sound scalar, and `p_eff()` returns it). Off-rounds give slow
    clients the identity row/col repair; they keep training locally,
    exactly the paper's offline-node semantics."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 frac: float = 0.3, period: int = 4):
        super().__init__(adj, p, seed)
        if not 0.0 <= frac <= 1.0:
            raise ValueError("frac must be in [0, 1]")
        if period < 1:
            raise ValueError("period must be >= 1")
        self.frac = float(frac)
        self.period = int(period)
        n_slow = int(round(self.frac * self.m))
        pick = np.random.default_rng((seed, 0x510))
        self.slow = np.zeros(self.m, bool)
        self.slow[pick.permutation(self.m)[:n_slow]] = True

    def p_eff(self) -> float:
        """Effective per-edge activation for the Lemma A.10 bound: the
        minimum over edges. Edges touching a slow client fire only on
        wake rounds -> p/period (slow clients wake together, so
        slow–slow edges are no worse); with no slow clients, p."""
        return self.p / self.period if self.slow.any() else self.p

    def next_w(self, t: int) -> np.ndarray:
        up = np.ones(self.m, bool)
        if t % self.period != 0:
            up[self.slow] = False
        a = self._fired_adj()
        a *= up[:, None] * up[None, :]
        return self._weights(a)


class ColdJoin(EdgeActivation):
    """Clients joining mid-run with cold adapters: `joiners` are offline
    (identity row/col, frozen out of gossip) until `join_round`, then
    participate like everyone else. The schedule side is the same
    identity-row repair churn uses; the *adapter-initialization half*
    lives in `join_events(t)` — `Session._one_round` polls it and
    warm-starts each joiner's LoRA/optimizer rows from its graph
    neighbors' average (consensus distance then contracts per Lemma
    A.10 instead of paying a cold-adapter transient; the conformance
    tier checks the contraction within the C_STALE budget)."""

    def __init__(self, adj: np.ndarray, p: float = 0.5, seed: int = 0,
                 joiners=1, join_round: int = 10):
        super().__init__(adj, p, seed)
        if join_round < 0:
            raise ValueError("join_round must be >= 0")
        if isinstance(joiners, (int, np.integer)):
            if not 0 <= joiners < self.m:
                raise ValueError("joiner count must be in [0, m)")
            joiners = tuple(range(self.m - int(joiners), self.m))
        self.joiners = tuple(int(j) for j in joiners)
        if any(not 0 <= j < self.m for j in self.joiners):
            raise ValueError("joiner index out of range")
        if len(self.joiners) >= self.m:
            raise ValueError("at least one client must start warm")
        self.join_round = int(join_round)

    def join_events(self, t: int) -> tuple:
        """Clients joining (cold->warm) at round t; the Session hook."""
        return self.joiners if t == self.join_round else ()

    def next_w(self, t: int) -> np.ndarray:
        up = np.ones(self.m, bool)
        if t < self.join_round:
            up[list(self.joiners)] = False
        a = self._fired_adj()
        a *= up[:, None] * up[None, :]
        return self._weights(a)


class BroadcastSchedule:
    """Process-grid agreement wrapper: rank 0's W_t is the only draw that
    counts. `ClusterSession` wraps schedules that do not declare
    ``deterministic`` (user-supplied objects, non-deterministic sources)
    so all processes mix with the same matrix even when the inner
    schedule's host RNG or Markov state could drift. Config-derived
    library schedules replay the identical stream per seed on every
    process (``deterministic=True``) and skip this wrapper — the
    per-round host broadcast is a blocking collective that dominates the
    round at small payloads (BENCH_multihost.json), and for a
    deterministic source it transports bytes every process already has.
    The paper's setting has exactly one realized W_t per round; under a
    cluster that realization is owned by one process only when the draw
    could disagree.

    Single-process this is an exact passthrough (same dtype, same RNG
    stream). Multi-process, the inner schedule only *advances* on rank 0;
    other ranks receive the broadcast value bit-exactly, widened to
    float64 (exact for every schedule dtype) so downstream full-precision
    consumers — `AdaptiveSchedule`'s spectral estimator, checkpoint
    replay — observe the same values a single-process run would, not a
    float32 shadow. Checkpoint replay calls `next_w` sequentially on
    every process, so the broadcast replays in lockstep.
    """

    deterministic = False   # the wrapper exists because the inner isn't

    def __init__(self, inner: TopologySchedule):
        self.inner = inner
        self.m = inner.m
        self.symmetric = inner.symmetric

    def support_adjacency(self) -> np.ndarray:
        return schedule_support(self.inner)

    def join_events(self, t: int) -> tuple:
        """Proxy the inner schedule's cold-join hook (empty otherwise) —
        wrapping must not hide joins from the Session's warm start."""
        fn = getattr(self.inner, "join_events", None)
        return tuple(fn(t)) if fn is not None else ()

    def set_weights(self, policy) -> None:
        """Proxy the control plane's weight-policy hook to the inner
        schedule (every process installs the same deterministic policy, so
        rank 0's broadcast draw already reflects it)."""
        fn = getattr(self.inner, "set_weights", None)
        if fn is None:
            raise ValueError(f"{type(self.inner).__name__} exposes no "
                             f"set_weights() hook")
        fn(policy)

    def next_w(self, t: int) -> np.ndarray:
        from repro.dist import multihost
        if not multihost.is_distributed():
            return self.inner.next_w(t)
        if multihost.is_primary():
            W = np.asarray(self.inner.next_w(t), np.float64)
        else:
            W = np.zeros((self.m, self.m), np.float64)
        return multihost.broadcast_from_primary(W)


class PhaseSwitch:
    """Switches between two schedules at round `switch_round` (the paper's
    strong→weak stress: connectivity degrades mid-run). Sub-schedule RNGs
    advance only while their phase is live, so sequential replay is exact."""

    def __init__(self, first: TopologySchedule, second: TopologySchedule,
                 switch_round: int):
        if first.m != second.m:
            raise ValueError("phase schedules must share m")
        self.first = first
        self.second = second
        self.switch_round = switch_round
        self.m = first.m
        self.symmetric = first.symmetric and second.symmetric

    @property
    def deterministic(self) -> bool:
        return bool(getattr(self.first, "deterministic", False)
                    and getattr(self.second, "deterministic", False))

    def support_adjacency(self) -> np.ndarray:
        return schedule_support(self.first) | schedule_support(self.second)

    def set_weights(self, policy) -> None:
        """Install a weight policy on BOTH phases — each phase hands the
        policy its own underlying adjacency, so FMMC re-optimizes for the
        post-switch graph rather than reusing the pre-switch weights."""
        for sched in (self.first, self.second):
            fn = getattr(sched, "set_weights", None)
            if fn is None:
                raise ValueError(f"{type(sched).__name__} exposes no "
                                 f"set_weights() hook")
            fn(policy)

    def next_w(self, t: int) -> np.ndarray:
        sched = self.first if t < self.switch_round else self.second
        return sched.next_w(t)
