"""`ControlConfig` — the structured description of the closed control loop.

Replaces the flat `adaptive_T` / `adaptive_c` / `adaptive_t_max` DFLConfig
knobs (still accepted, deprecated) with one validated sub-config carrying
the three policy axes of the control plane:

  t_policy       "fixed" | "adaptive"     — phase-aware T retuning
                 (Theorem V.3: T*(ρ) = c/√(1−ρ), applied only at phase
                 boundaries so the compiled round never retraces)
  rho_estimator  "spectral" | "frozen" | "gram"  — which live-traffic ρ̂²
                 route feeds the loop (repro.control.estimators)
  weight_policy  "metropolis" | "fmmc"   — how schedules turn fired
                 adjacencies into W_t (fastest-mixing weights optionally
                 biased by measured per-link bandwidth)

Like every DFLConfig field the struct is pure data: the compiled round is
oblivious to it, and `DFLConfig.cache_key()` hashes it through the normal
to_dict route (key version v8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Union

T_POLICIES = ("fixed", "adaptive")
RHO_ESTIMATORS = ("spectral", "frozen", "gram")
WEIGHT_POLICIES = ("metropolis", "fmmc")


@dataclass(frozen=True)
class ControlConfig:
    """Validated control-plane policy selection (a DFLConfig sub-config).

    Defaults describe the open-loop baseline — fixed T, Metropolis
    weights — under which the control plane is inert (`active` is False)
    and a Session behaves exactly as before the redesign.
    """

    t_policy: str = "fixed"          # "adaptive" = online T*(ρ̂)
    rho_estimator: str = "spectral"  # ρ̂² route feeding the T loop
    weight_policy: str = "metropolis"  # W_t construction policy
    c: float = 0.35                  # T*(ρ) = c/√(1−ρ̂)
    t_min: int = 1
    t_max: int = 15
    ewma: float = 0.2                # ρ̂² smoothing (spectral/frozen)
    gram_window: int = 32            # trailing W window (gram estimator)
    fmmc_iters: int = 120            # projected-subgradient iterations
    fmmc_cost_weight: float = 0.0    # bandwidth-penalty weight (0 = pure
                                     # fastest mixing)

    def __post_init__(self):
        def check(cond, msg):
            if not cond:
                raise ValueError(f"ControlConfig: {msg}")

        check(self.t_policy in T_POLICIES,
              f"unknown t_policy {self.t_policy!r}; known: {T_POLICIES}")
        check(self.rho_estimator in RHO_ESTIMATORS,
              f"unknown rho_estimator {self.rho_estimator!r}; "
              f"known: {RHO_ESTIMATORS}")
        check(self.weight_policy in WEIGHT_POLICIES,
              f"unknown weight_policy {self.weight_policy!r}; "
              f"known: {WEIGHT_POLICIES}")
        check(self.c > 0, "c must be positive")
        check(self.t_min >= 1, "t_min must be >= 1")
        check(self.t_max >= self.t_min, "t_max must be >= t_min")
        check(0.0 < self.ewma <= 1.0, "ewma must be in (0, 1]")
        check(self.gram_window >= 1, "gram_window must be >= 1")
        check(self.fmmc_iters >= 1, "fmmc_iters must be >= 1")
        check(self.fmmc_cost_weight >= 0.0,
              "fmmc_cost_weight must be >= 0")

    @property
    def active(self) -> bool:
        """True when any loop departs from the open-loop baseline (the
        Session only instantiates a ControlPlane for active configs)."""
        return self.t_policy != "fixed" or self.weight_policy != "metropolis"

    @classmethod
    def coerce(cls, value: Union["ControlConfig", Mapping, None]
               ) -> "ControlConfig":
        """Accept a ControlConfig, a plain mapping (JSON round-trips), or
        None (defaults)."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, Mapping):
            return cls(**dict(value))
        raise ValueError(f"ControlConfig: cannot coerce {type(value).__name__}"
                         f" (expected ControlConfig, mapping, or None)")
