"""`RoundStats` — the unified per-round observation payload.

One object per completed round carries everything an observer may want:
the realized mixing matrix W_t, the per-client loss vector, the comm
bytes the round moved, and the phase index. Both halves of the former
split surface consume it — `RoundEvent` callbacks (repro.api.session)
and `ControlPlane.observe()` (repro.control.plane) — replacing the
ad-hoc `observe_mixing_matrix` / `observe_frozen_contraction` call sites
that used to live in `repro.api.schedule`.

Derived quantities (loss reduction, consensus stats, the frozen-block Δ²
probe) are memoized lazily: constructing a RoundStats on the hot path
costs a few attribute stores and never syncs a device array.
"""
from __future__ import annotations

from typing import Mapping, Optional

import numpy as np


def metric_loss(metrics: Mapping) -> float:
    """The reported round loss: host-side reduction of the replicated
    per-client loss vector, in one fixed order — bitwise identical on
    every process grid. Falls back to the in-graph scalar (whose
    cross-client reduction XLA may decompose differently per grid) for
    round functions that predate `loss_per_client`."""
    pc = metrics.get("loss_per_client") if hasattr(metrics, "get") else None
    if pc is not None:
        a = np.asarray(pc, np.float32)          # (local_steps, n)
        return float(a.mean(axis=-1, dtype=np.float32)
                      .mean(dtype=np.float32))
    return float(metrics["loss"])


class RoundStats:
    """One round's observation record.

    Required fields are the round index `t` and the realized mixing
    matrix `W`; everything else is optional so the same class serves the
    live round loop (full payload), checkpoint replay, and direct
    schedule use (`RoundStats(t, W)` — a W-only observation). Lazy
    accessors return None when the underlying payload is absent instead
    of raising, so estimators can skip what a given stats object cannot
    provide.
    """

    def __init__(self, t: int, W: np.ndarray, *, phase: int = 0,
                 masks=None, metrics: Optional[Mapping] = None,
                 lora=None, comm_bytes: int = 0):
        self.t = int(t)
        self.W = np.asarray(W)
        self.phase = int(phase)          # phase index (increments at every
                                         # A/B boundary, not parity)
        self.masks = masks               # RoundMasks or None
        self.metrics = metrics           # jax arrays — not yet synced
        self.lora = lora                 # this round's post-mix state
        self.comm_bytes = int(comm_bytes)
        self._loss: Optional[float] = None
        self._loss_pc: Optional[np.ndarray] = None
        self._consensus: Optional[dict] = None
        self._w_gap: Optional[float] = None

    # -- losses -------------------------------------------------------------
    @property
    def loss(self) -> float:
        """Fixed-order scalar loss (``metric_loss``); NaN without metrics."""
        if self.metrics is None:
            return float("nan")
        if self._loss is None:
            self._loss = metric_loss(self.metrics)
        return self._loss

    @property
    def loss_per_client(self) -> Optional[np.ndarray]:
        """(m,) per-client loss averaged over the round's local steps;
        None when the round carried no per-client metrics."""
        if self.metrics is None:
            return None
        pc = self.metrics.get("loss_per_client") \
            if hasattr(self.metrics, "get") else None
        if pc is None:
            return None
        if self._loss_pc is None:
            a = np.asarray(pc, np.float32)      # (local_steps, m)
            self._loss_pc = a.mean(axis=0, dtype=np.float32)
        return self._loss_pc

    # -- mixing / consensus -------------------------------------------------
    def w_gap(self) -> float:
        """Spectral distance ||W_t − J||₂ of this round's mixing matrix."""
        if self._w_gap is None:
            m = self.W.shape[0]
            J = np.ones((m, m)) / m
            self._w_gap = float(np.linalg.norm(self.W - J, ord=2))
        return self._w_gap

    def consensus(self) -> Optional[dict]:
        """Consensus/theory diagnostics of this round's LoRA state
        (delta_a_sq, delta_b_sq, cross_norm, cs_bound) as floats; None
        when the stats carry no state snapshot."""
        if self.lora is None:
            return None
        if self._consensus is None:
            from repro.core.diagnostics import consensus_stats
            self._consensus = {k: float(v) for k, v in
                               consensus_stats(self.lora).items()}
        return self._consensus

    def frozen_delta_sq(self) -> Optional[float]:
        """Δ² of the round's FROZEN LoRA block — the Lemma A.4 consensus
        probe (the frozen block only gossips, so its disagreement contracts
        at exactly ρ² per round). Needs both the masks (to know which block
        froze) and the state snapshot; None otherwise."""
        if self.lora is None or self.masks is None:
            return None
        cs = self.consensus()
        frozen_b = bool(self.masks.update_a)     # A updates ⇒ B frozen
        return cs["delta_b_sq"] if frozen_b else cs["delta_a_sq"]
