"""`repro.control` — the bandwidth-aware closed-loop control plane.

Host-side policy layer between the declarative config (`repro.api`) and
the core math (`repro.core`): estimates ρ from live traffic, optimizes
mixing weights against measured per-link bandwidth, and retunes T at
phase boundaries — while the compiled round keeps consuming W_t and the
masks as plain data (one compile across every policy). Layering: this
package imports `repro.core` only; `repro.api` imports it, never the
reverse.
"""
from repro.control.config import (ControlConfig, RHO_ESTIMATORS,
                                  T_POLICIES, WEIGHT_POLICIES)
from repro.control.estimators import (FrozenContractionRho, GramRho,
                                      RhoEstimator, SpectralRho,
                                      make_estimator)
from repro.control.plane import (ControlPlane, FMMCWeightPolicy,
                                 metropolis_policy, weight_conformance)
from repro.control.stats import RoundStats, metric_loss

__all__ = [
    "ControlConfig", "ControlPlane", "RoundStats",
    "RhoEstimator", "SpectralRho", "FrozenContractionRho", "GramRho",
    "make_estimator", "FMMCWeightPolicy", "metropolis_policy",
    "weight_conformance", "metric_loss",
    "T_POLICIES", "RHO_ESTIMATORS", "WEIGHT_POLICIES",
]
