"""`RhoEstimator` — one protocol over the three live-traffic ρ̂² routes.

Before the control plane these lived apart: the spectral EWMA and the
frozen-contraction probe as `AdaptiveTController.observe_*` methods in
`repro.core.adaptive`, the gram route as the standalone
`rho_sq_from_samples` in `repro.core.topology`. This module unifies them
behind `update(stats) -> None` over a `RoundStats` payload:

  SpectralRho           EWMA of ||W_t − J||₂²  — cheap, per-round, needs
                        only the realized schedule (always available).
  FrozenContractionRho  Lemma A.4 consensus probe: the frozen block's Δ²
                        contracts at exactly ρ² per round, so the ratio
                        of consecutive Δ² is an unbiased sample. Needs
                        state snapshots; resets at phase boundaries (the
                        frozen block changes) and across observation gaps.
  GramRho               ρ̂² = ||mean_t W_tᵀW_t − J||₂ over a trailing
                        window — the tight route for the Appendix A-A
                        mean-square assumption under time-varying graphs.

The float math of the first two delegates to the shared update functions
in `repro.core.adaptive`, so an estimator-driven controller reproduces
the legacy `observe_*` trajectories bit-for-bit.
"""
from __future__ import annotations

from collections import deque
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.adaptive import (contraction_rho_sq_update,
                                 spectral_rho_sq_update)
from repro.core.topology import rho_sq_from_samples
from repro.control.config import RHO_ESTIMATORS
from repro.control.stats import RoundStats


@runtime_checkable
class RhoEstimator(Protocol):
    """Anything that folds RoundStats into a running ρ̂² estimate."""

    rho_sq: float

    def update(self, stats: RoundStats) -> None:
        ...


class SpectralRho:
    """Spectral route: ρ̂² ← EWMA of ||W_t − J||₂² per observed round."""

    def __init__(self, ewma: float = 0.2, rho_sq0: float = 0.5):
        self.ewma = float(ewma)
        self.rho_sq = float(rho_sq0)

    def update(self, stats: RoundStats) -> None:
        self.rho_sq = spectral_rho_sq_update(self.rho_sq,
                                             np.asarray(stats.W), self.ewma)


class FrozenContractionRho:
    """Consensus-probe route (Lemma A.4): ρ̂² from the contraction of the
    frozen block's Δ² between consecutive same-phase rounds. Stats without
    a state snapshot (replay, W-only observations) reset the probe — a
    ratio across a gap would not measure one round's contraction. Note
    the probe needs phases of length ≥ 2: at T = 1 the frozen block
    switches every round, so no two consecutive Δ² describe the same
    gossip-only block and the estimate keeps its prior."""

    def __init__(self, ewma: float = 0.2, rho_sq0: float = 0.5):
        self.ewma = float(ewma)
        self.rho_sq = float(rho_sq0)
        self._prev_delta_sq: float | None = None
        self._prev_phase: int | None = None

    def update(self, stats: RoundStats) -> None:
        delta_sq = stats.frozen_delta_sq()
        if delta_sq is None:
            self._prev_delta_sq = None
            self._prev_phase = None
            return
        if self._prev_delta_sq is not None \
                and stats.phase == self._prev_phase:
            self.rho_sq = contraction_rho_sq_update(
                self.rho_sq, self._prev_delta_sq, delta_sq, self.ewma)
        self._prev_delta_sq = delta_sq
        self._prev_phase = stats.phase


class GramRho:
    """Gram route: ρ̂² = ||mean WᵀW − J||₂ over the trailing `window`
    observed mixing matrices (`rho_sq_from_samples`)."""

    def __init__(self, window: int = 32, rho_sq0: float = 0.5):
        self.rho_sq = float(rho_sq0)
        self._ws: deque = deque(maxlen=int(window))

    def update(self, stats: RoundStats) -> None:
        self._ws.append(np.asarray(stats.W, dtype=float))
        self.rho_sq = rho_sq_from_samples(self._ws)


def make_estimator(kind: str, *, ewma: float = 0.2, window: int = 32,
                   rho_sq0: float = 0.5) -> RhoEstimator:
    """Estimator from its ControlConfig name."""
    if kind == "spectral":
        return SpectralRho(ewma=ewma, rho_sq0=rho_sq0)
    if kind == "frozen":
        return FrozenContractionRho(ewma=ewma, rho_sq0=rho_sq0)
    if kind == "gram":
        return GramRho(window=window, rho_sq0=rho_sq0)
    raise ValueError(f"unknown rho estimator {kind!r}; "
                     f"known: {RHO_ESTIMATORS}")
