"""`ControlPlane` — the host-side closed loop that runs between rounds.

The compiled DFL round treats W_t and the phase masks as *data*; the
ControlPlane is the host process that decides what that data should be.
Between rounds it closes three loops, each selected by `ControlConfig`:

  (a) online ρ estimation — a `RhoEstimator` (repro.control.estimators)
      folds each round's `RoundStats` into ρ̂²;
  (b) fastest-mixing edge weights — a weight policy installed into the
      topology schedule's `set_weights` hook rewires W_t construction
      from Metropolis to FMMC weights (`fastest_mixing_weights`),
      optionally biased by measured per-link bandwidth
      (`CommPlan.link_bytes`);
  (c) phase-aware T switching — ρ̂² feeds the `AdaptiveTController`,
      which re-selects T ONLY at phase boundaries, so the jitted round
      sees the same shapes every round and never retraces
      (`round_fn._cache_size()` stays 1 across all policies).

Every weight policy emits a conformance predicate (`weight_conformance`)
tying its realized W_t stream back to the Lemma A.10 / λ2(L) bound
1−ρ ≥ c_mix·p_eff·λ2(L) plus the structural gossip invariants (symmetry,
double stochasticity, non-negativity).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.adaptive import AdaptiveTController
from repro.core.topology import (fastest_mixing_weights, lemma_a10_gap_bound,
                                 metropolis_weights, rho_sq_from_samples)
from repro.control.config import ControlConfig
from repro.control.estimators import make_estimator
from repro.control.stats import RoundStats

# a weight policy maps the UNDERLYING adjacency to a per-round weight
# function over fired adjacencies: policy(adj) -> (fired_adj -> W)
WeightFn = Callable[[np.ndarray], np.ndarray]
WeightPolicy = Callable[[np.ndarray], WeightFn]


def metropolis_policy(adj: np.ndarray) -> WeightFn:
    """The baseline policy: per-round Metropolis weights of whatever
    subgraph fired (the underlying adjacency plays no role)."""
    return metropolis_weights


class FMMCWeightPolicy:
    """Fastest-mixing weight policy: optimize FMMC edge weights ONCE on
    the underlying adjacency (`fastest_mixing_weights`, optionally
    bandwidth-biased via `link_cost`), then restrict to the fired
    subgraph each round: W_t = I − L(w ∘ fired). Dropping edges only
    grows the diagonal, so W_t stays symmetric, doubly stochastic and
    non-negative for every fired subset — and equals the optimized W on
    static graphs. The per-round cost is one masked copy, not a solve."""

    def __init__(self, link_cost: Optional[np.ndarray] = None, *,
                 iters: int = 120, cost_weight: float = 0.0):
        self.link_cost = link_cost
        self.iters = int(iters)
        self.cost_weight = float(cost_weight)

    def __call__(self, adj: np.ndarray) -> WeightFn:
        adj = np.asarray(adj, dtype=float)
        cost = self.link_cost
        if cost is not None and np.shape(cost) != adj.shape:
            # e.g. a PhaseSwitch sub-graph over a different client count
            # than the CommPlan measured — fall back to unbiased FMMC
            cost = None
        W_star = fastest_mixing_weights(adj, cost, iters=self.iters,
                                        cost_weight=self.cost_weight)
        w_edge = W_star.copy()
        np.fill_diagonal(w_edge, 0.0)

        def weight_fn(fired: np.ndarray) -> np.ndarray:
            f = (np.asarray(fired) > 0).astype(float)
            np.fill_diagonal(f, 0.0)
            W = w_edge * f
            np.fill_diagonal(W, 1.0 - W.sum(1))
            return W

        return weight_fn


def weight_conformance(Ws, adj: np.ndarray, p_eff: float = 1.0,
                       c_mix: float = 1.0 / 16.0) -> dict:
    """The per-policy conformance predicate over a stream of realized
    mixing matrices: structural gossip invariants per sample (symmetry,
    double stochasticity, non-negativity) plus the Lemma A.10 spectral
    bound on the TIME-AVERAGED contraction — 1−ρ̂ ≥ c_mix·p_eff·λ2(L),
    with ρ̂² from the gram route (per-round gaps can legitimately be 0
    when few edges fire; the bound is a mean-square statement).

    Returns {"sym_err", "ds_err", "min_entry", "gap", "bound", "ok"}.
    """
    Ws = [np.asarray(W, dtype=float) for W in Ws]
    if not Ws:
        raise ValueError("weight_conformance needs at least one W sample")
    sym_err = max(float(np.abs(W - W.T).max()) for W in Ws)
    ds_err = max(max(float(np.abs(W.sum(0) - 1.0).max()),
                     float(np.abs(W.sum(1) - 1.0).max())) for W in Ws)
    min_entry = min(float(W.min()) for W in Ws)
    gap = 1.0 - float(np.sqrt(rho_sq_from_samples(Ws)))
    bound = lemma_a10_gap_bound(np.asarray(adj), p_eff, c_mix=c_mix)
    ok = (sym_err < 1e-8 and ds_err < 1e-8 and min_entry > -1e-12
          and gap >= bound - 1e-9)
    return {"sym_err": sym_err, "ds_err": ds_err, "min_entry": min_entry,
            "gap": gap, "bound": bound, "ok": ok}


class ControlPlane:
    """The closed-loop controller a Session instantiates for an active
    `ControlConfig`. Owns one `RhoEstimator`, at most one
    `AdaptiveTController` (t_policy "adaptive"), and at most one weight
    policy (weight_policy "fmmc" — "metropolis" installs nothing so the
    baseline path stays byte-identical). `observe()` consumes the same
    `RoundStats` the `RoundEvent` callbacks see."""

    def __init__(self, config: ControlConfig = ControlConfig(), *,
                 link_cost: Optional[np.ndarray] = None):
        self.config = ControlConfig.coerce(config)
        cc = self.config
        self.estimator = make_estimator(cc.rho_estimator, ewma=cc.ewma,
                                        window=cc.gram_window)
        self.controller: Optional[AdaptiveTController] = None
        if cc.t_policy == "adaptive":
            self.controller = AdaptiveTController(
                c=cc.c, ewma=cc.ewma, t_min=cc.t_min, t_max=cc.t_max)
        self.weight_policy: Optional[WeightPolicy] = None
        if cc.weight_policy == "fmmc":
            self.weight_policy = FMMCWeightPolicy(
                link_cost, iters=cc.fmmc_iters,
                cost_weight=cc.fmmc_cost_weight)
        self.link_cost = link_cost
        self.history: list = []          # per-observation telemetry rows

    # -- readouts -----------------------------------------------------------
    @property
    def rho_hat(self) -> float:
        """Current contraction estimate ρ̂ = √ρ̂²."""
        return float(np.sqrt(self.estimator.rho_sq))

    @property
    def T(self) -> Optional[int]:
        """Interval currently in force (None under t_policy 'fixed')."""
        return self.controller.T if self.controller is not None else None

    # -- the loop -----------------------------------------------------------
    def observe(self, stats: RoundStats) -> None:
        """Fold one completed round into the loop: update ρ̂², propagate it
        to the T controller (which applies it only at the NEXT phase
        boundary — mid-phase retuning would desynchronize the clients'
        phase calendars, the instability the paper's Alg. 1 avoids), and
        append a telemetry row."""
        self.estimator.update(stats)
        if self.controller is not None:
            self.controller.rho_sq = self.estimator.rho_sq
        self.history.append({"t": stats.t,
                             "rho_sq": float(self.estimator.rho_sq),
                             "T": self.controller.T
                             if self.controller is not None else 0,
                             "phase": stats.phase,
                             "comm_bytes": stats.comm_bytes})

    def observe_replay(self, t: int, W: np.ndarray) -> None:
        """Checkpoint-replay hook: re-feed the recorded W_t stream as
        W-only stats. Spectral and gram replay exactly (they consume only
        W); the frozen probe resets and re-locks from live rounds — its
        Δ² inputs are a function of training state that replay does not
        re-materialize."""
        self.observe(RoundStats(t, W))
