from repro.checkpoint.ckpt import load_pytree, restore_sharded, save_pytree

__all__ = ["load_pytree", "restore_sharded", "save_pytree"]
