"""Flat-npz pytree checkpointing, sharding-aware on restore.

Leaves are stored under path-encoded keys ("groups/0/attn/wq"); structure is
reconstructed from the keys (dicts and lists round-trip; list indices are
numeric path components). ``restore_sharded`` places leaves with
jax.device_put against a sharding tree — used by the launcher to restore a
run directly into the production mesh layout.
"""
from __future__ import annotations

import io
import os
import re
from typing import Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def listify(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(re.fullmatch(r"\d+", k) for k in keys):
            idx = sorted(int(k) for k in keys)
            assert idx == list(range(len(idx))), f"sparse list: {keys}"
            return [listify(node[str(i)]) for i in idx]
        return {k: listify(v) for k, v in node.items()}

    return listify(root)


def save_pytree(path: str, tree) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        np.savez(f, **flat)


def load_pytree(path: str):
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)


def restore_sharded(path: str, shardings=None):
    """Load and device_put each leaf with its sharding (or default device)."""
    tree = load_pytree(path)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, tree)
    flat_s = _flatten_shardings(shardings)
    flat_t = _flatten(tree)
    out = {}
    for k, v in flat_t.items():
        s = flat_s.get(k)
        out[k] = jax.device_put(v, s) if s is not None else jax.numpy.asarray(v)
    return _unflatten(out)


def _flatten_shardings(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_shardings(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten_shardings(v, f"{prefix}{i}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out
