"""Admission and fairness over adapter queues.

The engine used to own a single FIFO deque; that is the degenerate case
of this scheduler (one queue, no quotas). Here requests are queued *per
adapter* and admission runs deficit-round-robin (DRR) between the
queues, so one tenant flooding the engine cannot starve the others: each
non-empty queue earns ``quantum`` credit per rotation and releases one
request when its deficit covers the cost (uniform cost 1 — requests are
admitted one slot at a time). With a single queue this is exactly FIFO,
which keeps the pre-scheduler engine behavior bit-for-bit.

`TenantQuota` bounds a tenant two ways: ``max_queued`` rejects at submit
time (`QuotaExceeded`), ``max_active`` holds a queue back at admission
while the tenant already occupies that many slots.

The scheduler also owns the request registry and lifecycle metrics:
every `Request` records submit/admit/first-token/done both in engine
ticks and wall-clock, plus its preemption count; `summary()` aggregates
queue wait, TTFT, and latency percentiles for the traffic benchmark.

Policy only — no jax, no cache. Page eviction *mechanics* live in
`launch.serving.ServeEngine`; this module decides who queues and who
runs next.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

AdapterKey = Union[str, int, None]


@dataclass
class Request:
    """One generation request: prompt tokens, generation budget, the
    (optional) pool adapter that should serve it, and its lifecycle
    record (ticks + wall-clock for queue wait / TTFT / completion)."""
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    adapter: AdapterKey = None           # pool row / name; None = base
    tokens_out: list = field(default_factory=list)
    done: bool = False
    # lifecycle (filled in by the scheduler / engine)
    submit_tick: int = 0
    admit_tick: Optional[int] = None
    first_token_tick: Optional[int] = None
    done_tick: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    preemptions: int = 0

    @property
    def queue_wait_ticks(self) -> Optional[int]:
        if self.admit_tick is None:
            return None
        return self.admit_tick - self.submit_tick

    @property
    def ttft_ticks(self) -> Optional[int]:
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.submit_tick


@dataclass(frozen=True)
class TenantQuota:
    """Per-adapter limits: ``max_queued`` rejects submits past the queue
    bound, ``max_active`` caps simultaneously held slots."""
    max_active: Optional[int] = None
    max_queued: Optional[int] = None


class QuotaExceeded(RuntimeError):
    """Submit rejected: the adapter's queue is at its ``max_queued``."""


def _stats(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"n": 0}
    arr = np.asarray(xs, np.float64)
    return {"n": int(arr.size),
            "mean": float(arr.mean()),
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "max": float(arr.max())}


class Scheduler:
    """Deficit-round-robin admission over per-adapter queues."""

    def __init__(self, quotas: Optional[Dict[AdapterKey, TenantQuota]] = None,
                 quantum: float = 1.0, clock=time.perf_counter):
        self.quotas: Dict[AdapterKey, TenantQuota] = dict(quotas or {})
        self.quantum = float(quantum)
        self.clock = clock
        self.requests: Dict[int, Request] = {}
        self._queues: Dict[AdapterKey, deque] = {}
        self._deficit: Dict[AdapterKey, float] = {}
        self._order: List[AdapterKey] = []   # RR rotation, insertion order
        self._rr = 0
        self.n_submitted = 0
        self.n_completed = 0
        self.n_preemptions = 0

    # -- queue state ----------------------------------------------------
    def _queue_for(self, key: AdapterKey) -> deque:
        if key not in self._queues:
            self._queues[key] = deque()
            self._deficit[key] = 0.0
            self._order.append(key)
        return self._queues[key]

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def queued_for(self, key: AdapterKey) -> int:
        q = self._queues.get(key)
        return len(q) if q is not None else 0

    def queued_requests(self) -> List[Request]:
        """Every queued request, RR-queue order (for introspection)."""
        out: List[Request] = []
        for key in self._order:
            out.extend(self._queues[key])
        return out

    # -- lifecycle ------------------------------------------------------
    def submit(self, req: Request, tick: int = 0) -> None:
        """Enqueue; raises `QuotaExceeded` past the tenant's queue bound
        (the request is NOT registered in that case)."""
        quota = self.quotas.get(req.adapter)
        if quota is not None and quota.max_queued is not None and \
                self.queued_for(req.adapter) >= quota.max_queued:
            raise QuotaExceeded(
                f"adapter {req.adapter!r}: {quota.max_queued} requests "
                f"already queued")
        req.submit_tick = tick
        req.submit_time = self.clock()
        self.requests[req.rid] = req
        self._queue_for(req.adapter).append(req)
        self.n_submitted += 1

    def requeue_front(self, req: Request) -> None:
        """Preempted request back to the head of its queue (it holds
        admission priority — it already ran once)."""
        req.preemptions += 1
        self.n_preemptions += 1
        self._queue_for(req.adapter).appendleft(req)

    def next_request(self, active_counts: Dict[AdapterKey, int]
                     ) -> Optional[Request]:
        """DRR pick: rotate over the adapter queues from the RR cursor;
        each visited non-empty queue earns ``quantum``, the first whose
        deficit covers cost 1 (and whose tenant is under ``max_active``)
        releases its head. None when nothing is admissible. The caller
        marks admission (`mark_admitted`) once placement succeeds, or
        `push_front`s the request back."""
        n = len(self._order)
        for step in range(n):
            key = self._order[(self._rr + step) % n]
            q = self._queues[key]
            if not q:
                self._deficit[key] = 0.0   # classic DRR: idle queues
                continue                   # hold no credit
            quota = self.quotas.get(key)
            if quota is not None and quota.max_active is not None and \
                    active_counts.get(key, 0) >= quota.max_active:
                continue
            self._deficit[key] += self.quantum
            if self._deficit[key] >= 1.0:
                self._deficit[key] -= 1.0
                req = q.popleft()
                self._rr = (self._rr + step + 1) % n
                return req
        return None

    def push_front(self, req: Request) -> None:
        """Un-pop: the engine could not place the request after all (no
        pages free at admission). Not a preemption — nothing ran."""
        self._queue_for(req.adapter).appendleft(req)

    def mark_admitted(self, req: Request, tick: int) -> None:
        req.admit_tick = tick

    def mark_first_token(self, req: Request, tick: int) -> None:
        if req.first_token_tick is None:
            req.first_token_tick = tick
            req.first_token_time = self.clock()

    def mark_done(self, req: Request, tick: int) -> None:
        req.done_tick = tick
        req.done_time = self.clock()
        self.n_completed += 1

    # -- metrics --------------------------------------------------------
    def summary(self) -> dict:
        """Lifecycle aggregates over every request seen so far."""
        reqs = list(self.requests.values())
        waits = [float(r.queue_wait_ticks) for r in reqs
                 if r.queue_wait_ticks is not None]
        ttfts = [float(r.ttft_ticks) for r in reqs
                 if r.ttft_ticks is not None]
        ttft_s = [r.first_token_time - r.submit_time for r in reqs
                  if r.first_token_time is not None]
        lat_s = [r.done_time - r.submit_time for r in reqs
                 if r.done_time is not None]
        return {
            "submitted": self.n_submitted,
            "completed": self.n_completed,
            "queued": self.n_queued,
            "preemptions": self.n_preemptions,
            "queue_wait_ticks": _stats(waits),
            "ttft_ticks": _stats(ttfts),
            "ttft_s": _stats(ttft_s),
            "latency_s": _stats(lat_s),
        }
