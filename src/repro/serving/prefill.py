"""Chunked prefill driver: long prompts stream into the serving cache in
fixed-size compiled chunks.

The engine's default prefill is teacher-forcing through the decode step —
one engine tick per prompt token, correct but O(prompt) ticks. This
driver instead feeds a slot's prompt through
`transformer.chunk_prefill_step` in ``chunk``-token slices: every slice
has the same traced shape (the final one is padded; pads neither write
KV nor produce used output), so ONE compiled chunk trace serves every
prompt length — never a per-length trace.

The driver prefills ``seed[:-1]`` only. The engine then teacher-forces
the final prompt token through the normal decode step, which both writes
that token's KV and emits the first generated token — exactly the state
the teacher-forced path reaches, so downstream decode is unchanged.

Mechanism only: page allocation for the chunks is the engine's job
(tables must cover ``ceil((len(seed)-1)/page_size)`` pages before `run`).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.models import transformer as tf


class ChunkedPrefill:
    """One jitted chunk step per engine (two traces with/without lora,
    mirroring the engine's decode closure). ``compile_count`` counts
    traces and must stay at the number of distinct signatures used (1
    in steady state — asserted by tests)."""

    def __init__(self, params, cfg, chunk: int):
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        if not tf.supports_chunked_prefill(cfg):
            raise ValueError(
                f"chunked prefill unsupported for {cfg.name}: attention-only "
                f"decoders (recurrent/enc-dec archs use the engine's "
                f"teacher-forced prefill)")
        self.params = params
        self.cfg = cfg
        self.chunk = int(chunk)
        self.compile_count = 0

        def _step(p, c, toks, slot, start, limit):
            self.compile_count += 1
            return tf.chunk_prefill_step(p, cfg, toks, c, slot, start, limit)

        def _step_lora(p, c, toks, slot, start, limit, lo):
            self.compile_count += 1
            return tf.chunk_prefill_step(p, cfg, toks, c, slot, start, limit,
                                         lora=lo)

        self._step = jax.jit(_step)
        self._step_lora = jax.jit(_step_lora)

    def n_prefill_tokens(self, seed_len: int) -> int:
        """Tokens this driver would write for a seed (the rest is the
        engine's teacher-forced final token)."""
        return max(seed_len - 1, 0)

    def run(self, cache, seed: np.ndarray, slot: int, *, lora=None):
        """Stream ``seed[:-1]`` into ``cache`` for batch row ``slot``;
        returns the new cache. ``lora`` is the slot-mapped lora tree for a
        (1, C, d) activation (slot maps of shape (1,)), or None."""
        n_pre = self.n_prefill_tokens(len(seed))
        C = self.chunk
        for start in range(0, n_pre, C):
            toks = np.zeros((1, C), np.int32)
            part = np.asarray(seed[start:min(start + C, n_pre)], np.int32)
            toks[0, :len(part)] = part
            args = (self.params, cache, toks, np.int32(slot),
                    np.int32(start), np.int32(n_pre))
            cache = (self._step(*args) if lora is None
                     else self._step_lora(*args, lora))
        return cache
