"""Page-granular KV storage: `PagePool` + `BlockTables`.

The contiguous serving cache sizes every slot for ``max_len`` tokens up
front, so ``n_slots x max_len`` is a compile-time memory wall. Paging
splits the global-attention KV buffers into fixed-size physical pages
(``(n_pages, page_size, n_kv, head_dim)``) shared by all slots; each slot
holds a *block table* row mapping its logical page index to a physical
page. The compiled decode step receives the table as data — occupancy
changes never retrace.

Conventions (relied on by `models.attention` and the paged kernel):

- **Physical page 0 is the null page.** It is never allocated; free (or
  freshly reset) block-table rows are all-zeros, so inactive slots'
  writes land on page 0 where no active slot ever reads them. The pool
  therefore hands out pages ``1..n_pages-1`` only.
- Tables are host-side numpy; the engine ships them to the device once
  per tick (fixed shape ``(n_slots, pages_per_seq)`` int32).
- Allocation is all-or-nothing per request step: a slot either gets the
  page it needs or the caller preempts someone (policy lives in
  `launch.serving`, not here).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

NULL_PAGE = 0


class PagePool:
    """Free-list over ``n_pages`` physical KV pages (page 0 reserved)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"PagePool needs >= 2 pages (one is the "
                             f"reserved null page), got {n_pages}")
        self.n_pages = int(n_pages)
        # LIFO free list; seeded so the first allocations are 1, 2, 3, ...
        # A set mirrors membership: `free()`'s double-free check used to
        # scan the list (O(n) per page), and the pool holds thousands of
        # pages in a serving process.
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        self._free_set = set(self._free)

    @property
    def capacity(self) -> int:
        """Usable pages (excludes the null page)."""
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.capacity - self.n_free

    def alloc(self) -> Optional[int]:
        """One page, or None when exhausted (never raises: the caller
        decides between queueing and preemption)."""
        if not self._free:
            return None
        p = self._free.pop()
        self._free_set.discard(p)
        return p

    def alloc_many(self, k: int) -> Optional[List[int]]:
        """k pages all-or-nothing; None leaves the pool untouched."""
        if k < 0:
            raise ValueError(f"alloc_many({k})")
        if len(self._free) < k:
            return None
        pages = self._free[-k:][::-1]
        del self._free[len(self._free) - k:]
        self._free_set.difference_update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return a batch of pages. Atomic: the WHOLE batch is validated
        (range, double-free against the pool, duplicates within the
        batch) before any page is returned, so a raising call leaves the
        pool exactly as it was — a mid-sequence raise used to strand the
        already-appended prefix as freed while the rest stayed leaked."""
        batch = [int(p) for p in pages]
        seen = set()
        for p in batch:
            if not (0 < p < self.n_pages):
                raise ValueError(f"freeing invalid page {p}")
            if p in self._free_set or p in seen:
                raise ValueError(f"double free of page {p}")
            seen.add(p)
        self._free.extend(batch)
        self._free_set.update(batch)


class BlockTables:
    """Per-slot logical->physical page maps, ``(n_slots, pages_per_seq)``.

    Owns the host-side table array and each slot's allocation list; the
    pool stays a dumb free-list. `grow` is idempotent per page index and
    all-or-nothing, `release` returns every page and zeroes the row back
    to the null page.
    """

    def __init__(self, n_slots: int, pages_per_seq: int):
        self.n_slots = int(n_slots)
        self.pages_per_seq = int(pages_per_seq)
        self.table = np.zeros((self.n_slots, self.pages_per_seq), np.int32)
        self._owned: List[List[int]] = [[] for _ in range(self.n_slots)]

    def n_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def grow(self, slot: int, upto_page: int, pool: PagePool) -> bool:
        """Ensure logical pages ``0..upto_page`` are mapped for ``slot``.
        Returns False (pool unchanged) when the pool cannot cover the
        missing pages."""
        if upto_page >= self.pages_per_seq:
            raise ValueError(
                f"slot {slot} needs logical page {upto_page} but tables "
                f"cover {self.pages_per_seq} pages per sequence")
        need = upto_page + 1 - len(self._owned[slot])
        if need <= 0:
            return True
        pages = pool.alloc_many(need)
        if pages is None:
            return False
        for p in pages:
            self.table[slot, len(self._owned[slot])] = p
            self._owned[slot].append(p)
        return True

    def release(self, slot: int, pool: PagePool) -> None:
        pool.free(self._owned[slot])
        self._owned[slot] = []
        self.table[slot, :] = NULL_PAGE
