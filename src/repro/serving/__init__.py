"""repro.serving — the paged-KV serving core.

Three mechanisms, composed by `launch.serving.ServeEngine` and surfaced
through `api.serving.ServingSession`:

  `paging`     `PagePool` + `BlockTables`: page-granular KV storage for the
               decode slots. Slot count and context length stop being a
               compile-time memory wall — physical pages are allocated on
               demand and the compiled decode step sees only a fixed-shape
               block table (data, never a new trace).
  `prefill`    `ChunkedPrefill`: long prompts stream into pages in
               fixed-size compiled chunks (one trace per chunk shape)
               instead of one tick per prompt token or one giant
               per-length trace.
  `scheduler`  `Scheduler` + `TenantQuota`: admission control and fairness
               over adapters — per-tenant quotas, deficit-round-robin
               between adapter queues, preemption-by-page-eviction when
               the pool is exhausted, and request lifecycle metrics
               (queue wait, TTFT, preemptions).

Layering: imports models/kernels/configs only; `launch.serving` (the
engine) and `api.serving` (the session) sit above.
"""
from repro.serving.paging import BlockTables, PagePool
from repro.serving.prefill import ChunkedPrefill
from repro.serving.scheduler import (QuotaExceeded, Request, Scheduler,
                                     TenantQuota)

__all__ = [
    "BlockTables",
    "ChunkedPrefill",
    "PagePool",
    "QuotaExceeded",
    "Request",
    "Scheduler",
    "TenantQuota",
]
