"""`MaskSchedule` — one interface for static and adaptive phase schedules.

The DFL round consumes only a 4-scalar `RoundMasks`; what varies across
experiments is *how* those masks evolve over rounds. `MaskSchedule`
unifies the two regimes behind `next_masks(t, observations)`:

  * `StaticSchedule` — the paper's fixed-T calendar (`round_masks`),
    stateless, derived purely from the round index.
  * `AdaptiveSchedule` — the online controller (`AdaptiveTController`):
    observes each round's realized mixing matrix W_t (passed through
    `observations["W"]`) and re-selects T at phase boundaries.

`observations` is a read-only mapping the Session fills per round —
currently {"W": np.ndarray, "round": int, "session": Session}. Custom
schedules (damped mixing, per-round method switching, curriculum phases)
implement the same protocol and plug into `Session(schedule=...)`.

Rho estimation goes through the unified `RhoEstimator` protocol
(repro.control.estimators): `AdaptiveSchedule` folds each observed W_t
into a `RoundStats` payload and updates its estimator, instead of the
former ad-hoc `observe_mixing_matrix` call — same float sequence, one
observation surface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Protocol, runtime_checkable

import numpy as np

from repro.control.estimators import RhoEstimator, SpectralRho
from repro.control.stats import RoundStats
from repro.core.adaptive import AdaptiveTController, adaptive_round_masks
from repro.core.alternating import RoundMasks, round_masks


@runtime_checkable
class MaskSchedule(Protocol):
    """Anything that maps (round index, observations) -> RoundMasks."""

    def next_masks(self, t: int, observations: Mapping) -> RoundMasks:
        ...


@dataclass
class StaticSchedule:
    """The paper's fixed switching interval: masks from (method, t, T)."""
    method: str = "tad"
    T: int = 1

    def next_masks(self, t: int, observations: Mapping) -> RoundMasks:
        return round_masks(self.method, t, self.T)


class AdaptiveSchedule:
    """Online T selection (beyond-paper §VII): wraps AdaptiveTController.

    `estimator` selects the ρ̂² route: "spectral" folds each observed W_t
    into a `SpectralRho` (float-identical to the controller's legacy
    `observe_mixing_matrix` path); "none" leaves the controller's rho
    untouched (to drive it externally — e.g. by a `ControlPlane` — or to
    pin T for parity tests); any `RhoEstimator` instance plugs in as-is.
    `t_trace` records the interval in force at every round.
    """

    def __init__(self, method: str = "tad", *, c: float = 0.35,
                 t_max: int = 15, t_min: int = 1, ewma: float = 0.2,
                 estimator="spectral",
                 controller: Optional[AdaptiveTController] = None):
        self.method = method
        self.estimator = estimator
        self.controller = controller if controller is not None else \
            AdaptiveTController(c=c, t_max=t_max, t_min=t_min, ewma=ewma)
        if estimator == "spectral":
            self._est: Optional[RhoEstimator] = SpectralRho(
                ewma=self.controller.ewma, rho_sq0=self.controller.rho_sq)
        elif estimator == "none" or estimator is None:
            self._est = None
        elif isinstance(estimator, RhoEstimator):
            self._est = estimator
        else:
            raise ValueError(f"unknown estimator {estimator!r} (expected "
                             f"'spectral', 'none', or a RhoEstimator)")
        self.t_trace: list[int] = []

    def next_masks(self, t: int, observations: Mapping) -> RoundMasks:
        if self._est is not None:
            stats = observations.get("stats")
            if stats is None:
                W = observations.get("W")
                stats = RoundStats(t, np.asarray(W)) if W is not None \
                    else None
            if stats is not None:
                self._est.update(stats)
                self.controller.rho_sq = self._est.rho_sq
        masks = adaptive_round_masks(self.controller, self.method)
        self.t_trace.append(self.controller.T)
        return masks

    @property
    def T(self) -> int:
        return self.controller.T

    @property
    def rho_hat(self) -> float:
        return float(np.sqrt(self.controller.rho_sq))
