"""Multi-adapter TAD-LoRA serving on the `repro.api` substrate.

Decentralized training ends with one LoRA adapter PER CLIENT (plus their
gossip average); serving them should not need one engine per client. This
module closes the train->serve loop:

  `AdapterPool`     N adapters kept stacked as one pytree whose leaves carry
                    the pool axis at position -3 — exactly the training
                    layout, so `Session` checkpoints load without reshaping.
                    Row 0 is always the zero ("base") adapter; updates are
                    row-scatters, so weight hot-swap never changes a shape.
  `ServingSession`  config -> engine: owns the base model, the pool, and a
                    `launch.serving.ServeEngine`; requests name adapters,
                    slots gather them by id inside one compiled decode step.
  `ServeSync`       a Session callback that pushes the live per-client (and
                    consensus) adapters into a pool every K rounds —
                    serve-while-training.

    cfg = DFLConfig(model="gemma3-1b", rounds=20)
    Session(cfg, callbacks=[CheckpointCallback("run.npz")]).run()
    serving = ServingSession(model="gemma3-1b", checkpoint="run.npz")
    toks = serving.generate(prompt, adapter="client_3")
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.callbacks import Callback
from repro.checkpoint import load_pytree
from repro.configs import get_config
from repro.core.lora import build_lora_tree, client_mean
from repro.launch.serving import ServeEngine
from repro.models import transformer as tf

AdapterRef = Union[str, int, None]

_BASE = "base"
_CONSENSUS = "consensus"


def _pool_axis_rows(leaf) -> int:
    """Size of the pool/client axis (position -3) of an a/b leaf."""
    return leaf.shape[-3]


def _is_ab(node) -> bool:
    return (isinstance(node, dict) and "a" in node and "b" in node
            and not isinstance(node["a"], dict))


class AdapterPool:
    """A fixed-capacity bank of LoRA adapters stacked along axis -3.

    ``stacked`` mirrors the training lora tree (`core.lora.build_lora_tree`
    with ``n_clients=capacity``): plain leaves (N, d, r) and group-scanned
    leaves (G, N, d, r). ``capacity`` is a compile-time constant — the
    served shapes depend on it and on nothing else, so any number of
    registered adapters (and any later `update`) reuses one compiled
    decode step. Row 0 is the all-zero "base" adapter (ΔW = 0 — serving it
    reproduces the raw base model bit-for-bit).
    """

    def __init__(self, stacked, ids: Sequence[str]):
        self.stacked = jax.tree.map(jnp.asarray, stacked)
        self._ids: list[Optional[str]] = list(ids)
        leaves = jax.tree.leaves(self.stacked)
        if not leaves:
            raise ValueError("empty adapter tree")
        cap = _pool_axis_rows(leaves[0])
        if len(self._ids) != cap:
            raise ValueError(f"{len(self._ids)} ids for capacity {cap}")
        if self._ids[0] != _BASE:
            raise ValueError("row 0 must be the reserved 'base' adapter")
        self.capacity = cap

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_stacked(cls, lora, ids: Optional[Sequence[str]] = None, *,
                     capacity: int = 0,
                     consensus: bool = True) -> "AdapterPool":
        """Build a pool from a client-stacked training lora tree
        ((..., m, d, r) at axis -3 — a `Session.lora` or checkpoint tree).
        Registers "base" (zeros, row 0), "client_i" for each of the m
        client rows, and — with ``consensus`` — their mean; remaining rows
        up to ``capacity`` (default: exactly enough) stay free for `add`.
        """
        lora = jax.tree.map(jnp.asarray, lora)
        m = _pool_axis_rows(jax.tree.leaves(lora)[0])
        if ids is None:
            ids = [f"client_{i}" for i in range(m)]
        ids = list(ids)
        if len(ids) != m:
            raise ValueError(f"{len(ids)} ids for {m} stacked adapters")
        want = 1 + m + (1 if consensus else 0)
        cap = max(capacity, want)

        def alloc(leaf):
            shape = list(leaf.shape)
            shape[-3] = cap
            buf = jnp.zeros(shape, leaf.dtype)
            return buf.at[..., 1:1 + m, :, :].set(leaf)
        stacked = jax.tree.map(alloc, lora)
        names: list[Optional[str]] = [_BASE] + ids + [None] * (cap - 1 - m)
        pool = cls(stacked, names)
        if consensus:
            pool.add(_CONSENSUS, client_mean(lora))
        return pool

    @classmethod
    def from_checkpoint(cls, path: str, *, capacity: int = 0,
                        consensus: bool = True) -> "AdapterPool":
        """Load the per-client adapters a `Session.save` /
        `CheckpointCallback` checkpoint holds under its "lora" key."""
        return cls.from_stacked(load_pytree(path)["lora"],
                                capacity=capacity, consensus=consensus)

    @classmethod
    def empty(cls, params, cfg, *, capacity: int,
              dtype=jnp.float32) -> "AdapterPool":
        """All-base pool shaped for ``params``/``cfg`` with ``capacity``
        free rows — the serve-while-training starting point before the
        first `ServeSync` push."""
        zeros = build_lora_tree(jax.random.key(0), params, cfg,
                                n_clients=capacity, dtype=dtype)
        zeros = jax.tree.map(jnp.zeros_like, zeros)
        return cls(zeros, [_BASE] + [None] * (capacity - 1))

    # -- lookup -------------------------------------------------------------
    @property
    def ids(self) -> list[str]:
        """Registered adapter names, pool order (excludes free rows)."""
        return [i for i in self._ids if i is not None]

    @property
    def n_adapters(self) -> int:
        return len(self.ids)

    def row(self, adapter: AdapterRef) -> int:
        """Resolve an adapter name (or raw row index) to its pool row;
        ``None`` resolves to the base (zero) adapter."""
        if adapter is None:
            return 0
        if isinstance(adapter, (int, np.integer)):
            if not 0 <= adapter < self.capacity:
                raise KeyError(f"adapter row {adapter} out of range")
            return int(adapter)
        try:
            return self._ids.index(adapter)
        except ValueError:
            raise KeyError(f"unknown adapter {adapter!r}; "
                           f"registered: {self.ids}") from None

    def adapter(self, adapter: AdapterRef):
        """Extract one adapter as a single (unstacked) lora tree."""
        i = self.row(adapter)
        return jax.tree.map(lambda s: s[..., i, :, :], self.stacked)

    # -- mutation (all row-scatters: shapes never change) -------------------
    def _set_row(self, i: int, tree) -> None:
        self.stacked = jax.tree.map(
            lambda s, n: s.at[..., i, :, :].set(n.astype(s.dtype)),
            self.stacked, jax.tree.map(jnp.asarray, tree))

    def _register(self, adapter_id: str) -> int:
        """Claim the first free row for ``adapter_id`` (bookkeeping only —
        the caller writes the weights)."""
        if adapter_id in self._ids:
            raise ValueError(f"adapter {adapter_id!r} already registered; "
                             "use update()")
        try:
            i = self._ids.index(None)
        except ValueError:
            raise ValueError(
                f"pool full ({self.capacity}); build it with a larger "
                "capacity= (growing would recompile the decode step)"
            ) from None
        self._ids[i] = adapter_id
        return i

    def add(self, adapter_id: str, tree) -> int:
        """Register a new adapter in the first free row (single lora tree,
        no client axis). Raises when the pool is full — capacity is a
        compile-time constant by design."""
        i = self._register(adapter_id)
        self._set_row(i, tree)
        return i

    def update(self, adapter: AdapterRef, tree) -> None:
        """Hot-swap one adapter's weights (single lora tree). A pure
        row-scatter: engines pick the new weights up on their next tick
        with no recompilation; other rows are untouched."""
        i = self.row(adapter)
        if i == 0:
            raise ValueError("row 0 is the reserved zero 'base' adapter")
        self._set_row(i, tree)

    def sync_from(self, stacked_lora, *, consensus: bool = True) -> None:
        """Bulk hot-swap from a client-stacked training tree: client i's
        row (registering "client_i" if new) and — with ``consensus`` —
        their mean. One scatter per leaf for all clients (the `ServeSync`
        fast path)."""
        stacked_lora = jax.tree.map(jnp.asarray, stacked_lora)
        m = _pool_axis_rows(jax.tree.leaves(stacked_lora)[0])
        # register-only for new names; the ONE bulk scatter below carries
        # every client's weights
        rows = [self._ids.index(f"client_{i}") if f"client_{i}" in self._ids
                else self._register(f"client_{i}") for i in range(m)]
        idx = jnp.asarray(rows, jnp.int32)
        self.stacked = jax.tree.map(
            lambda s, src: s.at[..., idx, :, :].set(src.astype(s.dtype)),
            self.stacked, stacked_lora)
        mean = client_mean(stacked_lora)
        if consensus:
            if _CONSENSUS in self._ids:
                self.update(_CONSENSUS, mean)
            else:
                self.add(_CONSENSUS, mean)

    # -- the engine-facing view --------------------------------------------
    def serving_lora(self, slot_rows) -> dict:
        """The lora tree one engine tick feeds `decode_step`: every a/b
        leaf gains a "slot" map ((B,), or (G, B) under the group scan so
        lax.scan slices it per group) naming each decode slot's pool row.
        The a/b arrays are shared with the pool (no copy)."""
        s = jnp.asarray(slot_rows, jnp.int32)

        def wrap(node):
            if _is_ab(node):
                a = node["a"]
                slot = (jnp.broadcast_to(s, (a.shape[0], s.shape[0]))
                        if a.ndim == 4 else s)
                return {"a": node["a"], "b": node["b"], "slot": slot}
            if isinstance(node, dict):
                return {k: wrap(v) for k, v in node.items()}
            if isinstance(node, list):
                return [wrap(v) for v in node]
            return node
        return wrap(self.stacked)


class ServingSession:
    """A running multi-adapter serving deployment (the inference-side
    sibling of `Session`).

    Owns the base model, an `AdapterPool`, and a continuous-batching
    `ServeEngine`; every decode slot independently selects the adapter its
    request named, through one compiled decode step for the engine's whole
    lifetime (``serving.compile_count`` stays 1).

        serving = ServingSession(model="gemma3-1b", checkpoint="run.npz",
                                 n_slots=8)
        toks = serving.generate(prompt, adapter="client_3")
        serving.update_adapter("client_3", new_tree)   # hot-swap

    The base params are re-derived from ``init_seed`` exactly like
    `Session` derives them (so a training checkpoint pairs with the right
    base weights); pass ``params=`` to serve existing weights instead.
    The pool comes from ``adapters=`` (pre-built) or ``checkpoint=`` (a
    `Session` checkpoint); ``capacity=`` alone reserves an all-base pool
    to `add_adapter` into later. With none of the three, the session is
    pool-less and serves the base model with zero adapter overhead.

    The serving-core knobs pass straight through to the engine:
    ``paged``/``page_size``/``n_pages`` (block KV-cache pool),
    ``prefill_chunk`` (chunked prefill for long prompts), and ``quotas``
    (per-adapter `launch.serving.TenantQuota` limits). `metrics()` returns
    the request-lifecycle aggregates.
    """

    def __init__(self, model: str = "gemma3-1b", *, reduced: bool = True,
                 model_cfg=None, params=None, checkpoint: str = "",
                 adapters: Optional[AdapterPool] = None, capacity: int = 0,
                 consensus: bool = True, n_slots: int = 4,
                 max_len: int = 256, init_seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None, prefill_chunk: int = 0,
                 quotas: Optional[dict] = None):
        self.model_cfg = model_cfg if model_cfg is not None \
            else (get_config(model).reduced() if reduced
                  else get_config(model))
        self.params = params if params is not None \
            else tf.init_params(jax.random.key(init_seed), self.model_cfg)
        if adapters is not None:
            self.pool = adapters
        elif checkpoint:
            self.pool = AdapterPool.from_checkpoint(
                checkpoint, capacity=capacity, consensus=consensus)
        elif capacity:
            # no adapters yet but room reserved: an all-base pool to
            # `add_adapter` into later (capacity is a compile-time constant)
            self.pool = AdapterPool.empty(self.params, self.model_cfg,
                                          capacity=capacity)
        else:
            # base-model-only serving: skip the pool (and the per-slot
            # gather work) entirely
            self.pool = None
        self.engine = ServeEngine(self.params, self.model_cfg,
                                  n_slots=n_slots, max_len=max_len,
                                  adapters=self.pool, paged=paged,
                                  page_size=page_size, n_pages=n_pages,
                                  prefill_chunk=prefill_chunk, quotas=quotas)

    @classmethod
    def from_session(cls, session, *, consensus: bool = True,
                     capacity: int = 0, **kw) -> "ServingSession":
        """Serve a live (or finished) training `Session`: its base params
        and a pool seeded from its current per-client adapters. Pair with
        `ServeSync` to keep the pool tracking the run."""
        pool = AdapterPool.from_stacked(session.lora, capacity=capacity,
                                        consensus=consensus)
        return cls(model_cfg=session.model_cfg, params=session.base,
                   adapters=pool, **kw)

    # -- request interface --------------------------------------------------
    def submit(self, prompt, *, adapter: AdapterRef = None,
               max_new: int = 32, eos_id: Optional[int] = None) -> int:
        """Queue a prompt on the named adapter; returns the request id."""
        return self.engine.submit(prompt, max_new=max_new, eos_id=eos_id,
                                  adapter=adapter)

    def tick(self) -> int:
        """Advance every active slot by one token (see `ServeEngine.tick`)."""
        return self.engine.tick()

    def run(self, max_ticks: int = 10_000) -> None:
        """Drain the queue (all submitted requests complete)."""
        self.engine.run(max_ticks)

    def result(self, rid: int) -> list[int]:
        """Generated tokens of a (finished or in-flight) request."""
        return self.engine.requests[rid].tokens_out

    def generate(self, prompt, *, adapter: AdapterRef = None,
                 max_new: int = 32, eos_id: Optional[int] = None
                 ) -> list[int]:
        """Blocking convenience: submit + drain + return the new tokens.
        Batch-friendly throughput comes from `submit` + `run` instead."""
        rid = self.submit(prompt, adapter=adapter, max_new=max_new,
                          eos_id=eos_id)
        self.run()
        return self.result(rid)

    # -- pool management ----------------------------------------------------
    @property
    def adapters(self) -> list[str]:
        """Names currently served (pool order; "base" leads). Empty when
        the session was built pool-less (base-model-only serving)."""
        return self.pool.ids if self.pool is not None else []

    @property
    def compile_count(self) -> int:
        """decode_step traces so far — 1 after the first tick, forever."""
        return self.engine.compile_count

    def metrics(self) -> dict:
        """Request-lifecycle aggregates (queue wait, TTFT, latency,
        preemptions) plus engine counters — see `ServeEngine.metrics`."""
        return self.engine.metrics()

    def _require_pool(self) -> AdapterPool:
        if self.pool is None:
            raise ValueError("this ServingSession serves the base model "
                             "only; build it with checkpoint=/adapters=/"
                             "capacity= to hold adapters")
        return self.pool

    def add_adapter(self, adapter_id: str, tree) -> int:
        """Register a new adapter (single lora tree) in a free pool row."""
        return self._require_pool().add(adapter_id, tree)

    def update_adapter(self, adapter: AdapterRef, tree) -> None:
        """Hot-swap an adapter between ticks; in-flight slots pick the new
        weights up on the next token."""
        self._require_pool().update(adapter, tree)


@dataclass
class ServeSync(Callback):
    """Serve-while-training: every ``every`` rounds, push the training
    session's per-client adapters (and their consensus mean) into a
    `ServingSession`'s pool. Swaps are row-scatters between engine ticks —
    the serving side never recompiles, and requests submitted after round t
    decode with round-t weights.

        serving = ServingSession.from_session(sess)
        sess.callbacks.append(ServeSync(serving, every=5))
    """
    serving: ServingSession
    every: int = 1
    consensus: bool = True

    def on_round_end(self, event) -> None:
        if self.every > 1 and (event.t + 1) % self.every != 0 \
                and not event.is_last:
            return
        lora = event.lora
        from repro.dist import multihost
        if multihost.is_distributed():
            # under a ClusterSession the client axis is sharded across
            # processes while each pool is process-local serving state —
            # gather to host (exact) so every process's engine serves the
            # full adapter set. Runs on all ranks (it is a collective).
            lora = multihost.to_host(lora,
                                     getattr(event.session, "mesh", None))
        self.serving.pool.sync_from(lora, consensus=self.consensus)
