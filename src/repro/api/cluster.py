"""`ClusterSession` — a `Session` whose client axis spans processes.

The paper's setting is genuinely decentralized: clients on separate
machines gossiping over a time-varying graph. `ClusterSession` makes the
repo's execution match that reality without forking the round loop — it IS
a `Session`, running the same `build_round` product, the same schedules,
and the same callbacks, but on a global mesh built over every process in
the grid (`repro.dist.multihost`):

  * each process owns a contiguous shard of the client axis (m must divide
    over the grid's devices); local training is shard-local,
  * under ``mix_comm="dense"`` the gossip mix runs with ``mix_gather``
    resolved on: one all-gather of the stacked LoRA state per round (the
    paper's communication step, lowered to a cross-process collective)
    followed by a replicated W_t contraction — bitwise equal to the
    single-process round. Under ``mix_comm="sparse"/"sparse_overlap"``
    the round instead runs the `repro.dist.comm.CommPlan` halo exchange:
    one small all-gather of only the topology-coupled rows ("sparse" is
    still bitwise equal; "sparse_overlap" delays neighbor terms one
    round so the exchange overlaps local compute),
  * `TopologySchedule` draws that do not declare ``deterministic`` are
    wrapped in `BroadcastSchedule` so every process mixes with rank 0's
    realized W_t; config-derived library schedules replay identically
    per seed on every process and skip the per-round broadcast (a
    blocking host collective that dominated small-payload rounds),
  * checkpoints gather to host and are written by rank 0 only, in the
    exact format `Session.save` writes — a 2-process run's checkpoint
    restores into a single-process `Session` (and vice versa),
  * the control plane (`config.control`, repro.control) runs as
    replicated host math: every process folds the same realized W_t into
    the same estimator state and installs the same deterministic weight
    policy, so T retunes and FMMC weights agree across the grid without
    extra collectives. The frozen-contraction estimator is the exception
    (it reads full client state per round) and is rejected on grids >1
    process — use "spectral" or "gram" there.

Multi-controller contract: every process constructs the same
`ClusterSession` and makes the same calls in the same order. Callbacks run
on all processes — gate side effects (prints, file writes) on
``multihost.is_primary()``, never the computation.

Launch via ``python -m repro.launch.cluster`` (real grids use the
``REPRO_COORDINATOR``/``REPRO_NUM_PROCESSES``/``REPRO_PROCESS_ID`` env
protocol or `jax.distributed` auto-detection; ``--simulate N`` spawns N
local CPU processes over gloo — the CI path). Single-process construction
degrades to an exact `Session` (1-device mesh, passthrough broadcast).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import numpy as np

import jax

from repro.api.session import Session
from repro.checkpoint import save_pytree
from repro.dist import multihost, sharding
from repro.optim.adamw import AdamWState
from repro.scenarios.schedule import BroadcastSchedule


class ClusterSession(Session):
    """Multi-process DFL session: one process = one shard of the clients.

    Accepts every `Session` argument. Requires ``config.n_clients`` to be
    divisible by the grid's total device count. ``config.mix_gather`` is
    resolved per `repro.api.session._resolve_mix_gather` — "auto" turns
    the pre-mix all-gather on exactly when the grid has >1 process.
    """

    def __init__(self, config, **kw):
        multihost.initialize()          # env-protocol no-op if not gridded
        cc = config.control
        if cc.active and cc.rho_estimator == "frozen" \
                and jax.process_count() > 1:
            # the consensus probe reads the full client state every round —
            # a per-round blocking gather on a grid; the W-only routes are
            # replicated host math and grid-invariant by construction
            raise ValueError(
                "control.rho_estimator 'frozen' needs host-local client "
                "state each round; on a process grid use 'spectral' or "
                "'gram' (W_t is replicated on every process)")
        self.mesh = multihost.cluster_mesh()
        if config.n_clients % self.mesh.size != 0:
            raise ValueError(
                f"ClusterSession: n_clients={config.n_clients} must divide "
                f"over {self.mesh.size} devices "
                f"({jax.process_count()} processes)")
        self._client_slc = multihost.local_client_slice(config.n_clients,
                                                        self.mesh)
        super().__init__(config, **kw)
        self._wrap_schedule()
        self.base = multihost.replicate_tree(
            self.mesh, jax.tree.map(np.asarray, self.base))

    def _wrap_schedule(self) -> None:
        """Rank-0-owned W_t for schedules whose draws could disagree
        across processes. Deterministic (config-derived) schedules replay
        the identical stream per seed on every process, so the per-round
        broadcast — a blocking host collective — is skipped for them."""
        if not getattr(self.topo_schedule, "deterministic", False):
            self.topo_schedule = BroadcastSchedule(self.topo_schedule)

    # -- mesh binding (trace-time logical-axis resolution) ------------------
    @contextmanager
    def _bound(self):
        """Bind the cluster mesh for logical-axis resolution (the round's
        `shard_lora_tree` / `gather_clients` constraints) and restore the
        previous binding after — the session never leaks mesh state into
        other code running in this process."""
        prev_mesh = sharding.current_mesh()
        prev_map = sharding.current_axis_map()
        sharding.set_mesh(self.mesh)
        try:
            yield
        finally:
            if prev_mesh is None:
                sharding.clear_mesh()
            else:
                sharding.set_mesh(prev_mesh, prev_map)

    # -- state globalization ------------------------------------------------
    def _shard_client_tree(self, tree):
        """Full host-identical tree -> global arrays sharded over the
        client axis (-3). Each process contributes exactly its block; the
        slice is pure data movement, so the global state equals the
        single-process state bit-for-bit."""
        def one(x):
            x = np.asarray(x)
            local = x[..., self._client_slc, :, :]
            return multihost.shard_clients(self.mesh, local, x.shape,
                                           axis=x.ndim - 3)
        return jax.tree.map(one, tree)

    def _globalize_state(self) -> None:
        self.lora = self._shard_client_tree(self.lora)
        self.opt_state = AdamWState(
            step=multihost.replicate(self.mesh,
                                     np.asarray(self.opt_state.step)),
            mu=self._shard_client_tree(self.opt_state.mu),
            nu=self._shard_client_tree(self.opt_state.nu))
        if self.ef is not None:
            # the error-feedback buffer is (m, cols) with the client axis
            # leading — shard it like the round's other client state
            ef = np.asarray(self.ef)
            self.ef = multihost.shard_clients(
                self.mesh, ef[self._client_slc], ef.shape, axis=0)

    def reset_state(self) -> None:
        super().reset_state()
        self._globalize_state()

    # -- device placement hooks --------------------------------------------
    def _device_scalar_inputs(self, x):
        return multihost.replicate(self.mesh, np.asarray(x))

    def _to_device(self, raw):
        """Every process draws the identical full round batch from the
        shared data RNG (numpy, cheap at client counts that fit a grid)
        and contributes its client block; leaves become global arrays
        sharded over the batch's client axis (dim 1)."""
        def one(x):
            x = np.asarray(x)
            return multihost.shard_clients(self.mesh, x[:, self._client_slc],
                                           x.shape, axis=1)
        return jax.tree.map(one, self._raw_round_batch(raw))

    # -- cold joins ---------------------------------------------------------
    def _apply_client_matrix(self, R, zero_ef_rows=()):
        """The warm-start repair mixes *across* client rows, which live on
        different processes here: gather the sharded state to identical
        full host arrays (exact all-gather), apply the repair in numpy on
        every process (same inputs -> bitwise same result, no broadcast
        needed), then re-shard onto the grid."""
        self.lora = multihost.to_host(self.lora, self.mesh)
        self.opt_state = AdamWState(
            step=multihost.to_host(self.opt_state.step, self.mesh),
            mu=multihost.to_host(self.opt_state.mu, self.mesh),
            nu=multihost.to_host(self.opt_state.nu, self.mesh))
        if self.ef is not None:
            self.ef = multihost.to_host(self.ef, self.mesh)
        super()._apply_client_matrix(R, zero_ef_rows)
        self._globalize_state()

    # -- the round / evaluation under the bound mesh ------------------------
    def _one_round(self, **kw):
        with self._bound():
            return super()._one_round(**kw)

    def evaluate(self, n: Optional[int] = None,
                 seed: Optional[int] = None) -> dict:
        with self._bound():
            return super().evaluate(n, seed)

    # -- checkpoint / restore -----------------------------------------------
    def save(self, path: str) -> None:
        """Gather to host (exact all-gather) and write on rank 0 only, in
        `Session.save`'s format — restorable by any process count."""
        state = {
            "lora": multihost.to_host(self.lora, self.mesh),
            "opt": {"step": multihost.to_host(self.opt_state.step,
                                              self.mesh),
                    "mu": multihost.to_host(self.opt_state.mu, self.mesh),
                    "nu": multihost.to_host(self.opt_state.nu, self.mesh)},
            "meta": {"round": np.int64(self.t)},
        }
        if self.ef is not None:
            state["ef"] = multihost.to_host(self.ef, self.mesh)
        if multihost.is_primary():
            save_pytree(path, state)
        multihost.sync("ckpt-save")

    def restore(self, path: str) -> int:
        """`Session.restore` (every process reads the checkpoint and
        replays the RNG streams in lockstep), then re-globalize the
        restored state onto the grid."""
        saved = super().restore(path)
        if self._user_topo_schedule is None:
            # super().restore rebuilt the schedule unwrapped
            self._wrap_schedule()
        self._globalize_state()
        return saved
