"""Callbacks: metrics streaming, history recording, periodic checkpoints.

A callback implements `on_round_end(event)` and/or `on_run_end(session,
result)`. `RoundEvent` exposes the loss, consensus stats, and W spectral
info as memoized lazies, so multiple callbacks share one computation and
uninstrumented runs pay nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.api.session import RoundEvent, RunResult, Session


class Callback:
    """Base class; subclasses override either hook."""

    def on_round_end(self, event: RoundEvent) -> None:
        pass

    def on_run_end(self, session: Session, result: RunResult) -> None:
        pass


def _due(event: RoundEvent, every: int) -> bool:
    return every <= 1 or event.t % every == 0 or event.is_last


@dataclass
class ConsoleLogger(Callback):
    """Streams per-round metrics to stdout (quickstart/train.py style)."""
    every: int = 1
    consensus: bool = False

    def on_round_end(self, event: RoundEvent) -> None:
        if not _due(event, self.every):
            return
        line = (f"  round {event.t:4d} [{event.phase}-phase] "
                f"loss={event.loss:.4f}")
        if self.consensus:
            st = event.consensus()
            line += (f" ‖C‖={st['cross_norm']:.2e}"
                     f" Δ_A²={st['delta_a_sq']:.2e}"
                     f" Δ_B²={st['delta_b_sq']:.2e}")
        print(line, flush=True)


@dataclass
class HistoryRecorder(Callback):
    """Records {round, loss (+consensus stats)} dicts every `every` rounds
    — the metrics stream behind train.py --log and the benchmark
    diagnostics."""
    every: int = 1
    consensus: bool = False
    history: list = field(default_factory=list)

    def on_round_end(self, event: RoundEvent) -> None:
        if not _due(event, self.every):
            return
        rec = {"round": event.t, "loss": event.loss}
        if self.consensus:
            rec.update(event.consensus())
        self.history.append(rec)


@dataclass
class CheckpointCallback(Callback):
    """Saves the session every `every` rounds (0 = at run end only)."""
    path: str
    every: int = 0

    def on_round_end(self, event: RoundEvent) -> None:
        if self.every and (event.t + 1) % self.every == 0:
            event.session.save(self.path)

    def on_run_end(self, session: Session, result: RunResult) -> None:
        session.save(self.path)
