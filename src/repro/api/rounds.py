"""API-level constructor for the compiled DFL round.

`build_round` is the one place the experiment layer (Session, launchers,
dry-run spec builders) obtains a round function; everything above
`repro.core` routes through it so engine knobs (mixing lowering, buffer
donation) are applied uniformly. The low-level `repro.core.make_dfl_round`
remains exported for library users who wire loops themselves.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.fedtrain import make_dfl_round
from repro.optim.adamw import AdamW


def build_round(loss_fn: Callable, optimizer: AdamW, *,
                local_steps: int = 1,
                mix_impl: str = "planned",
                mix_flat_lowering: Optional[str] = None,
                mix_gather: bool = False,
                mix_comm: str = "dense",
                mix_quant: str = "off",
                comm_plan=None,
                donate: bool = False):
    """Build round_fn(base, lora, opt_state, batch, W, masks).

    mix_flat_lowering ("auto" | "flat" | "per_segment") pins the planned
    path's fused-buffer lowering for this round function; None defers to
    the process default (repro.core.mixing.set_flat_lowering).
    mix_gather pins the dense cluster communication step: all-gather the
    client axis before the mixing contraction (bitwise-parity lowering
    for multi-process runs; no-op without a bound mesh).
    mix_comm ("dense" | "sparse" | "sparse_overlap") selects the gossip
    communication lowering; the sparse modes exchange only the
    topology-coupled rows described by ``comm_plan`` (a
    `repro.dist.comm.CommPlan`), and "sparse_overlap" delays the
    off-diagonal mixing terms by one round so the exchange overlaps with
    local compute.
    mix_quant ("off" | "int8" | "fp8") compresses the sparse halo
    exchange with per-client error feedback; quant round functions take
    an extra ``ef`` buffer and return ``ef_new`` (see
    `repro.core.fedtrain.make_dfl_round`).
    """
    return make_dfl_round(loss_fn, optimizer, local_steps=local_steps,
                          mix_impl=mix_impl,
                          mix_flat_lowering=mix_flat_lowering,
                          mix_gather=mix_gather,
                          mix_comm=mix_comm,
                          mix_quant=mix_quant,
                          comm_plan=comm_plan,
                          donate=donate)
