"""`repro.api` — the declarative DFL experiment layer.

config -> Session -> callbacks: a `DFLConfig` describes the experiment,
a `Session` owns topology sampling / the compiled mesh-aware round /
checkpointing, a `MaskSchedule` (static or adaptive) drives the phase
calendar, and callbacks stream metrics. `ClusterSession` is the same
Session with its client axis sharded across a process grid
(`repro.dist.multihost`) — launched by `repro.launch.cluster`. The serving side mirrors it:
an `AdapterPool` stacks the per-client adapters a run produces and a
`ServingSession` serves them from one compiled decode step (`ServeSync`
bridges the two for serve-while-training). The closed-loop control plane
(`ControlConfig`/`ControlPlane`/`RoundStats`, from `repro.control`)
re-tunes T and mixing weights between rounds from the same observation
payload callbacks consume. `repro.core` stays the low-level primitive
layer underneath.
"""
from repro.api.callbacks import (Callback, CheckpointCallback, ConsoleLogger,
                                 HistoryRecorder)
from repro.api.cluster import ClusterSession
from repro.api.config import DFLConfig
from repro.api.rounds import build_round
from repro.api.schedule import AdaptiveSchedule, MaskSchedule, StaticSchedule
from repro.api.serving import AdapterPool, ServeSync, ServingSession
from repro.control import ControlConfig, ControlPlane, RoundStats
from repro.serving import QuotaExceeded, TenantQuota
from repro.api.session import RoundEvent, RunResult, Session
from repro.scenarios import TopologySchedule, schedule_from_config

__all__ = [
    "DFLConfig", "Session", "ClusterSession", "RunResult", "RoundEvent",
    "MaskSchedule", "StaticSchedule", "AdaptiveSchedule",
    "ControlConfig", "ControlPlane", "RoundStats",
    "TopologySchedule", "schedule_from_config",
    "Callback", "ConsoleLogger", "HistoryRecorder", "CheckpointCallback",
    "AdapterPool", "ServingSession", "ServeSync",
    "TenantQuota", "QuotaExceeded",
    "build_round",
]
