"""`Session` — a running DFL experiment built from a `DFLConfig`.

Owns everything the seven former hand-wired loops re-implemented: model +
LoRA init, topology sampling, the data pipeline, the jitted DFL round
(mesh-aware via `repro.dist` — it runs unchanged under a bound production
mesh — with optional buffer donation), checkpoint/resume through
`repro.checkpoint`, and a callback hook list.

    cfg = DFLConfig(model="gemma3-1b", task="lm", n_clients=6, rounds=15)
    sess = Session(cfg, callbacks=[ConsoleLogger()])
    result = sess.run()

The round loop is deliberately bare — sample W_t, ask the `MaskSchedule`
for this round's masks, step the compiled round, notify callbacks — so a
Session round costs the same as a hand-wired loop (BENCH_round_loop.json
tracks the overhead). Per-round derived quantities (consensus stats, W
spectral gap, float(loss)) are computed lazily by `RoundEvent` only when
a callback asks, never on the hot path.

Builds are cached per model/task signature, so sweeps that vary only
seeds/topology/T (the benchmark grids) re-use one set of init params and
one compiled round function.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.config import DFLConfig
from repro.api.rounds import build_round
from repro.api.schedule import AdaptiveSchedule, MaskSchedule, StaticSchedule
from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.control.plane import ControlPlane
from repro.control.stats import RoundStats, metric_loss as _metric_loss
from repro.core.alternating import RoundMasks
from repro.core.diagnostics import consensus_stats
from repro.core import mixing
from repro.core.lora import build_lora_tree
from repro.core.topology import Topology, make_topology, \
    optimal_switching_interval
from repro.data.partition import make_partition
from repro.data.shards import ShardSet
from repro.data.stream import FederatedStream
from repro.data.synthetic import (eval_batch, federated_batches,
                                  label_skew_partitions, lm_token_stream,
                                  make_task)
from repro.dist.comm import CommPlan, build_comm_plan, dense_recv_bytes
from repro.optim.adamw import AdamW, AdamWState
from repro.scenarios.library import estimate_rho_sq, schedule_from_config
from repro.scenarios.schedule import TopologySchedule, schedule_support


# ---------------------------------------------------------------------------
# round events (lazy views handed to callbacks)
# ---------------------------------------------------------------------------

class RoundEvent:
    """One round's outcome, as callbacks see it. A thin view over the
    round's `RoundStats` payload (repro.control.stats) — the SAME object
    `ControlPlane.observe()` consumed, so derived quantities (loss
    reduction, consensus stats) are memoized once and shared between the
    control loop and every callback. The stats snapshot THIS round's lora
    tree, so a deferred `consensus()` call still describes round t —
    though under `donate=True` the buffers are consumed by the next
    round, so compute consensus inside on_round_end there."""

    def __init__(self, session: "Session", t: int, masks: RoundMasks,
                 W: np.ndarray, metrics: Mapping, is_last: bool,
                 stats: Optional[RoundStats] = None):
        self.session = session
        self.t = t
        self.masks = masks
        self.W = W
        self.metrics = metrics          # jax arrays — not yet synced
        self.is_last = is_last
        self.stats = stats if stats is not None else RoundStats(
            t, W, masks=masks, metrics=metrics, lora=session.lora)
        self.lora = self.stats.lora     # this round's state (post-mix)

    @property
    def phase(self) -> str:
        return "A" if self.masks.update_a else "B"

    @property
    def loss(self) -> float:
        return self.stats.loss

    def consensus(self) -> dict:
        """Consensus/theory diagnostics of THIS round's LoRA state
        (delta_a_sq, delta_b_sq, cross_norm, cs_bound) as floats."""
        return self.stats.consensus()

    def w_gap(self) -> float:
        """Spectral distance ||W_t - J||_2 of this round's mixing matrix."""
        return self.stats.w_gap()


@dataclass
class RunResult:
    rounds: int
    wall_s: float
    final_loss: float
    T: int


# ---------------------------------------------------------------------------
# cached builds (model init + compiled round per model/task signature)
# ---------------------------------------------------------------------------

@dataclass
class _Built:
    model_cfg: object
    task: object                 # SyntheticTask or None for "lm"
    base: object
    lora0: object
    opt: AdamW
    round_fn: Callable
    acc_fn: Optional[Callable]
    comm_plan: Optional[CommPlan]


_BUILD_CACHE: dict = {}


def _resolve_mix_gather(mode: str) -> bool:
    """"auto" turns the pre-mix client all-gather on exactly when the run
    spans processes (repro.dist.multihost) — single-process rounds keep
    the unconstrained lowering, cluster rounds pin the bitwise-parity
    communication step."""
    if mode == "on":
        return True
    if mode == "off":
        return False
    return jax.process_count() > 1


def _comm_plan_for(cfg: DFLConfig) -> Optional[CommPlan]:
    """The sparse-exchange CommPlan a config describes (None for dense).

    The union support comes from a FRESH config-derived schedule replica
    (support is static — probing it consumes no RNG the round loop owns),
    compiled against the process grid's total device count. One shard
    (single process, CPU) degenerates to a local contraction."""
    if cfg.mix_comm == "dense":
        return None
    support = schedule_support(schedule_from_config(cfg))
    return build_comm_plan(support, n_shards=jax.device_count())


def _build_key(cfg: DFLConfig, comm_plan: Optional[CommPlan] = None):
    return (cfg.model, cfg.reduced, cfg.model_kw, cfg.task,
            cfg.feature_shift, cfg.n_clients, cfg.lr, cfg.local_steps,
            cfg.mix_impl, cfg.mix_flat_lowering,
            _resolve_mix_gather(cfg.mix_gather), cfg.donate, cfg.init_seed,
            cfg.mix_comm, cfg.mix_quant,
            cfg.data_source, cfg.data_path,
            comm_plan.signature() if comm_plan is not None else None)


def _build(cfg: DFLConfig, model_cfg, loss_fn) -> _Built:
    cacheable = model_cfg is None and loss_fn is None
    comm_plan = _comm_plan_for(cfg)
    key = _build_key(cfg, comm_plan)
    if cacheable and key in _BUILD_CACHE:
        return _BUILD_CACHE[key]

    base_key = jax.random.key(cfg.init_seed)
    lora_key = jax.random.key(cfg.init_seed + 1)
    acc_fn = None
    task = None

    if cfg.task == "lm":
        from repro.models import transformer as tf
        mc = model_cfg
        if mc is None:
            mc = get_config(cfg.model)
            if cfg.reduced:
                mc = mc.reduced()
        base = tf.init_params(base_key, mc)
        if loss_fn is None:
            def loss_fn(bp, lo, micro, _cfg=mc):
                out, per = tf.lm_loss(bp, _cfg, micro["tokens"],
                                      micro["targets"],
                                      frontend=micro.get("frontend"),
                                      lora=lo, per_client=True)
                return out[0], per
    else:
        from repro.models.classifier import (classifier_accuracy,
                                             classifier_loss, encoder_config,
                                             init_classifier)
        mc = model_cfg if model_cfg is not None \
            else encoder_config(**dict(cfg.model_kw))
        if cfg.data_source == "shards":
            # task identity comes from the shard manifest; its token ids
            # must live inside the model's embedding table
            task = ShardSet(cfg.data_path)
            if task.vocab_size > mc.vocab_size:
                raise ValueError(
                    f"shard set {task.name!r} has vocab_size="
                    f"{task.vocab_size} > model vocab_size="
                    f"{mc.vocab_size}; regenerate the shards or widen "
                    f"model_kw['vocab_size']")
        else:
            # task tokens must live inside the model's embedding table
            task = make_task(cfg.task, feature_shift=cfg.feature_shift,
                             vocab_size=mc.vocab_size)
        base = init_classifier(base_key, mc, n_classes=task.n_classes)
        if loss_fn is None:
            def loss_fn(bp, lo, micro, _cfg=mc):
                return classifier_loss(bp, _cfg, micro["tokens"],
                                       micro["labels"], lora=lo,
                                       per_client=True)
        acc_fn = jax.jit(lambda bp, toks, labs, lo, _cfg=mc:
                         classifier_accuracy(bp, _cfg, toks, labs, lora=lo))

    lora0 = build_lora_tree(lora_key, base, mc, n_clients=cfg.n_clients)
    opt = AdamW(lr=cfg.lr)
    round_fn = build_round(loss_fn, opt, local_steps=cfg.local_steps,
                           mix_impl=cfg.mix_impl,
                           mix_flat_lowering=cfg.mix_flat_lowering,
                           mix_gather=_resolve_mix_gather(cfg.mix_gather),
                           mix_comm=cfg.mix_comm,
                           mix_quant=cfg.mix_quant,
                           comm_plan=comm_plan,
                           donate=cfg.donate)
    if not cfg.donate:
        round_fn = jax.jit(round_fn)

    built = _Built(model_cfg=mc, task=task, base=base, lora0=lora0,
                   opt=opt, round_fn=round_fn, acc_fn=acc_fn,
                   comm_plan=comm_plan)
    if cacheable:
        _BUILD_CACHE[key] = built
    return built


def clear_build_cache() -> None:
    _BUILD_CACHE.clear()


# ---------------------------------------------------------------------------
# the Session
# ---------------------------------------------------------------------------

class Session:
    """One DFL experiment: state + the compiled round + the round loop.

    Construction is cheap when an equal model/task signature was built
    before (init params and the jitted round are cached module-wide).
    `model_cfg` overrides the architecture with a custom ModelConfig;
    `loss_fn(base, lora, micro) -> scalar` overrides the objective;
    `schedule` overrides the mask schedule (default: static T from the
    config, or a controller-driven `AdaptiveSchedule` when
    config.control.t_policy == "adaptive");
    `topology_schedule` overrides the communication condition (default:
    built from config.scenario via `repro.scenarios`).

    An *active* config.control (repro.control.ControlConfig) additionally
    instantiates a `ControlPlane` at `session.control`: each round's
    `RoundStats` is fed to `control.observe()` before callbacks fire, the
    plane's weight policy is installed into the topology schedule's
    `set_weights` hook, and — for t_policy "adaptive" — the plane's
    controller drives the mask schedule, retuning T only at phase
    boundaries (the compiled round never retraces).
    """

    def __init__(self, config: DFLConfig, *, model_cfg=None,
                 loss_fn: Optional[Callable] = None,
                 schedule: Optional[MaskSchedule] = None,
                 topology_schedule: Optional[TopologySchedule] = None,
                 callbacks: Sequence = ()):
        self.config = config
        self.callbacks = list(callbacks)
        built = _build(config, model_cfg, loss_fn)
        self.model_cfg = built.model_cfg
        self.task = built.task
        self.base = built.base
        self.opt = built.opt
        self.round_fn = built.round_fn
        self._acc_fn = built.acc_fn
        self._lora0 = built.lora0
        self.comm_plan = built.comm_plan    # None for mix_comm="dense"

        # the underlying graph + legacy sampler stay exposed as
        # `session.topology`; the round loop itself draws W_t from the
        # TopologySchedule the config's scenario selects (the "gossip"
        # default wraps self.topology, sharing its RNG stream)
        self.topology: Topology = make_topology(
            config.topology, config.n_clients, config.p, seed=config.seed,
            **dict(config.topology_kw))
        self._user_topo_schedule = topology_schedule
        self.topo_schedule: TopologySchedule = topology_schedule \
            if topology_schedule is not None \
            else schedule_from_config(config, topology=self.topology)
        if self.comm_plan is not None and topology_schedule is not None:
            # the sparse exchange only moves rows inside the CONFIG's
            # support; a user schedule coupling rows outside it would
            # silently mix against zeros
            extra = schedule_support(topology_schedule) \
                & ~self.comm_plan.support
            if extra.any():
                raise ValueError(
                    "topology_schedule couples clients outside the "
                    "config-derived support the sparse CommPlan was "
                    "compiled for; use mix_comm='dense' or align the "
                    "schedule's support_adjacency() with the config")
        self._rho: Optional[float] = None
        self._T: Optional[int] = config.T or None
        self._comm_bytes: Optional[int] = None
        self.control = self._make_control()
        self._install_weight_policy()
        self._user_schedule = schedule
        self.schedule = schedule if schedule is not None \
            else self._default_schedule()

        self.t = 0
        self.last_metrics: Optional[Mapping] = None
        self.last_event: Optional[RoundEvent] = None
        self.reset_state()

    def _make_control(self) -> Optional[ControlPlane]:
        """The ControlPlane this config asks for (None when the control
        struct is inert — the open-loop default costs nothing). Under
        sparse comm the plane's FMMC policy is fed the CommPlan's
        per-link byte accounting as its bandwidth cost."""
        cc = self.config.control
        if cc is None or not cc.active:
            return None
        link_cost = None
        if self.comm_plan is not None:
            plan = mixing.get_mix_plan(self._lora0)
            link_cost = self.comm_plan.link_bytes(plan.cols)
        return ControlPlane(cc, link_cost=link_cost)

    def _install_weight_policy(self) -> None:
        """Install the control plane's weight policy into the topology
        schedule's `set_weights` hook (no-op for the Metropolis baseline,
        which must stay byte-identical to pre-control runs)."""
        if self.control is None or self.control.weight_policy is None:
            return
        hook = getattr(self.topo_schedule, "set_weights", None)
        if hook is None:
            raise ValueError(
                f"control.weight_policy="
                f"{self.config.control.weight_policy!r} needs a topology "
                f"schedule with a set_weights() hook; "
                f"{type(self.topo_schedule).__name__} exposes none — use a "
                f"Metropolis-based scenario schedule or drop the weight "
                f"policy")
        hook(self.control.weight_policy)

    def _default_schedule(self) -> MaskSchedule:
        cfg = self.config
        if self.control is not None and self.control.controller is not None:
            # the plane owns rho estimation (ControlPlane.observe); the
            # schedule only advances the shared controller's calendar
            return AdaptiveSchedule(cfg.method, estimator="none",
                                    controller=self.control.controller)
        return StaticSchedule(cfg.method, self.T)

    # -- state --------------------------------------------------------------
    @property
    def rho(self) -> float:
        """Monte-Carlo contraction estimate of the communication condition
        (memoized). The legacy gossip scenario keeps the per-sample
        Topology estimator (identical T* selection to pre-scenario runs);
        every other scenario measures a fresh replica of its schedule via
        the time-averaged ||E[WᵀW] − J||₂ gram route. Undefined for a
        user-supplied topology_schedule: the live schedule's RNG belongs
        to the round loop and cannot be probed, so set T explicitly (or
        pass a mask schedule) instead of relying on T*(rho)."""
        if self._rho is None:
            if self._user_topo_schedule is not None:
                raise ValueError(
                    "rho/T*(rho) is undefined for a user-supplied "
                    "topology_schedule (probing it would consume the run's "
                    "W_t stream); set config.T explicitly or pass a mask "
                    "schedule")
            if self.config.scenario == "gossip":
                self._rho = self.topology.rho_estimate(100)
            else:
                # probe a FRESH config-derived replica — never the live
                # schedule, whose RNG the round loop owns (a user-supplied
                # schedule is proxied by the config's scenario)
                self._rho = float(np.sqrt(estimate_rho_sq(
                    schedule_from_config(self.config), rounds=100)))
        return self._rho

    @property
    def T(self) -> int:
        """The static switching interval: config.T, or T*(rho) on first
        access (lazy — adaptive/custom-schedule sessions never pay for
        the Monte-Carlo rho estimate behind it)."""
        if self._T is None:
            self._T = optimal_switching_interval(self.rho)
        return self._T

    def reset_state(self) -> None:
        """(Re)initialize lora/opt state and the data pipeline at round 0.
        The topology RNG is NOT reset — call sites that need a bit-for-bit
        replay construct a fresh Session instead."""
        lora0 = self._lora0
        if self.config.donate:
            # donated buffers are consumed by the round — never hand the
            # cached init tree itself to a donating round function
            lora0 = jax.tree.map(lambda x: jnp.array(x, copy=True), lora0)
        self.lora = lora0
        self.opt_state: AdamWState = self.opt.init(self.lora)
        # compressed gossip carries the per-client error-feedback
        # accumulator as round state, zero at round 0 (the MixPlan's
        # unpadded (m, cols) flat layout)
        self.ef = None
        if self.config.mix_quant != "off":
            plan = mixing.get_mix_plan(self.lora)
            self.ef = jnp.zeros((plan.m, plan.cols), jnp.float32)
        old = getattr(self, "_batches", None)
        if old is not None and hasattr(old, "close"):
            old.close()                 # join a prefetching stream's worker
        self._batches = self._raw_batch_iter()
        self.t = 0
        self.last_metrics = None
        self.last_stats: Optional[RoundStats] = None
        # phase-index tracking for RoundStats (increments at every A/B
        # boundary; the frozen-contraction estimator pairs Δ² samples only
        # within one phase)
        self._phase_idx = 0
        self._prev_update_a: Optional[bool] = None

    def _track_phase(self, masks: RoundMasks) -> int:
        ua = bool(masks.update_a)
        if self._prev_update_a is not None and ua != self._prev_update_a:
            self._phase_idx += 1
        self._prev_update_a = ua
        return self._phase_idx

    def _round_comm_bytes(self) -> int:
        """Per-round gossip bytes this process RECEIVES under the live
        lowering (memoized: the flat layout is static across rounds).
        Dense single-process runs receive 0 — the exchange never leaves
        the process."""
        if self._comm_bytes is None:
            plan = mixing.get_mix_plan(self._lora0)
            cfg = self.config
            if self.comm_plan is None:
                self._comm_bytes = dense_recv_bytes(
                    cfg.n_clients, jax.process_count(), plan.cols)
            elif cfg.mix_quant != "off":
                self._comm_bytes = \
                    self.comm_plan.sparse_recv_bytes_quant(plan.cols)
            else:
                self._comm_bytes = \
                    self.comm_plan.sparse_recv_bytes(plan.cols)
        return self._comm_bytes

    # -- data ---------------------------------------------------------------
    # raw (numpy) draws and device conversion are split so checkpoint
    # replay can advance the data RNG without materializing device arrays
    # (the shard stream skips even that: its batches are pure functions of
    # the round index, so replay is an O(1) seek)
    def _raw_batch_iter(self) -> Iterator:
        cfg = self.config
        if cfg.data_source == "shards":
            shards: ShardSet = self.task
            parts = make_partition(cfg.partitioner, shards.labels("train"),
                                   cfg.n_clients, seed=cfg.data_seed,
                                   domains=shards.domains("train"),
                                   **dict(cfg.partitioner_kw))
            return FederatedStream(shards, parts, batch=cfg.batch_size,
                                   local_steps=cfg.local_steps,
                                   seed=cfg.data_seed,
                                   prefetch=cfg.data_prefetch)
        return self._synthetic_batch_iter()

    def _synthetic_batch_iter(self) -> Iterator:
        cfg = self.config
        if cfg.task == "lm":
            m, ls, b, S = (cfg.n_clients, cfg.local_steps, cfg.batch_size,
                           cfg.seq_len)
            stream = lm_token_stream(self.model_cfg.vocab_size, b * ls, S,
                                     n_clients=m, seed=cfg.data_seed)
            for raw in stream:
                yield {k: v.reshape(m, ls, b, S).swapaxes(0, 1)
                       for k, v in raw.items()}
        else:
            parts = label_skew_partitions(self.task.n_classes, cfg.n_clients)
            # effectively endless: per-round draws don't depend on the total
            yield from federated_batches(self.task, parts, cfg.batch_size,
                                         cfg.local_steps, rounds=1 << 62,
                                         seed=cfg.data_seed)

    def _device_scalar_inputs(self, x):
        """Placement hook for the round's small replicated inputs (W_t,
        masks). ClusterSession overrides this to build global replicated
        arrays on the cluster mesh; single-process it is a plain put."""
        return jnp.asarray(x)

    def _raw_round_batch(self, raw) -> dict:
        """Complete one round's raw numpy batch (adds the frontend-token
        zeros LM archs expect). Placement-independent: ClusterSession
        reuses this and only changes where the leaves land."""
        cfg = self.config
        raw = dict(raw)
        nft = getattr(self.model_cfg, "n_frontend_tokens", 0)
        if cfg.task == "lm" and nft:
            raw["frontend"] = np.zeros(
                (cfg.local_steps, cfg.n_clients, cfg.batch_size, nft,
                 self.model_cfg.d_model), np.float32)
        return raw

    def _to_device(self, raw):
        return jax.tree.map(jnp.asarray, self._raw_round_batch(raw))

    # -- the round loop -----------------------------------------------------
    def step(self) -> RoundEvent:
        """Run exactly one round (callbacks fire, like run()) and return
        its event."""
        ev = self._one_round(is_last=False, notify=True, want_event=True)
        self.last_event = ev
        return ev

    # -- cold joins (adapter-initialization half of the identity repair) ----
    def _apply_client_matrix(self, R: np.ndarray,
                             zero_ef_rows: tuple = ()) -> None:
        """Apply a host-side (m, m) row-mixing matrix to every client-axis
        state tree (LoRA factors + Adam moments). Runs in numpy on the
        full state so every process grid computes the identical result
        bit-for-bit; `zero_ef_rows` clears those clients' error-feedback
        accumulators (a joiner's residual describes pre-join state).
        ClusterSession overrides this to gather/re-shard around it."""
        R64 = np.asarray(R, np.float64)

        def one(x):
            a = np.asarray(x)
            mixed = np.einsum("ij,...jdr->...idr", R64, a)
            return jnp.asarray(mixed.astype(a.dtype))

        self.lora = jax.tree.map(one, self.lora)
        self.opt_state = AdamWState(
            step=self.opt_state.step,
            mu=jax.tree.map(one, self.opt_state.mu),
            nu=jax.tree.map(one, self.opt_state.nu))
        if self.ef is not None and zero_ef_rows:
            ef = np.array(self.ef)
            ef[list(zero_ef_rows)] = 0.0
            self.ef = jnp.asarray(ef)

    def _warm_start_clients(self, joiners: tuple) -> None:
        """Initialize joining clients' adapters from the average of their
        already-warm graph neighbors (uniform over the support adjacency,
        excluding co-joiners). A joiner with no warm neighbor keeps its
        cold state — the identity row is the only sound fallback."""
        m = self.config.n_clients
        sup = np.asarray(schedule_support(self.topo_schedule), bool)
        js = {int(j) for j in joiners}
        R = np.eye(m)
        for j in js:
            nbrs = [k for k in range(m)
                    if k != j and k not in js and sup[j, k]]
            if nbrs:
                R[j, :] = 0.0
                R[j, nbrs] = 1.0 / len(nbrs)
        self._apply_client_matrix(R, zero_ef_rows=tuple(sorted(js)))

    def _one_round(self, *, is_last: bool, notify: bool,
                   want_event: bool = False) -> Optional[RoundEvent]:
        t = self.t
        join_fn = getattr(self.topo_schedule, "join_events", None)
        if join_fn is not None:
            joiners = tuple(join_fn(t))
            if joiners:
                self._warm_start_clients(joiners)
        batch = self._to_device(next(self._batches))
        W_np = self.topo_schedule.next_w(t)
        masks = self.schedule.next_masks(
            t, {"W": W_np, "round": t, "session": self})
        W_dev = self._device_scalar_inputs(np.asarray(W_np, np.float32))
        masks_dev = self._device_scalar_inputs(masks.as_array())
        if self.ef is not None:
            # quantized round: the error-feedback buffer threads through
            self.lora, self.opt_state, metrics, self.ef = self.round_fn(
                self.base, self.lora, self.opt_state, batch, W_dev,
                masks_dev, self.ef)
        else:
            self.lora, self.opt_state, metrics = self.round_fn(
                self.base, self.lora, self.opt_state, batch, W_dev,
                masks_dev)
        self.last_metrics = metrics
        # one observation payload per round, shared by the control loop
        # and every callback (construction is lazy — no device sync here)
        stats = RoundStats(t, W_np, phase=self._track_phase(masks),
                           masks=masks, metrics=metrics, lora=self.lora,
                           comm_bytes=self._round_comm_bytes())
        self.last_stats = stats
        if self.control is not None:
            self.control.observe(stats)
        # t advances BEFORE callbacks fire: a checkpoint taken inside a
        # callback resumes after the round it just observed
        self.t = t + 1
        ev = None
        if want_event or (notify and self.callbacks):
            ev = RoundEvent(self, t, masks, W_np, metrics, is_last,
                            stats=stats)
        if notify and ev is not None:
            for cb in self.callbacks:
                cb.on_round_end(ev)
        return ev

    def run(self, rounds: Optional[int] = None) -> RunResult:
        """Run `rounds` (default config.rounds) rounds from the current
        state; fires on_round_end per round and on_run_end at the end."""
        n = self.config.rounds if rounds is None else rounds
        t0 = time.time()
        end = self.t + n
        while self.t < end:
            self._one_round(is_last=(self.t == end - 1), notify=True)
        jax.block_until_ready(self.lora)
        wall = time.time() - t0
        final = _metric_loss(self.last_metrics) \
            if self.last_metrics is not None else float("nan")
        result = RunResult(rounds=n, wall_s=wall, final_loss=final,
                           T=getattr(self.schedule, "T", self.T))
        for cb in self.callbacks:
            cb.on_run_end(self, result)
        return result

    # -- evaluation / diagnostics ------------------------------------------
    def consensus(self) -> dict:
        return {k: float(v) for k, v in
                consensus_stats(self.lora).items()}

    def client_lora(self, i: int):
        return jax.tree.map(lambda x: x[..., i, :, :], self.lora)

    def evaluate(self, n: Optional[int] = None,
                 seed: Optional[int] = None) -> dict:
        """Mean per-client accuracy on the task's balanced test draw
        (classifier tasks; the paper's evaluation protocol)."""
        if self.task is None:
            raise ValueError("evaluate() is defined for classifier tasks; "
                             "LM runs score held-out loss/perplexity at the "
                             "call site (see examples/dfl_finetune.py)")
        cfg = self.config
        n_eval = n if n is not None else cfg.eval_n
        eval_seed = seed if seed is not None else cfg.eval_seed
        if isinstance(self.task, ShardSet):
            test = self.task.eval_batch(n_eval, seed=eval_seed)
        else:
            test = eval_batch(self.task, n_eval, seed=eval_seed)
        # placement hook: on a cluster the eval batch must be replicated
        # onto the global mesh next to the replicated base params
        toks = self._device_scalar_inputs(test["tokens"])
        labs = self._device_scalar_inputs(test["labels"])
        accs = [float(self._acc_fn(self.base, toks, labs,
                                   self.client_lora(i)))
                for i in range(cfg.n_clients)]
        return {"acc": float(np.mean(accs)),
                "acc_std_clients": float(np.std(accs)),
                "per_client": accs}

    # -- checkpoint / resume ------------------------------------------------
    def save(self, path: str) -> None:
        """Checkpoint lora + optimizer state + round counter (flat npz)."""
        tree = {
            "lora": self.lora,
            "opt": {"step": self.opt_state.step, "mu": self.opt_state.mu,
                    "nu": self.opt_state.nu},
            "meta": {"round": np.int64(self.t)},
        }
        if self.ef is not None:
            tree["ef"] = self.ef
        save_pytree(path, tree)

    def restore(self, path: str) -> int:
        """Resume from a checkpoint: restores state AND replays the
        topology/data/schedule RNGs up to the saved round, so a restored
        run continues bit-for-bit where the original left off — including
        time-varying TopologySchedules (churn Markov state, phase
        switches), whose per-round W_t draws are re-issued in order. A
        user-supplied `schedule`/`topology_schedule` object must be
        freshly constructed (the replay advances it from its current
        state)."""
        tree = load_pytree(path)
        self.reset_state()
        cfg = self.config
        self.topology = make_topology(cfg.topology, cfg.n_clients, cfg.p,
                                      seed=cfg.seed,
                                      **dict(cfg.topology_kw))
        if self._user_topo_schedule is None:
            self.topo_schedule = schedule_from_config(
                cfg, topology=self.topology)
        # fresh control plane (estimator/controller state replays below)
        # and re-install its weight policy into the rebuilt schedule
        self.control = self._make_control()
        self._install_weight_policy()
        if self._user_schedule is None:
            self.schedule = self._default_schedule()
        saved_round = int(np.asarray(tree["meta"]["round"]))
        if hasattr(self._batches, "seek"):
            # shard streams are pure functions of the round index: replay
            # is an O(1) reposition, bit-for-bit equal to re-iteration
            self._batches.seek(saved_round)
        else:
            for _ in range(saved_round):
                next(self._batches)          # data RNG replay (numpy only)
        for t in range(saved_round):
            W = self.topo_schedule.next_w(t)  # topology RNG replay
            masks = self.schedule.next_masks(
                t, {"W": W, "round": t, "session": self})
            self._track_phase(masks)
            if self.control is not None:
                # W-only replay: spectral/gram re-estimate exactly; the
                # frozen probe resets and re-locks from live rounds
                self.control.observe_replay(t, W)
        self.lora = jax.tree.map(jnp.asarray, tree["lora"])
        opt = tree["opt"]
        self.opt_state = AdamWState(
            step=jnp.asarray(opt["step"]),
            mu=jax.tree.map(jnp.asarray, opt["mu"]),
            nu=jax.tree.map(jnp.asarray, opt["nu"]))
        if self.ef is not None and "ef" in tree:
            self.ef = jnp.asarray(tree["ef"])
        self.t = saved_round
        return saved_round
