"""`DFLConfig` — the single declarative description of a DFL experiment.

One frozen dataclass captures everything the paper's protocol needs:
model/task, federation geometry (clients, graph family + topology_kw,
communication scenario + scenario_kw, p), method + switching interval,
optimization (rounds, local steps, lr, batch), engine knobs (mixing
lowering, donation), and seeds. A `Session` (repro.api.session)
turns a config into a running experiment; `cache_key()` is a stable JSON
hash used by the benchmark results cache.

Seed conventions (all derivable from `seed` unless overridden):
  base params   <- jax.random.key(init_seed)        (init_seed = seed)
  LoRA factors  <- jax.random.key(init_seed + 1)
  topology RNG  <- seed
  data pipeline <- data_seed                         (data_seed = seed)
  evaluation    <- eval_seed (classifier tasks)
Benchmark sweeps typically pin `init_seed` while varying `seed`, so every
seed shares one init and only data/topology randomness moves.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from dataclasses import dataclass, field
from typing import Mapping, Optional, Union

from repro.control.config import ControlConfig
from repro.core.alternating import METHODS
from repro.core.topology import GRAPH_FAMILIES
from repro.data.partition import PARTITIONERS
from repro.scenarios.library import SCENARIOS

CLASSIFIER_TASKS = ("sst2", "qqp", "qnli", "mnli")
TOPOLOGIES = GRAPH_FAMILIES
MIX_IMPLS = ("planned", "per_leaf", "concat")
FLAT_LOWERINGS = ("auto", "flat", "per_segment")
MIX_GATHER_MODES = ("auto", "on", "off")
MIX_COMM_MODES = ("dense", "sparse", "sparse_overlap")
MIX_QUANT_MODES = ("off", "int8", "fp8")
DATA_SOURCES = ("synthetic", "shards")

_KEY_VERSION = 8   # bump when semantics of any field change

# legacy flat-knob defaults (pre-v8 configs; see DFLConfig.control)
_LEGACY_ADAPTIVE = {"adaptive_T": False, "adaptive_c": 0.35,
                    "adaptive_t_max": 15}


@dataclass(frozen=True)
class DFLConfig:
    """Declarative DFL experiment description (validated, hashable key)."""

    # -- model / task -------------------------------------------------------
    model: str = "gemma3-1b"     # arch name (repro.configs) or "encoder"
    task: str = "lm"             # "lm" or a classifier task (CLASSIFIER_TASKS)
    reduced: bool = True         # reduced() arch config (CPU scale)
    model_kw: tuple = ()         # encoder_config(**kw) overrides (dict ok)

    # -- federation ---------------------------------------------------------
    n_clients: int = 8
    topology: str = "complete"   # underlying graph family (GRAPH_FAMILIES)
    topology_kw: tuple = ()      # graph params (er_q, ws_k/ws_beta, torus_*)
    p: float = 0.2               # edge activation probability
    scenario: str = "gossip"     # communication condition (SCENARIOS):
                                 # "gossip" = the paper's Lemma A.10 sampler
    scenario_kw: tuple = ()      # schedule params (churn leave/rejoin,
                                 # straggler drop, phase_switch knobs)
    method: str = "tad"
    T: int = 0                   # switching interval; 0 = topology-aware T*
    # DEPRECATED flat adaptive knobs (v7-era). Still accepted: non-default
    # values emit a DeprecationWarning and map onto `control`; after
    # resolution they mirror the struct (adaptive_T <-> t_policy,
    # adaptive_c <-> c, adaptive_t_max <-> t_max), so old- and new-style
    # configs compare (and cache-key) identically.
    adaptive_T: Optional[bool] = None     # -> control.t_policy "adaptive"
    adaptive_c: Optional[float] = None    # -> control.c
    adaptive_t_max: Optional[int] = None  # -> control.t_max
    control: Optional[Union[ControlConfig, Mapping]] = None
                                 # closed-loop control plane policies
                                 # (repro.control.ControlConfig; dict ok);
                                 # None resolves to the open-loop default

    # -- optimization -------------------------------------------------------
    rounds: int = 40
    local_steps: int = 4
    batch_size: int = 4          # per-client, per-local-step
    seq_len: int = 64            # LM task only (classifier tasks fix theirs)
    lr: float = 1e-3

    # -- engine -------------------------------------------------------------
    mix_impl: str = "planned"
    mix_flat_lowering: str = "auto"   # auto = flat on TPU, per-segment off
    mix_gather: str = "auto"     # dense mode: all-gather clients before
                                 # mixing: auto = on iff multi-process
                                 # (bitwise cluster parity), "on"/"off"
                                 # pin it (ignored by sparse modes)
    mix_comm: str = "dense"      # gossip comm lowering: "dense" |
                                 # "sparse" (topology-support exchange,
                                 # bitwise equal) | "sparse_overlap"
                                 # (one-round-delayed neighbor terms)
    mix_quant: str = "off"       # compressed gossip: quantize the sparse
                                 # halo exchange ("int8" | "fp8") with
                                 # per-client error feedback; "off" keeps
                                 # the fp32 wire format bit-for-bit
    donate: bool = False         # donate lora/opt buffers (in-place round)

    # -- seeds / data -------------------------------------------------------
    seed: int = 0
    data_seed: Optional[int] = None   # defaults to seed
    init_seed: Optional[int] = None   # defaults to seed
    feature_shift: int = 0       # per-client feature dialects (classifier)
    eval_n: int = 384
    eval_seed: int = 9999
    data_source: str = "synthetic"  # "synthetic" (per-round draws) |
                                 # "shards" (tokenized shard set at
                                 # data_path through FederatedStream)
    data_path: str = ""          # shard-set directory (data_source=shards)
    partitioner: str = "paper"   # non-IID partitioner (repro.data
                                 # PARTITIONERS; shards source only)
    partitioner_kw: tuple = ()   # partitioner params (dirichlet alpha, ...)
    data_prefetch: int = 0       # stream prefetch depth (0 = synchronous)

    def __post_init__(self):
        for kw_field in ("model_kw", "topology_kw", "scenario_kw",
                         "partitioner_kw"):
            v = getattr(self, kw_field)
            if isinstance(v, Mapping):
                object.__setattr__(self, kw_field, tuple(sorted(v.items())))
            else:
                object.__setattr__(self, kw_field, tuple(v))
        if self.data_seed is None:
            object.__setattr__(self, "data_seed", self.seed)
        if self.init_seed is None:
            object.__setattr__(self, "init_seed", self.seed)
        self._resolve_control()
        self._validate()

    def _resolve_control(self) -> None:
        """Resolve the deprecated flat adaptive knobs and the structured
        `control` field into one canonical ControlConfig, then mirror the
        struct back onto the flat fields so old-style and new-style
        configs are field-identical (same equality, same cache key)."""
        flat = {k: getattr(self, k) for k in _LEGACY_ADAPTIVE}
        ctrl = self.control
        if ctrl is not None:
            ctrl = ControlConfig.coerce(ctrl)
            # both given (e.g. a to_dict round-trip carrying the mirror):
            # consistent values pass silently, conflicts are errors
            mirror = {"adaptive_T": ctrl.t_policy == "adaptive",
                      "adaptive_c": ctrl.c, "adaptive_t_max": ctrl.t_max}
            for k, v in flat.items():
                if v is not None and v != mirror[k]:
                    raise ValueError(
                        f"DFLConfig: deprecated {k}={v!r} conflicts with "
                        f"control={ctrl}; set the ControlConfig field only")
        else:
            given = {k: v for k, v in flat.items() if v is not None}
            resolved = {**_LEGACY_ADAPTIVE, **given}
            if any(resolved[k] != _LEGACY_ADAPTIVE[k] for k in given):
                warnings.warn(
                    "DFLConfig adaptive_T/adaptive_c/adaptive_t_max are "
                    "deprecated; use control=ControlConfig(t_policy="
                    "'adaptive', c=..., t_max=...) (repro.control)",
                    DeprecationWarning, stacklevel=4)
            ctrl = ControlConfig(
                t_policy="adaptive" if resolved["adaptive_T"] else "fixed",
                c=resolved["adaptive_c"],
                t_max=resolved["adaptive_t_max"])
        object.__setattr__(self, "control", ctrl)
        object.__setattr__(self, "adaptive_T", ctrl.t_policy == "adaptive")
        object.__setattr__(self, "adaptive_c", ctrl.c)
        object.__setattr__(self, "adaptive_t_max", ctrl.t_max)

    def _validate(self) -> None:
        def check(cond, msg):
            if not cond:
                raise ValueError(f"DFLConfig: {msg}")

        check(self.task == "lm" or self.task in CLASSIFIER_TASKS,
              f"unknown task {self.task!r}; known: 'lm' + {CLASSIFIER_TASKS}")
        if self.task == "lm":
            check(self.model != "encoder",
                  "task 'lm' needs an architecture name, not 'encoder'")
            check(not self.model_kw,
                  "model_kw applies to the 'encoder' classifier model only")
        else:
            check(self.model == "encoder",
                  f"classifier task {self.task!r} requires model='encoder'")
        check(self.method in METHODS,
              f"unknown method {self.method!r}; known: {METHODS}")
        check(self.topology in TOPOLOGIES,
              f"unknown topology {self.topology!r}; known: {TOPOLOGIES}")
        check(self.scenario in SCENARIOS,
              f"unknown scenario {self.scenario!r}; known: {SCENARIOS}")
        check(not (self.scenario in ("gossip", "static")
                   and self.scenario_kw),
              f"scenario {self.scenario!r} takes no scenario_kw")
        check(self.mix_impl in MIX_IMPLS,
              f"unknown mix_impl {self.mix_impl!r}; known: {MIX_IMPLS}")
        check(self.mix_flat_lowering in FLAT_LOWERINGS,
              f"unknown mix_flat_lowering {self.mix_flat_lowering!r}; "
              f"known: {FLAT_LOWERINGS}")
        check(self.mix_gather in MIX_GATHER_MODES,
              f"unknown mix_gather {self.mix_gather!r}; "
              f"known: {MIX_GATHER_MODES}")
        check(self.mix_comm in MIX_COMM_MODES,
              f"unknown mix_comm {self.mix_comm!r}; "
              f"known: {MIX_COMM_MODES}")
        check(self.mix_comm == "dense" or self.mix_impl == "planned",
              f"mix_comm {self.mix_comm!r} lowers through the MixPlan "
              f"flat layout; it requires mix_impl='planned'")
        check(self.mix_quant in MIX_QUANT_MODES,
              f"unknown mix_quant {self.mix_quant!r}; "
              f"known: {MIX_QUANT_MODES}")
        check(self.mix_quant == "off" or self.mix_comm != "dense",
              f"mix_quant {self.mix_quant!r} compresses the sparse halo "
              f"exchange; it requires mix_comm='sparse' or "
              f"'sparse_overlap'")
        check(self.data_source in DATA_SOURCES,
              f"unknown data_source {self.data_source!r}; "
              f"known: {DATA_SOURCES}")
        if self.data_source == "shards":
            check(bool(self.data_path),
                  "data_source 'shards' requires data_path (a shard-set "
                  "directory; see repro.data.shards.write_shards)")
            check(self.task != "lm",
                  "data_source 'shards' serves classifier tasks (the LM "
                  "stream stays synthetic)")
        else:
            check(self.partitioner == "paper" and not self.partitioner_kw,
                  "partitioner/partitioner_kw apply to data_source="
                  "'shards' (the synthetic source hard-codes the paper "
                  "rows)")
        check(self.partitioner in PARTITIONERS,
              f"unknown partitioner {self.partitioner!r}; "
              f"known: {sorted(PARTITIONERS)}")
        check(self.data_prefetch >= 0, "data_prefetch must be >= 0")
        check(self.n_clients >= 2, "n_clients must be >= 2")
        check(0.0 < self.p <= 1.0, "p must be in (0, 1]")
        check(self.rounds > 0, "rounds must be positive")
        check(self.local_steps > 0, "local_steps must be positive")
        check(self.batch_size > 0, "batch_size must be positive")
        check(self.T >= 0, "T must be >= 0 (0 selects T*(rho))")
        if self.control.t_policy == "adaptive":
            check(self.method in ("tad", "rolora"),
                  "control.t_policy 'adaptive' (deprecated alias "
                  "adaptive_T) applies to alternating methods only")
        if self.control.weight_policy == "fmmc":
            check(self.scenario != "gossip",
                  "control.weight_policy 'fmmc' rewires Metropolis-weight "
                  "construction; the 'gossip' pairwise sampler has no "
                  "weight matrix to optimize — pick a scenario such as "
                  "'static' or 'edge_activation'")

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for kw_field in ("model_kw", "topology_kw", "scenario_kw",
                         "partitioner_kw"):
            d[kw_field] = dict(getattr(self, kw_field))
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "DFLConfig":
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    def cache_key(self) -> str:
        """Stable 16-hex id of the full setting (benchmark results cache)."""
        blob = json.dumps({"v": _KEY_VERSION, **self.to_dict()},
                          sort_keys=True)
        return hashlib.md5(blob.encode()).hexdigest()[:16]

    def replace(self, **kw) -> "DFLConfig":
        """dataclasses.replace with seed re-derivation: when `seed`
        changes and data_seed/init_seed were following it (equal to the
        old seed) and are not explicitly overridden, they follow the new
        seed instead of freezing at their old resolved values. Control
        fields re-resolve analogously: replacing a deprecated flat knob
        re-derives `control` from the flat triple, and replacing
        `control` drops the stale flat mirror."""
        legacy = [k for k in _LEGACY_ADAPTIVE if k in kw]
        if legacy and "control" not in kw:
            kw["control"] = None          # flat knobs win; struct re-derives
            for k in _LEGACY_ADAPTIVE:
                kw.setdefault(k, getattr(self, k))
        elif "control" in kw and not legacy:
            for k in _LEGACY_ADAPTIVE:
                kw[k] = None              # struct wins; mirror re-derives
        if "seed" in kw:
            if "data_seed" not in kw and self.data_seed == self.seed:
                kw["data_seed"] = None
            if "init_seed" not in kw and self.init_seed == self.seed:
                kw["init_seed"] = None
        return dataclasses.replace(self, **kw)
