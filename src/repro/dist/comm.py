"""`CommPlan` — topology-derived sparse communication plans for gossip.

The dense cluster lowering (``mix_gather``) all-gathers the full stacked
client axis every round regardless of how sparse W_t is — O(m) rows per
process even when a ring couples only O(degree) neighbors. This module
compiles the *union support* of a `TopologySchedule`'s mixing matrices
(every (i, j) any W_t of the run can make nonzero) against the process
grid into a static exchange plan:

  * ``needed``  — which remote client rows each shard's W rows touch,
  * ``export``  — which locally-owned rows any other shard needs,
  * a rectangular ``(n_shards, k)`` export index table (k = the max
    export count, shards with fewer rows pad with local row 0 — a real
    row, so the padded exchange carries only true values),
  * per-shard send/recv peer sets (the gossip neighborhoods), and
  * exact per-round byte accounting for both the dense and the sparse
    exchange.

The plan is *data* for `repro.core.mixing.mix_tree_sparse`: inside one
``shard_map`` region each shard gathers its export rows, one small
all-gather moves the ``(n_shards, k, cols)`` halo (on gloo/CPU; a TPU
mesh lowers the same op to collective-permute traffic on the torus),
rows land in a zero-initialized (m, cols) source buffer, and the local
W rows contract against it. Rows outside the support multiply exact
zero weights, so the sparse result equals the dense contraction
bit-for-bit on static graphs (see tests/test_comm.py).

Layering: this module knows nothing about schedules — callers hand it a
support adjacency (`repro.scenarios.schedule.schedule_support` derives
one from any library `TopologySchedule`).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True, eq=False)
class CommPlan:
    """Static sparse-exchange plan of one (support, process-grid) pair."""

    m: int                      # global clients
    n_shards: int               # process-grid shards of the client axis
    m_loc: int                  # clients per shard (m / n_shards)
    k: int                      # export rows per shard (padded, uniform)
    export_local: np.ndarray    # (n_shards, k) int32 local row indices
    export_global: np.ndarray   # (n_shards*k,) int32 global row ids
    support: np.ndarray         # (m, m) bool union support (incl. diag)
    send_peers: Tuple[tuple, ...]   # per shard: shards reading its rows
    recv_peers: Tuple[tuple, ...]   # per shard: shards it reads rows from

    @property
    def cross_edges(self) -> int:
        """Support entries that cross a shard boundary (the rows moved)."""
        owner = np.arange(self.m) // self.m_loc
        return int(np.count_nonzero(
            self.support & (owner[:, None] != owner[None, :])))

    def sparse_recv_bytes(self, cols: int, itemsize: int = 4) -> int:
        """Per-round bytes one process RECEIVES under the sparse halo
        exchange: the other shards' export rows of the (m, cols) flat
        mixing buffer. 0 on a single shard."""
        if self.n_shards <= 1:
            return 0
        return itemsize * cols * self.k * (self.n_shards - 1)

    def sparse_recv_bytes_quant(self, cols: int, payload_itemsize: int = 1,
                                scale_itemsize: int = 4) -> int:
        """Per-round bytes one process RECEIVES under the *compressed*
        halo exchange (``mix_quant`` int8/fp8): each export row ships a
        1-byte-per-element quantized payload plus one f32 scale instead
        of fp32 values — (payload·cols + scale) per row versus 4·cols,
        ≈ 0.25× at int8. 0 on a single shard."""
        if self.n_shards <= 1:
            return 0
        per_row = payload_itemsize * cols + scale_itemsize
        return per_row * self.k * (self.n_shards - 1)

    def link_bytes(self, cols: int, itemsize: int = 4) -> np.ndarray:
        """Per-link byte cost matrix of the sparse exchange: ``(m, m)``
        floats where entry (i, j) is the bytes/round that support link
        moving client j's flat row toward client i costs — ``itemsize *
        cols`` when the link crosses a shard boundary, 0 for co-located
        links (the halo never leaves the process) and off-support pairs.
        This is the measured bandwidth figure the control plane feeds to
        `fastest_mixing_weights` as its ``link_cost``: FMMC then trades
        spectral gap against weight placed on expensive cross-process
        links."""
        owner = np.arange(self.m) // self.m_loc
        cross = self.support & (owner[:, None] != owner[None, :])
        return (float(itemsize) * cols) * cross.astype(float)

    def signature(self) -> str:
        """Stable hex id of (support, grid) — build-cache key material."""
        h = hashlib.md5()
        h.update(np.ascontiguousarray(self.support, np.uint8).tobytes())
        h.update(f"/{self.m}/{self.n_shards}".encode())
        return h.hexdigest()[:16]


def dense_recv_bytes(m: int, n_shards: int, cols: int,
                     itemsize: int = 4) -> int:
    """Per-round bytes one process RECEIVES under the dense ``mix_gather``
    lowering: every other shard's client rows of the stacked LoRA state
    (cols = columns per client of the flat layout). 0 on a single shard."""
    if n_shards <= 1:
        return 0
    return itemsize * cols * (m - m // n_shards)


def build_comm_plan(support: np.ndarray, n_shards: int) -> CommPlan:
    """Compile a union-support adjacency against an ``n_shards`` grid.

    ``support[i, j]`` truthy means some W_t of the run may weight client
    j's state into client i's mix. The diagonal is always added (a client
    keeps its own state), and ownership is the contiguous process-major
    block layout of `repro.dist.multihost.local_client_slice`.
    """
    sup = np.asarray(support)
    if sup.ndim != 2 or sup.shape[0] != sup.shape[1]:
        raise ValueError(f"support must be square, got {sup.shape}")
    m = sup.shape[0]
    if n_shards < 1 or m % n_shards != 0:
        raise ValueError(f"client axis {m} must divide over {n_shards} "
                         f"shards")
    sup = (sup != 0)
    np.fill_diagonal(sup, True)
    m_loc = m // n_shards
    owner = np.arange(m) // m_loc

    needed = []      # per shard: remote global rows its W rows read
    for p in range(n_shards):
        cols = np.flatnonzero(sup[p * m_loc:(p + 1) * m_loc].any(axis=0))
        needed.append([int(j) for j in cols if owner[j] != p])
    export = [sorted({j for q in range(n_shards) if q != p
                      for j in needed[q] if owner[j] == p})
              for p in range(n_shards)]

    k = max((len(e) for e in export), default=0)
    export_local = np.zeros((n_shards, k), np.int32)
    export_global = np.zeros(n_shards * k, np.int32)
    for p, rows in enumerate(export):
        if k == 0:
            break
        # pad with local row 0: a real row, so padded slots carry true
        # values and the duplicate scatter writes are value-identical
        padded = rows + [p * m_loc] * (k - len(rows))
        export_local[p] = np.asarray(padded, np.int32) - p * m_loc
        export_global[p * k:(p + 1) * k] = padded

    recv = tuple(tuple(sorted({int(owner[j]) for j in needed[p]}))
                 for p in range(n_shards))
    send = tuple(tuple(sorted({q for q in range(n_shards)
                               if p in recv[q]}))
                 for p in range(n_shards))
    return CommPlan(m=m, n_shards=n_shards, m_loc=m_loc, k=k,
                    export_local=export_local, export_global=export_global,
                    support=sup, send_peers=send, recv_peers=recv)
