"""Multi-process execution substrate: one process = one shard of the
client axis.

`repro.dist.sharding` maps logical axes onto a mesh; this module is the
layer below that makes the mesh *span processes*. A cluster run calls
``initialize()`` once (reading ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES``
/ ``REPRO_PROCESS_ID`` when launched by ``repro.launch.cluster``), builds a
``cluster_mesh()`` over every process's devices, and then the exact same
jitted DFL round runs SPMD: each process owns ``m / process_count`` clients,
the planned gossip mix lowers to cross-process collectives, and everything
above (`Session`, schedules, callbacks) is unchanged.

All helpers degrade to exact no-ops in a single-process run, so the same
code path serves a laptop and a cluster. On CPU the collective backend is
gloo (``jax_cpu_collectives_implementation``), which is what the
``--simulate N`` CI mode exercises; on TPU pods ``jax.distributed`` uses
the native fabric.

Two rules for code running under a cluster mesh:

1. Every process executes the same jax computations in the same order
   (multi-controller SPMD). Callbacks run on all processes; gate *side
   effects* (prints, file writes) on ``is_primary()``, never the
   computation itself.
2. Host-side randomness must agree across processes. Config-derived
   schedules agree by construction (same seed); user-supplied stateful
   schedules are wrapped in ``repro.scenarios.BroadcastSchedule`` so rank
   0's draw is the only one that counts.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_INITIALIZED = [False]

# env protocol of repro.launch.cluster (also honored by initialize())
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> bool:
    """Join the process grid (idempotent; no-op for single-process runs).

    Arguments default to the ``REPRO_*`` env protocol set by
    ``repro.launch.cluster``; with neither args nor env this is a
    single-process run and nothing happens. Returns True when
    ``jax.distributed`` was (or already is) initialized.

    Must be called before any jax device/computation use — CPU collectives
    (gloo) are selected here and jax backends are frozen on first use.
    """
    if _INITIALIZED[0]:
        return True
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None and os.environ.get(ENV_NUM_PROCESSES):
        num_processes = int(os.environ[ENV_NUM_PROCESSES])
    if process_id is None and os.environ.get(ENV_PROCESS_ID):
        process_id = int(os.environ[ENV_PROCESS_ID])
    if coordinator is None or num_processes is None or num_processes <= 1:
        return False
    try:  # CPU multi-process collectives route through gloo
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # noqa: BLE001 — config name varies across jax versions
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    _INITIALIZED[0] = True
    return True


def shutdown() -> None:
    if _INITIALIZED[0]:
        jax.distributed.shutdown()
        _INITIALIZED[0] = False


def process_count() -> int:
    return jax.process_count()


def process_index() -> int:
    return jax.process_index()


def is_distributed() -> bool:
    return jax.process_count() > 1


def is_primary() -> bool:
    """True on the process that owns side effects (logs, checkpoints)."""
    return jax.process_index() == 0


def cluster_mesh(axis: str = "data") -> Mesh:
    """1-D mesh over ALL processes' devices on the given axis name.

    ``DEFAULT_AXIS_MAP`` routes the logical "clients"/"batch" axes over
    ("pod", "data"), so with axis="data" the client axis shards across the
    whole process grid — the decentralized setting: each process is a
    "site" owning a contiguous block of clients.
    """
    return Mesh(np.array(jax.devices()), (axis,))


def local_client_slice(m: int, mesh: Optional[Mesh] = None) -> slice:
    """This process's contiguous block of the client axis.

    Requires ``m`` divisible by the total device count (enforced by
    ``ClusterSession``); devices are laid out process-major in
    ``jax.devices()``, so process p owns clients [p*m/np, (p+1)*m/np).
    """
    n_dev = mesh.size if mesh is not None else jax.device_count()
    if m % n_dev != 0:
        raise ValueError(f"client axis {m} must divide over {n_dev} devices")
    per_proc = m // jax.process_count()
    lo = jax.process_index() * per_proc
    return slice(lo, lo + per_proc)


# ---------------------------------------------------------------------------
# host<->global array movement
# ---------------------------------------------------------------------------

def replicate(mesh: Mesh, x) -> jax.Array:
    """Global fully-replicated array from identical per-host values.

    Every process must pass the same value (exact replication, no
    arithmetic); single-process this is a plain device put.
    """
    x = np.asarray(x)
    return jax.make_array_from_process_local_data(
        NamedSharding(mesh, P()), x, x.shape)


def replicate_tree(mesh: Mesh, tree):
    return jax.tree.map(lambda x: replicate(mesh, x), tree)


def shard_clients(mesh: Mesh, x, global_shape, axis: int) -> jax.Array:
    """Global array sharded over the client axis from this process's
    local block (``x`` covers exactly ``local_client_slice`` rows of
    ``axis``). The mesh's single axis carries the client dim; every other
    dim is replicated."""
    spec = [None] * len(global_shape)
    spec[axis] = mesh.axis_names[0]
    return jax.make_array_from_process_local_data(
        NamedSharding(mesh, P(*spec)), np.ascontiguousarray(x),
        tuple(global_shape))


@functools.lru_cache(maxsize=None)
def _gather_identity(out_sharding: NamedSharding):
    # one jitted identity per out-sharding: repeated gathers (ServeSync
    # every K rounds, 4 trees per checkpoint save) must not retrace
    return jax.jit(lambda t: t, out_shardings=out_sharding)


def fully_replicated(tree, mesh: Optional[Mesh] = None):
    """Gather every leaf to full replication (one jitted identity; the
    allgather is exact — no arithmetic). Leaves become addressable on
    every process, so ``np.asarray`` works directly afterwards."""
    if mesh is None or mesh.size == 1:
        return tree
    return _gather_identity(NamedSharding(mesh, P()))(tree)


def to_host(tree, mesh: Optional[Mesh] = None):
    """Gather a (possibly client-sharded) tree to plain numpy on every
    process — the checkpoint-save path under a cluster mesh."""
    return jax.tree.map(np.asarray, fully_replicated(tree, mesh))


def sync(tag: str = "repro") -> None:
    """Barrier across the process grid (no-op single-process)."""
    if is_distributed():
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(tag)


def broadcast_from_primary(x: np.ndarray) -> np.ndarray:
    """Rank 0's array on every process, BIT-EXACT (no-op single-process).

    The payload travels as raw bytes (uint8 view), so float64 host values
    — e.g. a TopologySchedule's W_t, which adaptive-T estimators consume
    at full precision — arrive with the identical bits rank 0 drew; jax's
    default float64→float32 demotion never touches them. Every process
    must pass an array of the same shape and dtype.
    """
    x = np.asarray(x)
    if not is_distributed():
        return x
    from jax.experimental import multihost_utils
    raw = np.ascontiguousarray(x).ravel().view(np.uint8)
    # integer transport is value-exact even though the collective may
    # upcast uint8 (e.g. to int32) — convert back before re-viewing bytes
    out = np.asarray(multihost_utils.broadcast_one_to_all(raw))
    return out.astype(np.uint8).view(x.dtype).reshape(x.shape)
