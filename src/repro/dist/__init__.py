"""Distribution substrate: the logical-axis sharding layer (GSPMD).

``repro.dist.sharding`` is the single place where logical axis names
("clients", "batch", "model", "fsdp", ...) meet concrete mesh axes.
Model and launch code never name mesh axes directly.
"""
from repro.dist import sharding

__all__ = ["sharding"]
