"""Distribution substrate: sharding (GSPMD) + multihost (process grid).

``repro.dist.sharding`` is the single place where logical axis names
("clients", "batch", "model", "fsdp", ...) meet concrete mesh axes.
Model and launch code never name mesh axes directly.

``repro.dist.multihost`` makes the mesh span processes: process-grid
initialization (`jax.distributed`), the cluster mesh, client-axis
ownership, and exact host<->global array movement (replicate /
shard_clients / fully_replicated). `repro.api.ClusterSession` sits on it.

``repro.dist.comm`` compiles a topology's union support against the
process grid into a `CommPlan` — the static neighbor-only exchange the
sparse gossip lowering (`mix_comm="sparse"/"sparse_overlap"`) runs
instead of the dense client-axis all-gather.
"""
from repro.dist import comm, multihost, sharding
from repro.dist.comm import CommPlan, build_comm_plan, dense_recv_bytes

__all__ = ["sharding", "multihost", "comm", "CommPlan", "build_comm_plan",
           "dense_recv_bytes"]
