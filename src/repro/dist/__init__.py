"""Distribution substrate: sharding (GSPMD) + multihost (process grid).

``repro.dist.sharding`` is the single place where logical axis names
("clients", "batch", "model", "fsdp", ...) meet concrete mesh axes.
Model and launch code never name mesh axes directly.

``repro.dist.multihost`` makes the mesh span processes: process-grid
initialization (`jax.distributed`), the cluster mesh, client-axis
ownership, and exact host<->global array movement (replicate /
shard_clients / fully_replicated). `repro.api.ClusterSession` sits on it.
"""
from repro.dist import multihost, sharding

__all__ = ["sharding", "multihost"]
