"""Logical-axis sharding: the mapping layer between model code and meshes.

Model / launch code annotates arrays with *logical* dim names ("clients",
"batch", "model", "fsdp", "seq", "seq_act"); a (mesh, axis_map) pair bound
via ``set_mesh`` translates those names to mesh axes. Outside a bound mesh
every annotation is a no-op, so the same model code runs unchanged on a
laptop CPU and a 512-chip pod.

``DEFAULT_AXIS_MAP`` routes the DFL client axis over ("pod", "data") —
axes absent from the mesh in use are dropped at resolution time, so one
map serves the single-pod (16, 16) mesh (clients over "data"), the
multi-pod (2, 16, 16) mesh (clients over pod x data — gossip across the
DCN boundary, the paper's inter-site links), the (2, 2) debug mesh, and
the 1x1 test mesh (everything replicated).

Parameter sharding follows Megatron rules (`_param_spec`): column weights
shard d_out, row weights shard d_in, embeddings shard the vocab dim,
stacked MoE experts shard the expert dim when divisible; rank/group dims
are never sharded. Non-divisible dims stay unsharded rather than erroring
— reduced test configs must lower on any mesh.
"""
from __future__ import annotations

import math
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Axis maps
# ---------------------------------------------------------------------------

DEFAULT_AXIS_MAP: dict = {
    "clients": ("pod", "data"),
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "model": ("model",),
}

# The multi-pod mesh uses the same logical routing — "pod" simply resolves
# there. Kept as a distinct name so launch code can document intent (and
# diverge later, e.g. pod-local FSDP).
MULTIPOD_AXIS_MAP: dict = dict(DEFAULT_AXIS_MAP)

_STATE = threading.local()


def set_mesh(mesh, axis_map: Optional[dict] = None) -> None:
    """Bind (mesh, axis_map) for `logical` / `axis_size` resolution."""
    _STATE.mesh = mesh
    _STATE.axis_map = dict(axis_map if axis_map is not None
                           else DEFAULT_AXIS_MAP)


def clear_mesh() -> None:
    _STATE.mesh = None
    _STATE.axis_map = None


def current_mesh():
    return getattr(_STATE, "mesh", None)


def current_axis_map() -> Optional[dict]:
    return getattr(_STATE, "axis_map", None)


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def resolve_axes(mesh, axes) -> tuple:
    """Mesh axes for a logical mapping, dropping axes the mesh lacks."""
    if not axes:
        return ()
    return tuple(a for a in axes if a in mesh.axis_names)


def axes_size(mesh, axes) -> int:
    """Product of the mapped mesh-axis sizes (1 when nothing resolves)."""
    return math.prod(mesh.shape[a] for a in resolve_axes(mesh, axes))


def axis_size(name: str) -> int:
    """Size of a *logical* axis under the bound mesh (1 when unbound)."""
    mesh, amap = current_mesh(), current_axis_map()
    if mesh is None or amap is None:
        return 1
    return axes_size(mesh, amap.get(name, ()))


def _entry(axes: tuple):
    """PartitionSpec entry: bare name for one axis, tuple for several."""
    return axes[0] if len(axes) == 1 else axes


def spec_for(shape, names, mesh, axis_map) -> P:
    """PartitionSpec from per-dim logical names.

    A dim is sharded only when its mapped axes resolve on the mesh, are
    not already consumed by an earlier dim, have product > 1, and divide
    the dim — otherwise it stays replicated (never an error).
    """
    parts: list = []
    used: set = set()
    for dim, name in zip(shape, names):
        axes = resolve_axes(mesh, axis_map.get(name, ())) if name else ()
        if axes and all(a not in used for a in axes):
            n = math.prod(mesh.shape[a] for a in axes)
            if n > 1 and dim % n == 0:
                parts.append(_entry(axes))
                used.update(axes)
                continue
        parts.append(None)
    return P(*parts)


def logical(x: jax.Array, *names) -> jax.Array:
    """Constrain ``x``'s sharding by logical dim names (no-op unbound)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    amap = current_axis_map() or DEFAULT_AXIS_MAP
    spec = spec_for(x.shape, names, mesh, amap)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def replicated(x: jax.Array) -> jax.Array:
    """Constrain ``x`` fully replicated on the bound mesh (no-op unbound).

    Under a cluster mesh this is the explicit gather: GSPMD lowers the
    constraint to an all-gather of whatever axes ``x`` was sharded over.
    The gather is exact (pure data movement), so computations downstream
    of it are bitwise equal to their single-process lowering.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def gather_clients(tree):
    """Replicate every leaf of a client-sharded tree (see ``replicated``).
    The DFL round applies this before gossip mixing when ``mix_gather``
    is on: one all-gather of the stacked LoRA state per round — the
    paper's communication step, made explicit — followed by a mixing
    contraction whose per-element arithmetic matches the single-process
    round bit-for-bit."""
    return jax.tree.map(replicated, tree)


# ---------------------------------------------------------------------------
# Parameter sharding (Megatron rules)
# ---------------------------------------------------------------------------

# Row-parallel weights contract their *input* dim against a column-sharded
# activation: shard d_in, all-reduce the output. Everything else matrix-
# shaped defaults to column-parallel (shard d_out).
_ROW_PARALLEL = frozenset({"wo", "w_down", "w_out"})


def _param_spec(path: str, shape, mesh, axis_map, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    path is "/"-joined tree keys (e.g. "groups/0/attn/wq"); only the leaf
    name and a "moe" path component participate in classification. With
    ``fsdp`` the non-TP matrix dim additionally shards over the "fsdp"
    logical axis.
    """
    name = path.rsplit("/", 1)[-1]
    nd = len(shape)
    parts: list = [None] * nd
    model = resolve_axes(mesh, axis_map.get("model", ()))
    data = resolve_axes(mesh, axis_map.get("fsdp", ()))
    m_n = math.prod(mesh.shape[a] for a in model) if model else 1
    d_n = math.prod(mesh.shape[a] for a in data) if data else 1

    if nd < 2:
        return P(*parts)          # norms / biases / scalars: replicated

    if name == "embed":           # (vocab, d): shard the vocab dim
        if m_n > 1 and shape[0] % m_n == 0:
            parts[0] = _entry(model)
        return P(*parts)
    if name == "unembed":         # (d, vocab): shard the vocab dim
        if m_n > 1 and shape[-1] % m_n == 0:
            parts[-1] = _entry(model)
        return P(*parts)

    # Stacked MoE experts (E, d0, d1): expert-parallel over "model" when E
    # divides it (dense-EP — each device holds only its local experts);
    # under fsdp the d_model matrix dim additionally shards over "data".
    in_moe = "moe" in path.split("/")
    if in_moe and nd == 3 and m_n > 1 and shape[0] % m_n == 0 \
            and set(model) != set(data):
        parts[0] = _entry(model)
        if fsdp and d_n > 1:
            dm = 2 if name in _ROW_PARALLEL else 1
            if shape[dm] % d_n == 0:
                parts[dm] = _entry(data)
        return P(*parts)

    # Generic matrix (leading group/stack dims never sharded): TP on the
    # last two dims per row/column classification.
    tp_dim = nd - 2 if name in _ROW_PARALLEL else nd - 1
    other = nd - 1 if name in _ROW_PARALLEL else nd - 2
    if m_n > 1 and shape[tp_dim] % m_n == 0:
        parts[tp_dim] = _entry(model)
    if fsdp and d_n > 1 and shape[other] % d_n == 0 \
            and not set(data) & set(model):
        parts[other] = _entry(data)
    return P(*parts)


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_shardings(params, mesh, axis_map: Optional[dict] = None, *,
                    fsdp: bool = False):
    """NamedSharding tree for a parameter (or ShapeDtypeStruct) tree."""
    amap = axis_map if axis_map is not None else DEFAULT_AXIS_MAP

    def one(path, leaf):
        spec = _param_spec(_path_str(path), leaf.shape, mesh, amap,
                           fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)
