"""qwen2-7b [dense]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064. QKV bias. [arXiv:2407.10671]
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="decoder",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    pattern=(LayerSpec(kind=ATTN, window=None, ffn=DENSE),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    citation="arXiv:2407.10671 (Qwen2)",
    sub_quadratic=False,
)
