"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384, 6H (kv=6), d_ff=1536,
vocab=51865. Encoder-decoder; conv/mel frontend is a stub — input_specs()
supplies 1500 precomputed frame embeddings. [arXiv:2212.04356]

Whisper uses plain LayerNorm + GELU MLP; the substrate approximates the MLP
with its gated form (parameter-count-comparable) and keeps LayerNorm
semantics via RMSNorm — noted in DESIGN.md. Decoder uses learned positions in
the original; we use RoPE uniformly across the zoo (substrate choice).
"""
from repro.configs.base import ATTN, CROSS, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                       # decoder layers (self + cross each)
    enc_layers=4,                     # encoder layers (bidirectional)
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    pattern=(LayerSpec(kind=ATTN, ffn=DENSE),),  # self-attn; cross added by encdec wrapper
    n_frontend_tokens=1500,           # whisper 30s -> 1500 frames
    qkv_bias=True,
    tie_embeddings=True,
    citation="arXiv:2212.04356 (Radford et al., Whisper)",
    sub_quadratic=False,              # full-attention decoder
    decode_capable=True,              # enc-dec: decoder decodes
)
