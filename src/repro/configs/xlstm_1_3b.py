"""xlstm-1.3b [ssm]: 48 blocks, d_model=2048, 4H (kv=4), d_ff=0 (blocks carry
internal projections), vocab=50304. sLSTM + mLSTM at the paper's 7:1 ratio:
pattern = 7x mLSTM + 1x sLSTM, x6 groups = 48. [arXiv:2405.04517]
"""
from repro.configs.base import MLSTM, NONE, SLSTM, LayerSpec, ModelConfig

_M = LayerSpec(kind=MLSTM, ffn=NONE)
_S = LayerSpec(kind=SLSTM, ffn=NONE)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="decoder",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    conv1d_width=4,
    tie_embeddings=True,
    citation="arXiv:2405.04517 (xLSTM)",
    sub_quadratic=True,   # pure recurrence -> O(1) state decode
)
