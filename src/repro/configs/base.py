"""Config schema for assigned architectures.

Every architecture in the public pool is described by a ModelConfig: a
repeating ``pattern`` of LayerSpec entries (scanned as stacked groups by the
transformer substrate) plus global dims. ``reduced()`` yields the smoke-test
variant mandated by the task (<=2 pattern repeats, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Layer / model schema
# ---------------------------------------------------------------------------

# Layer kinds
ATTN = "attn"          # self-attention (global or sliding-window)
CROSS = "cross"        # cross-attention (vlm / enc-dec decoder)
RGLRU = "rglru"        # RG-LRU recurrent block (recurrentgemma)
MLSTM = "mlstm"        # matrix-LSTM block (xlstm)
SLSTM = "slstm"        # scalar-LSTM block (xlstm)

# FFN kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"          # block carries its own internal projections (xlstm)


@dataclass(frozen=True)
class LayerSpec:
    """One layer in the repeating pattern."""
    kind: str = ATTN
    window: Optional[int] = None       # sliding-window size; None = global attention
    ffn: str = DENSE

    def __post_init__(self):
        assert self.kind in (ATTN, CROSS, RGLRU, MLSTM, SLSTM), self.kind
        assert self.ffn in (DENSE, MOE, NONE), self.ffn


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # "decoder" | "encdec" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int                    # logical vocab (loss is masked to this)
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim (fine-grained MoE)
    router_aux_coef: float = 0.01
    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"                  # gate activation for the gated MLP
    logit_softcap: float = 0.0         # gemma-style final-logit softcap (0 = off)
    # --- enc-dec / vlm frontends (stubbed modality encoders) ---
    n_frontend_tokens: int = 0         # audio frames / image patch tokens
    enc_layers: int = 0                # whisper encoder depth
    # --- recurrent block dims ---
    rglru_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4              # temporal conv inside recurrent block
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # --- lora (the paper's technique) ---
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_targets: tuple[str, ...] = ("wq", "wv")
    # --- bookkeeping ---
    citation: str = ""
    sub_quadratic: bool = False        # eligible for long_500k decode
    decode_capable: bool = True        # encoder-only archs would be False

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.family in ("decoder", "encdec", "vlm"), self.family

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Physical vocab, padded for shardability over the model axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_len(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def tail_pattern(self) -> tuple[LayerSpec, ...]:
        return self.pattern[: self.tail_len]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Approximate dense-equivalent parameter count N (for 6ND)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        return _count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 pattern repeats, d_model<=512, <=4 experts."""
        n_heads = min(self.n_heads, 4)
        hd = min(self.hd, 64)
        d_model = min(self.d_model, 256)
        # keep head structure consistent
        n_kv = min(self.n_kv_heads, n_heads)
        pat_len = len(self.pattern)
        n_layers = pat_len if pat_len >= 2 else 2
        n_layers = min(n_layers, 8)  # recurrentgemma pattern=3 -> 3 layers etc.
        return replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            enc_layers=min(self.enc_layers, 2),
            rglru_width=min(self.rglru_width, d_model) if self.rglru_width else 0,
            lora_rank=4,
            # shrink windows so local attention is exercised at tiny seq
            pattern=tuple(
                replace(ls, window=min(ls.window, 8) if ls.window else None)
                for ls in self.pattern
            ),
        )


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.hd
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    total = cfg.vocab_padded * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_padded * d

    def layer_params(spec: LayerSpec) -> int:
        p = 0
        if spec.kind in (ATTN, CROSS):
            p += d * q_dim + 2 * d * kv_dim + q_dim * d  # wq wk wv wo
        elif spec.kind == RGLRU:
            w = cfg.rglru_width or d
            p += 2 * d * w + w * d        # in-proj(x2 branches) + out-proj
            p += cfg.conv1d_width * w + 2 * w  # conv + gates (diagonal-ish)
        elif spec.kind == MLSTM:
            inner = int(d * cfg.mlstm_proj_factor)
            p += 2 * d * inner + inner * d + 3 * inner * (inner // max(cfg.n_heads, 1))
        elif spec.kind == SLSTM:
            p += 4 * d * d + int(d * cfg.slstm_proj_factor) * d * 2
        if spec.ffn == DENSE:
            p += 3 * d * cfg.d_ff
        elif spec.ffn == MOE:
            e_ff = cfg.moe_d_ff or cfg.d_ff
            n_e = cfg.top_k + cfg.n_shared_experts if active_only else (
                cfg.n_experts + cfg.n_shared_experts)
            p += 3 * d * e_ff * n_e + d * cfg.n_experts  # experts + router
        p += 2 * d  # norms
        return p

    groups = cfg.n_groups
    for spec in cfg.pattern:
        total += groups * layer_params(spec)
    for spec in cfg.tail_pattern:
        total += layer_params(spec)
    # whisper encoder
    for _ in range(cfg.enc_layers):
        total += d * q_dim * 2 + 2 * d * kv_dim + 3 * d * cfg.d_ff + 2 * d
    return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "granite-34b": "granite_34b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-7b": "qwen2_7b",
    "gemma3-1b": "gemma3_1b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-1.3b": "xlstm_1_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
