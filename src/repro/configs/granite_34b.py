"""granite-34b [dense]: 88L, d_model=6144, 48H (GQA kv=1 / MQA), d_ff=24576,
vocab=49152. Llama-style code model. [arXiv:2405.04324]
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="decoder",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    pattern=(LayerSpec(kind=ATTN, window=None, ffn=DENSE),),
    rope_theta=10000.0,
    tie_embeddings=False,
    citation="arXiv:2405.04324 (Granite Code Models)",
    sub_quadratic=False,
)
