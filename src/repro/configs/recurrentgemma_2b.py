"""recurrentgemma-2b [hybrid]: 26L, d_model=2560, 10H (GQA kv=1), d_ff=7680,
vocab=256000. Griffin-style: (RG-LRU, RG-LRU, local-attn) 1:2 ratio,
window 2048. 26 = 8x3 + 2 -> 8 scanned groups + (RG-LRU, RG-LRU) tail.
[arXiv:2402.19427]
"""
from repro.configs.base import ATTN, DENSE, RGLRU, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="decoder",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=(
        LayerSpec(kind=RGLRU, ffn=DENSE),
        LayerSpec(kind=RGLRU, ffn=DENSE),
        LayerSpec(kind=ATTN, window=2048, ffn=DENSE),
    ),
    rglru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
    logit_softcap=30.0,
    citation="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    sub_quadratic=True,   # recurrence + windowed attention -> long_500k runs
)
