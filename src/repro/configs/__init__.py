from repro.configs.base import (ARCH_IDS, ModelConfig, LayerSpec, all_configs,
                                get_config)
from repro.configs.shapes import (SHAPE_IDS, SHAPES, InputShape,
                                  shape_applicable)

__all__ = [
    "ARCH_IDS", "ModelConfig", "LayerSpec", "all_configs", "get_config",
    "SHAPE_IDS", "SHAPES", "InputShape", "shape_applicable",
]
