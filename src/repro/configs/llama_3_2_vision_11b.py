"""llama-3.2-vision-11b [vlm]: 40L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=128256. Cross-attention image layers every 5th layer: pattern
(self x4, cross) x8 = 40. Vision encoder/projector is a stub — input_specs()
supplies 1601 projected patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ATTN, CROSS, DENSE, LayerSpec, ModelConfig

_SELF = LayerSpec(kind=ATTN, window=None, ffn=DENSE)
_CROSS = LayerSpec(kind=CROSS, ffn=DENSE)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS),
    n_frontend_tokens=1601,           # 1 tile x (40x40+1) patches
    rope_theta=500000.0,
    tie_embeddings=False,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    sub_quadratic=False,
)
