"""mixtral-8x22b [moe]: 56L, d_model=6144, 48H (GQA kv=8), expert d_ff=16384,
vocab=32768. 8 experts top-2, sliding-window attention (w=4096).
[arXiv:2401.04088]
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="decoder",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec(kind=ATTN, window=4096, ffn=MOE),),
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=16384,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    citation="arXiv:2401.04088 (Mixtral of Experts)",
    sub_quadratic=True,   # SWA rolling cache on every layer -> long_500k runs
)
