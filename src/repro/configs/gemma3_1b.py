"""gemma3-1b [dense]: 26L, d_model=1152, 4H (GQA kv=1), d_ff=6912,
vocab=262144. 5:1 local:global attention, local window 512, 128k-capable via
sliding windows. 26 = 4x6 + 2 -> 4 scanned (local x5, global) groups +
(local, local) tail. [hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind=ATTN, window=512, ffn=DENSE)
_GLOBAL = LayerSpec(kind=ATTN, window=None, ffn=DENSE)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="decoder",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:google/gemma-3-1b-pt",
    # mostly sliding-window; the few global layers keep an MQA cache whose
    # decode cost is linear in cache length -> long_500k is runnable.
    sub_quadratic=True,
)
