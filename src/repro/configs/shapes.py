"""The four assigned input shapes.

train_4k lowers the paper's DFL round (local LoRA steps + joint gossip
mixing); prefill/decode shapes lower serving steps. ``long_500k`` requires a
sub-quadratic architecture (cfg.sub_quadratic).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
SHAPE_IDS = tuple(SHAPES)


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Return (applicable, reason-if-not) for an (arch, shape) pair."""
    if shape.kind == "decode" and not cfg.decode_capable:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention architecture; 500k decode needs a "
                       "sub-quadratic (sliding-window / recurrent) variant")
    return True, ""
