"""moonshot-v1-16b-a3b [moe]: 48L, d_model=2048, 16H (GQA kv=16), expert
d_ff=1408, vocab=163840, MoE 64 experts top-6 (+2 shared, deepseek-v3-style
fine-grained MoE per the Moonlight card). [hf:moonshotai/Moonlight-16B-A3B]

Assignment spec lists uniform d_ff=1408 (expert width); we follow it for all
layers (the real model's dense first layer is noted in DESIGN.md).
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="decoder",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    pattern=(LayerSpec(kind=ATTN, window=None, ffn=MOE),),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    rope_theta=50000.0,
    tie_embeddings=True,
    citation="hf:moonshotai/Moonlight-16B-A3B",
    sub_quadratic=False,
)
