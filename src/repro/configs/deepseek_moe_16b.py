"""deepseek-moe-16b [moe]: 28L, d_model=2048, 16H (kv=16), expert d_ff=1408,
vocab=102400. Fine-grained MoE: 2 shared + 64 routed experts, top-6.
[arXiv:2401.06066]

Assignment spec lists all layers MoE with d_ff=1408; the real model's dense
layer-0 FFN is noted in DESIGN.md.
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="decoder",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    pattern=(LayerSpec(kind=ATTN, window=None, ffn=MOE),),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    rope_theta=10000.0,
    tie_embeddings=False,
    citation="arXiv:2401.06066 (DeepSeekMoE)",
    sub_quadratic=False,
)
