from repro.data.synthetic import (SyntheticTask, federated_batches,
                                  label_skew_partitions, lm_token_stream,
                                  make_task)

__all__ = ["SyntheticTask", "federated_batches", "label_skew_partitions",
           "lm_token_stream", "make_task"]
