from repro.data.partition import (PARTITIONERS, client_label_distributions,
                                  dirichlet_partition, domain_partition,
                                  iid_partition, label_skew, make_partition,
                                  paper_partition, quantity_skew_partition)
from repro.data.shards import (ShardSet, write_paper_task_shards,
                               write_shards)
from repro.data.stream import FederatedStream
from repro.data.synthetic import (SyntheticTask, federated_batches,
                                  label_skew_partitions, lm_token_stream,
                                  make_task)

__all__ = ["SyntheticTask", "federated_batches", "label_skew_partitions",
           "lm_token_stream", "make_task",
           "ShardSet", "write_shards", "write_paper_task_shards",
           "FederatedStream",
           "PARTITIONERS", "make_partition", "iid_partition",
           "dirichlet_partition", "quantity_skew_partition",
           "domain_partition", "paper_partition",
           "client_label_distributions", "label_skew"]
