"""Synthetic federated data pipeline (offline container — no GLUE download).

Paper §VI-A partitions 10 clients with label skew:
  binary tasks: 3×[0.9,0.1], 3×[0.1,0.9], 4×[0.5,0.5]
  MNLI (3-way): 4×[0.9,0.05,0.05], 3×[0.05,0.9,0.05], 3×[0.05,0.05,0.9]

We reproduce exactly those client label distributions over a synthetic
sequence-classification task whose labels are *learnable from token
statistics*: each class owns a set of "signal" tokens; a sequence of class c
mixes signal tokens of class c with shared noise tokens. Difficulty is
controlled by signal_rate. This keeps the FL dynamics (heterogeneity,
cross-client interference) faithful while being runnable on CPU.

Also provides an LM token-stream pipeline for the end-to-end LM example.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

PAPER_PARTITION_BINARY = [[0.9, 0.1]] * 3 + [[0.1, 0.9]] * 3 + [[0.5, 0.5]] * 4
PAPER_PARTITION_MNLI = ([[0.9, 0.05, 0.05]] * 4 + [[0.05, 0.9, 0.05]] * 3 +
                        [[0.05, 0.05, 0.9]] * 3)


def label_skew_partitions(n_classes: int, n_clients: int = 10, *,
                          seed: int = 0, alpha: float = 0.15) -> np.ndarray:
    """The paper's client label distributions (rows: clients).

    The (2, 10) and (3, 10) shapes are the hard-coded §VI-A tables.
    Every other shape falls back to a *seeded* Dirichlet(alpha) draw per
    client, rotated so client i's heaviest expected class is i mod
    n_classes (the same 1/n_classes-of-clients-per-class structure as
    the paper rows). Same (seed, alpha) -> identical matrix; the
    regression test in tests/test_data.py pins the default draw.
    """
    if n_classes == 2 and n_clients == 10:
        return np.array(PAPER_PARTITION_BINARY)
    if n_classes == 3 and n_clients == 10:
        return np.array(PAPER_PARTITION_MNLI)
    if alpha <= 0:
        raise ValueError("alpha must be positive")
    rng = np.random.default_rng((int(seed), n_classes, n_clients))
    conc = np.full(n_classes, float(alpha))
    probs = np.empty((n_clients, n_classes))
    for i in range(n_clients):
        row = np.sort(rng.dirichlet(conc))[::-1]      # heaviest first
        order = np.roll(np.arange(n_classes), -(i % n_classes))
        probs[i, order] = row
    return probs / probs.sum(1, keepdims=True)


@dataclass
class SyntheticTask:
    name: str
    n_classes: int
    vocab_size: int = 512
    seq_len: int = 16
    signal_rate: float = 0.3
    n_signal_tokens: int = 8
    seed: int = 0
    feature_shift: int = 0   # per-client signal-dialect size (0 = IID feats)
    _signal: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # disjoint signal token sets per class (ids in upper vocab range)
        pool = rng.permutation(self.vocab_size // 2) + self.vocab_size // 2
        self._signal = pool[: self.n_classes * self.n_signal_tokens].reshape(
            self.n_classes, self.n_signal_tokens)

    def sample(self, labels: np.ndarray, rng: np.random.Generator,
               client: Optional[int] = None):
        """labels: (n,) -> tokens (n, seq_len) int32.

        With ``feature_shift`` > 0 and a ``client`` id, each client
        expresses a class through its own sub-dialect of the class's
        signal tokens — per-client feature heterogeneity on top of label
        skew. This makes the clients' LoRA subspaces genuinely conflict,
        which is where the paper's bilinear cross-term bites."""
        n = len(labels)
        toks = rng.integers(0, self.vocab_size // 2,
                            size=(n, self.seq_len))
        sig_mask = rng.random((n, self.seq_len)) < self.signal_rate
        if self.feature_shift and client is not None:
            k = min(self.feature_shift, self.n_signal_tokens)
            offs = (client * k + rng.integers(0, k, size=(n, self.seq_len))
                    ) % self.n_signal_tokens
            sig_toks = self._signal[labels[:, None], offs]
        else:
            sig_idx = rng.integers(0, self.n_signal_tokens,
                                   size=(n, self.seq_len))
            sig_toks = self._signal[labels[:, None], sig_idx]
        return np.where(sig_mask, sig_toks, toks).astype(np.int32)


def make_task(name: str, seed: int = 0, **kw) -> SyntheticTask:
    """Proxies for the paper's four GLUE tasks (binary except MNLI)."""
    presets = {
        "sst2": dict(n_classes=2, signal_rate=0.30),
        "qqp": dict(n_classes=2, signal_rate=0.22),
        "qnli": dict(n_classes=2, signal_rate=0.26),
        "mnli": dict(n_classes=3, signal_rate=0.22),
    }
    if name not in presets:
        raise KeyError(f"unknown task {name!r}; known: {list(presets)}")
    return SyntheticTask(name=name, seed=seed, **{**presets[name], **kw})


def federated_batches(task: SyntheticTask, partitions: np.ndarray,
                      batch_size: int, local_steps: int,
                      rounds: int, seed: int = 0
                      ) -> Iterator[dict]:
    """Yields one round's batch: tokens (local_steps, m, b, S),
    labels (local_steps, m, b) — leading scan axis for the DFL round."""
    m = partitions.shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        toks = np.empty((local_steps, m, batch_size, task.seq_len), np.int32)
        labs = np.empty((local_steps, m, batch_size), np.int32)
        for i in range(m):
            lab = rng.choice(task.n_classes,
                             size=(local_steps, batch_size),
                             p=partitions[i])
            labs[:, i] = lab
            toks[:, i] = task.sample(lab.reshape(-1), rng,
                                     client=i).reshape(
                local_steps, batch_size, task.seq_len)
        yield {"tokens": toks, "labels": labs}


def eval_batch(task: SyntheticTask, n: int, seed: int = 10_000) -> dict:
    """IID balanced test set (the paper evaluates on the task's test split)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, task.n_classes, size=n)
    return {"tokens": task.sample(labels, rng),
            "labels": labels.astype(np.int32)}


def lm_token_stream(vocab_size: int, batch: int, seq_len: int, *,
                    n_clients: Optional[int] = None, seed: int = 0
                    ) -> Iterator[dict]:
    """Markov-chain synthetic LM stream (for the end-to-end LM example);
    with n_clients, each client gets a different transition matrix
    (non-IID)."""
    rng = np.random.default_rng(seed)
    shape = (n_clients, batch) if n_clients else (batch,)

    def chain_step(cur, bias):
        # next token = (cur * 31 + bias + noise) % vocab : cheap structure
        noise = rng.integers(0, 7, size=cur.shape)
        return (cur * 31 + bias + noise) % vocab_size

    biases = rng.integers(0, vocab_size, size=shape[0] if n_clients else 1)
    while True:
        cur = rng.integers(0, vocab_size, size=shape)
        toks = [cur]
        for _ in range(seq_len):
            b = biases[:, None] if n_clients else biases
            cur = chain_step(cur, b)
            toks.append(cur)
        arr = np.stack(toks, axis=-1).astype(np.int32)
        yield {"tokens": arr[..., :-1], "targets": arr[..., 1:]}
