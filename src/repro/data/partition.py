"""Pluggable non-IID partitioners: shard rows -> per-client index sets.

Paper §VI-A evaluates TAD-LoRA under heterogeneous client data; this
module is where that heterogeneity is manufactured. A *partitioner* maps
a split's per-row metadata (labels, domains) to `n_clients` disjoint
index arrays, one per client, which `repro.data.stream.FederatedStream`
then iterates per-client epochs over.

Every partitioner obeys three invariants the property tier enforces
(`tests/test_property.py`):

  * deterministic — same (inputs, seed) -> identical partition,
  * total — the client index sets are disjoint and cover a subset of
    rows with every client receiving >= 1 sample,
  * parameterized skew — the knob that controls heterogeneity moves the
    measured skew monotonically (Dirichlet ``alpha`` down => label
    distributions drift apart).

Registry::

    "paper"      hard-coded §VI-A label-skew rows (via
                 repro.data.synthetic.label_skew_partitions), rows
                 realized by sampling without replacement
    "dirichlet"  label-skew Dirichlet(alpha) per client (FedML idiom)
    "quantity"   quantity skew: IID labels, Dirichlet(alpha) sizes
    "domain"     per-client domain shift: shard `domains` ids dealt
                 round-robin to clients
    "iid"        uniform shuffle split (control)
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import numpy as np

Partition = Tuple[np.ndarray, ...]


def _as_labels(labels) -> np.ndarray:
    lab = np.asarray(labels, np.int64).ravel()
    if lab.size == 0:
        raise ValueError("cannot partition an empty split")
    return lab


def _ensure_nonempty(parts, rng: np.random.Generator) -> Partition:
    """Give every empty client one row stolen from the largest client —
    the 'every client trains' invariant the round loop assumes (an empty
    client would make its fixed-shape batch undefined)."""
    parts = [np.asarray(p, np.int64) for p in parts]
    for i, p in enumerate(parts):
        if len(p) == 0:
            donor = int(np.argmax([len(q) for q in parts]))
            if len(parts[donor]) <= 1:
                raise ValueError("fewer rows than clients — cannot give "
                                 "every client a sample")
            k = int(rng.integers(0, len(parts[donor])))
            parts[i] = parts[donor][k:k + 1]
            parts[donor] = np.delete(parts[donor], k)
    return tuple(parts)


def iid_partition(labels, n_clients: int, *, seed: int = 0) -> Partition:
    """Uniform shuffle split — the homogeneous control."""
    lab = _as_labels(labels)
    rng = np.random.default_rng((int(seed), 0xD1D))
    perm = rng.permutation(len(lab))
    return _ensure_nonempty(np.array_split(perm, n_clients), rng)


def dirichlet_partition(labels, n_clients: int, *, alpha: float = 0.5,
                        seed: int = 0) -> Partition:
    """Label-skew Dirichlet: for each class, split its rows across
    clients by a Dirichlet(alpha) draw. Small alpha -> each class
    concentrates on few clients (strong skew); large alpha -> IID."""
    if alpha <= 0:
        raise ValueError("alpha must be positive")
    lab = _as_labels(labels)
    rng = np.random.default_rng((int(seed), 0xD12))
    out = [[] for _ in range(n_clients)]
    for c in np.unique(lab):
        idx = rng.permutation(np.flatnonzero(lab == c))
        props = rng.dirichlet(np.full(n_clients, float(alpha)))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(np.int64)
        for i, chunk in enumerate(np.split(idx, cuts)):
            out[i].append(chunk)
    parts = [np.sort(np.concatenate(p)) if p else np.empty(0, np.int64)
             for p in out]
    return _ensure_nonempty(parts, rng)


def quantity_skew_partition(labels, n_clients: int, *, alpha: float = 0.5,
                            seed: int = 0) -> Partition:
    """Quantity skew: labels stay IID per client but client dataset
    *sizes* follow Dirichlet(alpha) — some clients are data-rich, some
    data-poor (every client keeps >= 1 row)."""
    if alpha <= 0:
        raise ValueError("alpha must be positive")
    lab = _as_labels(labels)
    rng = np.random.default_rng((int(seed), 0xD13))
    perm = rng.permutation(len(lab))
    props = rng.dirichlet(np.full(n_clients, float(alpha)))
    cuts = (np.cumsum(props)[:-1] * len(lab)).astype(np.int64)
    return _ensure_nonempty(np.split(perm, cuts), rng)


def domain_partition(labels, n_clients: int, *, domains=None,
                     seed: int = 0) -> Partition:
    """Per-client domain shift: each distinct domain id is dealt to one
    client (round-robin in sorted-id order after a seeded shuffle of the
    deal). With exactly `n_clients` domains — the layout
    `write_paper_task_shards` produces — client i recovers domain π(i)
    whole, i.e. a full feature-dialect per client."""
    lab = _as_labels(labels)
    if domains is None:
        raise ValueError("domain partitioner needs per-row `domains` "
                         "(shard sets store them; see ShardSet.domains)")
    dom = np.asarray(domains, np.int64).ravel()
    if dom.shape != lab.shape:
        raise ValueError("domains must align with labels")
    ids = np.unique(dom[dom >= 0])
    if len(ids) == 0:
        raise ValueError("split has no domain ids (all -1) — use a "
                         "label-based partitioner instead")
    rng = np.random.default_rng((int(seed), 0xD14))
    order = rng.permutation(len(ids))
    out = [[] for _ in range(n_clients)]
    for k, j in enumerate(order):
        out[k % n_clients].append(np.flatnonzero(dom == ids[j]))
    parts = [np.sort(np.concatenate(p)) if p else np.empty(0, np.int64)
             for p in out]
    return _ensure_nonempty(parts, rng)


def paper_partition(labels, n_clients: int, *, seed: int = 0) -> Partition:
    """The §VI-A hard-coded label-skew rows, realized on real rows: each
    client draws (without replacement) a class mix matching its
    `label_skew_partitions` row as closely as the split allows."""
    from repro.data.synthetic import label_skew_partitions

    lab = _as_labels(labels)
    n_classes = int(lab.max()) + 1
    rows = label_skew_partitions(n_classes, n_clients)
    rng = np.random.default_rng((int(seed), 0xD15))
    pools = {c: list(rng.permutation(np.flatnonzero(lab == c)))
             for c in range(n_classes)}
    per_client = len(lab) // n_clients
    out = []
    for i in range(n_clients):
        want = np.floor(rows[i] * per_client).astype(np.int64)
        take = []
        for c in range(n_classes):
            got = [pools[c].pop() for _ in range(min(want[c],
                                                     len(pools[c])))]
            take.extend(got)
        # top up from whatever classes still have rows, largest-need first
        while len(take) < per_client:
            c = max(pools, key=lambda c: len(pools[c]))
            if not pools[c]:
                break
            take.append(pools[c].pop())
        out.append(np.sort(np.asarray(take, np.int64)))
    return _ensure_nonempty(out, rng)


PARTITIONERS: Dict[str, Callable[..., Partition]] = {
    "iid": iid_partition,
    "dirichlet": dirichlet_partition,
    "quantity": quantity_skew_partition,
    "domain": domain_partition,
    "paper": paper_partition,
}


def make_partition(name: str, labels, n_clients: int, *, seed: int = 0,
                   domains=None, **kw) -> Partition:
    """Dispatch by registry name. `domains` is forwarded only to the
    domain partitioner; unknown kwargs raise (same contract as
    `repro.scenarios.schedule_from_config`)."""
    if name not in PARTITIONERS:
        raise ValueError(f"unknown partitioner {name!r}; known: "
                         f"{sorted(PARTITIONERS)}")
    if name == "domain":
        kw = dict(kw, domains=domains)
    try:
        return PARTITIONERS[name](labels, n_clients, seed=seed, **kw)
    except TypeError as e:
        raise ValueError(f"bad partitioner_kw for {name!r}: {e}") from e


def client_label_distributions(parts: Sequence[np.ndarray], labels,
                               n_classes: int) -> np.ndarray:
    """(n_clients, n_classes) empirical label distribution per client —
    the quantity the skew-monotonicity property is measured on."""
    lab = _as_labels(labels)
    out = np.zeros((len(parts), n_classes))
    for i, p in enumerate(parts):
        out[i] = np.bincount(lab[p], minlength=n_classes)[:n_classes]
        out[i] /= max(1, len(p))
    return out


def label_skew(parts: Sequence[np.ndarray], labels,
               n_classes: int) -> float:
    """Mean total-variation distance of client label distributions from
    the global mix — 0 for IID, -> 1 as clients specialize."""
    dist = client_label_distributions(parts, labels, n_classes)
    lab = _as_labels(labels)
    global_mix = np.bincount(lab, minlength=n_classes)[:n_classes] / len(lab)
    return float(np.mean(np.abs(dist - global_mix).sum(1) / 2.0))
