"""FederatedStream — fixed-shape, optionally prefetching batch iterator.

The round loop consumes one dict per round::

    {"tokens": (local_steps, m, batch, S) int32,
     "labels": (local_steps, m, batch)    int32}

exactly the shape `repro.data.synthetic.federated_batches` yields, so
`Session` swaps sources without recompiling — shard boundaries, epoch
boundaries and client dataset sizes never reach the compiled round.

Determinism contract (the whole point of this module):
`round_batch(t)` is a **pure function of the round index** — client i's
sample sequence is the concatenation of per-epoch permutations seeded
``(seed, client, epoch)``, and round t reads positions
``[t*local_steps*batch, (t+1)*...)`` of it. Consequences the test tier
pins down:

  * checkpoint/restore replays bit-for-bit: restoring to round t is
    `seek(t)`, O(1), no RNG state to serialize (`tests/test_data.py`),
  * process grids are invariant: every `ClusterSession` process draws
    the identical full batch and ships its own client block, so 1p/2p/4p
    grids see the same global batch order (`tests/test_multihost.py`),
  * the prefetch thread cannot skew anything — it only computes
    `round_batch(t+1)` early, it never owns state.

Prefetch is opt-in (``prefetch=1``); a closed stream joins its worker.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Optional, Sequence

import numpy as np

from repro.data.shards import ShardSet


class FederatedStream:
    """Iterator of round batches over (ShardSet, partition).

    Also usable as a plain iterator (`next(stream)`) — that path walks
    an internal round counter that `seek` repositions in O(1).
    """

    def __init__(self, shards: ShardSet, parts: Sequence[np.ndarray], *,
                 batch: int, local_steps: int, seed: int = 0,
                 split: str = "train", prefetch: int = 0):
        self.shards = shards
        self.parts = tuple(np.asarray(p, np.int64) for p in parts)
        if any(len(p) == 0 for p in self.parts):
            raise ValueError("every client needs >= 1 row (see "
                             "repro.data.partition._ensure_nonempty)")
        self.n_clients = len(self.parts)
        self.batch = int(batch)
        self.local_steps = int(local_steps)
        self.seed = int(seed)
        self.split = split
        self._t = 0
        self._per_round = self.batch * self.local_steps
        self._worker: Optional[_Prefetcher] = None
        if prefetch:
            self._worker = _Prefetcher(self, depth=int(prefetch))

    # -- pure index math ----------------------------------------------------
    def _epoch_perm(self, client: int, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, int(client), int(epoch)))
        return rng.permutation(len(self.parts[client]))

    def client_rows(self, client: int, t: int) -> np.ndarray:
        """Global row indices client `client` trains on in round t —
        positions [t*ls*b, (t+1)*ls*b) of its infinite epoch-permutation
        stream, mapped through its partition."""
        n = len(self.parts[client])
        lo = t * self._per_round
        hi = lo + self._per_round
        local = np.empty(self._per_round, np.int64)
        out = 0
        for epoch in range(lo // n, (hi - 1) // n + 1):
            a = max(lo, epoch * n) - epoch * n
            b = min(hi, (epoch + 1) * n) - epoch * n
            local[out:out + (b - a)] = self._epoch_perm(client, epoch)[a:b]
            out += b - a
        return self.parts[client][local]

    def round_batch(self, t: int) -> Dict[str, np.ndarray]:
        """The full round-t batch, identical on every caller."""
        if t < 0:
            raise ValueError("round index must be >= 0")
        idx = np.stack([self.client_rows(i, t)
                        for i in range(self.n_clients)])   # (m, ls*b)
        flat = self.shards.read(self.split, idx.ravel())
        S = self.shards.seq_len
        m, ls, b = self.n_clients, self.local_steps, self.batch
        toks = flat["tokens"].reshape(m, ls, b, S).transpose(1, 0, 2, 3)
        labs = flat["labels"].reshape(m, ls, b).transpose(1, 0, 2)
        return {"tokens": np.ascontiguousarray(toks),
                "labels": np.ascontiguousarray(labs)}

    # -- iterator / lifecycle ----------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        t = self._t
        self._t = t + 1
        if self._worker is not None:
            return self._worker.get(t)
        return self.round_batch(t)

    def seek(self, t: int) -> None:
        """Reposition to round t in O(1) — restore never replays data."""
        if t < 0:
            raise ValueError("round index must be >= 0")
        self._t = int(t)
        if self._worker is not None:
            self._worker.flush(self._t)

    @property
    def round(self) -> int:
        return self._t

    def close(self) -> None:
        """Join the prefetch worker (no-op without one). Idempotent."""
        if self._worker is not None:
            self._worker.close()
            self._worker = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _Prefetcher:
    """Bounded-queue worker computing `round_batch(t)` ahead of the
    consumer. Because batches are pure functions of t, the worker holds
    no stream state — `flush` after a seek just restarts it at the new
    position."""

    def __init__(self, stream: FederatedStream, depth: int = 1):
        self._stream = stream
        self._depth = max(1, depth)
        self._q: queue.Queue = queue.Queue(maxsize=self._depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._start(stream.round)

    def _start(self, t0: int) -> None:
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, args=(t0,), daemon=True,
            name="repro-data-prefetch")
        self._thread.start()

    def _run(self, t: int) -> None:
        while not self._stop.is_set():
            item = (t, self._stream.round_batch(t))
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.05)
                    break
                except queue.Full:
                    continue
            t += 1

    def get(self, t: int):
        while True:
            got_t, batch = self._q.get()
            if got_t == t:
                return batch
            if got_t > t:           # consumer seeked backwards under us
                self.flush(t)

    def flush(self, t0: int) -> None:
        """Discard queued batches and restart the worker at round t0."""
        self._halt()
        self._q = queue.Queue(maxsize=self._depth)
        self._start(t0)

    def _halt(self) -> None:
        self._stop.set()
        if self._thread is not None:
            while True:             # drain so a blocked put() can exit
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=5.0)
            self._thread = None

    def close(self) -> None:
        self._halt()
