"""Tokenized shard sets — the on-disk format of the streaming data layer.

A *shard set* is a directory of fixed-schema npz shards plus a
``meta.json`` manifest:

    <path>/
      meta.json                  {"name", "n_classes", "vocab_size",
                                  "seq_len", "splits": {split: [[file, n],
                                  ...]}}
      train-00000.npz            tokens (n, S) int32, labels (n,) int32,
      train-00001.npz            domains (n,) int32 (−1 = no domain)
      val-00000.npz

The format is deliberately boring: flat numpy rows, no compression
tricks, every shard independently readable. What makes it a *streaming*
layer is the reader contract — `ShardSet.read` gathers arbitrary global
row indices across shard boundaries into one fixed-shape batch, so the
batch iterator (`repro.data.stream.FederatedStream`) never exposes shard
boundaries to the compiled round.

`domains` carries per-sample provenance (which client dialect / corpus
slice generated the row); the "domain" partitioner turns it into
per-client domain shift. Rows without provenance store −1.

Offline container note: there is no GLUE download here. MNLI-style shard
sets are *generated* from `repro.data.synthetic.SyntheticTask` at the
paper's client label distributions (`write_paper_task_shards`), keeping
the FL dynamics faithful while staying runnable anywhere.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

META_NAME = "meta.json"
_REQUIRED_KEYS = ("tokens", "labels")


class ShardSet:
    """Reader over a shard directory: metadata + cross-shard row gather.

    Loaded shards are cached (a shard set a stream touches every round
    stays resident); `read` is pure indexing, safe to call from a
    prefetch thread.
    """

    def __init__(self, path: str):
        self.path = os.path.abspath(path)
        meta_path = os.path.join(self.path, META_NAME)
        if not os.path.exists(meta_path):
            raise FileNotFoundError(
                f"shard set {path!r} has no {META_NAME} — not a shard "
                f"directory (write one with repro.data.shards.write_shards)")
        with open(meta_path) as f:
            meta = json.load(f)
        self.name: str = meta["name"]
        self.n_classes: int = int(meta["n_classes"])
        self.vocab_size: int = int(meta["vocab_size"])
        self.seq_len: int = int(meta["seq_len"])
        self.splits: Dict[str, List[Tuple[str, int]]] = {
            split: [(fn, int(n)) for fn, n in files]
            for split, files in meta["splits"].items()}
        self._cache: Dict[str, Dict[str, np.ndarray]] = {}
        # cumulative row offsets per split: shard k covers
        # [offsets[k], offsets[k+1])
        self._offsets = {
            split: np.concatenate([[0], np.cumsum([n for _, n in files])])
            for split, files in self.splits.items()}

    # -- metadata -----------------------------------------------------------
    def split_size(self, split: str = "train") -> int:
        self._check_split(split)
        return int(self._offsets[split][-1])

    def signature(self) -> str:
        """Stable 16-hex id of the manifest (build-cache material)."""
        blob = json.dumps({
            "name": self.name, "n_classes": self.n_classes,
            "vocab_size": self.vocab_size, "seq_len": self.seq_len,
            "splits": {k: [list(x) for x in v]
                       for k, v in sorted(self.splits.items())}},
            sort_keys=True)
        return hashlib.md5(blob.encode()).hexdigest()[:16]

    def _check_split(self, split: str) -> None:
        if split not in self.splits:
            raise KeyError(f"shard set {self.name!r} has no split "
                           f"{split!r}; known: {sorted(self.splits)}")

    # -- row access ---------------------------------------------------------
    def _shard(self, fn: str) -> Dict[str, np.ndarray]:
        if fn not in self._cache:
            with np.load(os.path.join(self.path, fn)) as z:
                self._cache[fn] = {k: z[k] for k in z.files}
            for k in _REQUIRED_KEYS:
                if k not in self._cache[fn]:
                    raise ValueError(f"shard {fn} missing array {k!r}")
        return self._cache[fn]

    def labels(self, split: str = "train") -> np.ndarray:
        """All labels of a split, in global row order (partitioners key
        off this; one pass, then cached via the shard cache)."""
        self._check_split(split)
        return np.concatenate([self._shard(fn)["labels"]
                               for fn, _ in self.splits[split]])

    def domains(self, split: str = "train") -> np.ndarray:
        """Per-sample domain ids (−1 where the shard has none)."""
        self._check_split(split)
        out = []
        for fn, n in self.splits[split]:
            sh = self._shard(fn)
            out.append(sh.get("domains",
                              np.full(n, -1, np.int32)))
        return np.concatenate(out)

    def read(self, split: str, indices: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather global row `indices` (any order, repeats allowed) across
        shard boundaries -> {"tokens": (n, S) int32, "labels": (n,) int32}.
        The output row order is exactly the input index order."""
        self._check_split(split)
        idx = np.asarray(indices, np.int64)
        total = self.split_size(split)
        if idx.size and (idx.min() < 0 or idx.max() >= total):
            raise IndexError(f"indices out of range for split {split!r} "
                             f"of {total} rows")
        offsets = self._offsets[split]
        files = self.splits[split]
        toks = np.empty((idx.size, self.seq_len), np.int32)
        labs = np.empty(idx.size, np.int32)
        shard_of = np.searchsorted(offsets, idx, side="right") - 1
        for k in np.unique(shard_of):
            sel = shard_of == k
            local = idx[sel] - offsets[k]
            sh = self._shard(files[k][0])
            toks[sel] = sh["tokens"][local]
            labs[sel] = sh["labels"][local]
        return {"tokens": toks, "labels": labs}

    # -- evaluation ---------------------------------------------------------
    def eval_batch(self, n: int, seed: int = 10_000,
                   split: str = "val") -> Dict[str, np.ndarray]:
        """Seeded class-balanced draw from the held-out split (the same
        protocol `repro.data.synthetic.eval_batch` implements for the
        synthetic task: the paper evaluates on the task's test split)."""
        self._check_split(split)
        labels = self.labels(split)
        rng = np.random.default_rng(seed)
        want = rng.integers(0, self.n_classes, size=n)
        pools = [np.flatnonzero(labels == c) for c in range(self.n_classes)]
        for c, pool in enumerate(pools):
            if len(pool) == 0:
                raise ValueError(f"split {split!r} has no samples of "
                                 f"class {c} — cannot draw a balanced "
                                 f"eval batch")
        idx = np.array([pools[c][rng.integers(0, len(pools[c]))]
                        for c in want], np.int64)
        return self.read(split, idx)


# ---------------------------------------------------------------------------
# writers
# ---------------------------------------------------------------------------

def write_shards(path: str, name: str, *, n_classes: int, vocab_size: int,
                 splits: Dict[str, Dict[str, np.ndarray]],
                 shard_size: int = 1024) -> ShardSet:
    """Write a shard set: `splits` maps split name -> {"tokens": (N, S),
    "labels": (N,), optional "domains": (N,)}. Rows are split into
    `shard_size`-row shards in order (the last shard is short)."""
    if shard_size <= 0:
        raise ValueError("shard_size must be positive")
    os.makedirs(path, exist_ok=True)
    manifest: Dict[str, list] = {}
    seq_len = None
    for split, arrays in splits.items():
        toks = np.asarray(arrays["tokens"], np.int32)
        labs = np.asarray(arrays["labels"], np.int32)
        if toks.ndim != 2 or len(toks) != len(labs):
            raise ValueError(f"split {split!r}: tokens must be (N, S) with "
                             f"labels (N,)")
        if seq_len is None:
            seq_len = toks.shape[1]
        elif toks.shape[1] != seq_len:
            raise ValueError("all splits must share seq_len")
        if labs.size and (labs.min() < 0 or labs.max() >= n_classes):
            raise ValueError(f"split {split!r}: labels outside "
                             f"[0, {n_classes})")
        if toks.size and toks.max() >= vocab_size:
            raise ValueError(f"split {split!r}: token ids exceed "
                             f"vocab_size={vocab_size}")
        doms = np.asarray(arrays.get("domains",
                                     np.full(len(labs), -1)), np.int32)
        manifest[split] = []
        for k, start in enumerate(range(0, len(labs), shard_size)):
            sl = slice(start, start + shard_size)
            fn = f"{split}-{k:05d}.npz"
            np.savez(os.path.join(path, fn), tokens=toks[sl],
                     labels=labs[sl], domains=doms[sl])
            manifest[split].append([fn, int(len(labs[sl]))])
    meta = {"name": name, "n_classes": int(n_classes),
            "vocab_size": int(vocab_size), "seq_len": int(seq_len or 0),
            "splits": manifest}
    with open(os.path.join(path, META_NAME), "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    return ShardSet(path)


def write_paper_task_shards(path: str, task_name: str, *,
                            n_clients: int = 10, n_per_client: int = 400,
                            n_val: int = 1024, shard_size: int = 1024,
                            seed: int = 0, vocab_size: Optional[int] = None,
                            feature_shift: int = 2,
                            partitions: Optional[Sequence] = None,
                            ) -> ShardSet:
    """Generate an MNLI-style shard set at the paper's §VI-A client label
    distributions from the synthetic task proxies.

    Each of the `n_clients` source domains contributes `n_per_client`
    rows drawn from its paper label-skew row, expressed through its own
    signal-token dialect (``feature_shift``) — `domains[k]` records the
    source. The "domain" partitioner then reproduces the paper's
    heterogeneous clients exactly; "dirichlet"/"quantity" re-partition
    the same corpus into other §VI-A regimes. The val split is IID,
    dialect-free (the paper evaluates on the task's test split)."""
    from repro.data.synthetic import label_skew_partitions, make_task

    task = make_task(task_name, seed=seed, feature_shift=feature_shift,
                     **({"vocab_size": vocab_size} if vocab_size else {}))
    parts = np.asarray(partitions) if partitions is not None else \
        label_skew_partitions(task.n_classes, n_clients)
    if parts.shape[0] != n_clients:
        raise ValueError(f"partitions rows {parts.shape[0]} != "
                         f"n_clients {n_clients}")
    rng = np.random.default_rng(seed + 1)
    toks, labs, doms = [], [], []
    for i in range(n_clients):
        lab = rng.choice(task.n_classes, size=n_per_client, p=parts[i])
        toks.append(task.sample(lab, rng, client=i))
        labs.append(lab.astype(np.int32))
        doms.append(np.full(n_per_client, i, np.int32))
    val_lab = rng.integers(0, task.n_classes, size=n_val)
    splits = {
        "train": {"tokens": np.concatenate(toks),
                  "labels": np.concatenate(labs),
                  "domains": np.concatenate(doms)},
        "val": {"tokens": task.sample(val_lab, rng),
                "labels": val_lab.astype(np.int32)},
    }
    return write_shards(path, task_name, n_classes=task.n_classes,
                        vocab_size=task.vocab_size, splits=splits,
                        shard_size=shard_size)
