"""Fused LoRA matmul Pallas kernel: y = x@W + scale·(x@a)@b.

The low-rank path rides along the MXU base-matmul tiles: for each (i, j)
output block we sweep K in bk-sized steps, accumulating BOTH the dense
partial product x_blk @ W_blk and the rank-r projection x_blk @ a_blk in
VMEM scratch; on the final K step the (bm, r) @ (r, bn) correction lands on
the accumulator. One HBM sweep over x instead of two (dense + adapter),
which is the hot-spot of LoRA fine-tuning at framework scale.

Block sizes default to MXU-aligned 128 multiples; rank r stays whole (it is
8–64, far below a VMEM tile).

`slot_lora_matmul` is the multi-adapter serving variant: the adapter tensors
carry a leading pool axis (N_adapters, ...) and every batch row selects its
adapter by a per-row slot id. The gather happens INSIDE the kernel via
scalar-prefetched block index maps (the id picks which adapter row the a/b
BlockSpecs DMA), so one compiled decode step serves heterogeneous adapters —
swapping an adapter or retargeting a slot never changes any traced shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compat


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scale: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    xb = x_ref[...]
    acc_ref[...] += jnp.dot(xb, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(xb, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        corr = jnp.dot(xa_ref[...], b_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * corr).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scale: float = 1.0, *, bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool = False) -> jax.Array:
    """x: (M, K), w: (K, N), a: (K, r), b: (r, N) -> (M, N)."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk

    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, a, b)


def _slot_kernel(slot_ref, x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref,
                 xa_ref, *, scale: float, nk: int):
    del slot_ref                      # consumed by the block index maps
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    xb = x_ref[...]                   # (1, bk) — one decode slot's row
    acc_ref[...] += jnp.dot(xb, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(xb, a_ref[0],
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        corr = jnp.dot(xa_ref[...], b_ref[0].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * corr).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bn", "bk",
                                             "interpret"))
def slot_lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                     slots: jax.Array, scale: float = 1.0, *, bn: int = 128,
                     bk: int = 128, interpret: bool = False) -> jax.Array:
    """Per-row adapter-indexed LoRA matmul (the multi-adapter decode step).

    x: (B, K), w: (K, N), a: (N_ad, K, r), b: (N_ad, r, N),
    slots: (B,) int32 adapter ids -> y[i] = x[i]@w + scale·(x[i]@a[s_i])@b[s_i].

    ``slots`` is a scalar-prefetch operand: the a/b index maps read it to DMA
    adapter row s_i for grid row i, so the gather costs one block choice, not
    a materialized (B, K, r) gather in HBM. Row blocks are bm=1 (decode B is
    the slot count, single tokens); the dense product still tiles (bk, bn)
    on the MXU.
    """
    B, K = x.shape
    N = w.shape[1]
    r = a.shape[2]
    bn, bk = min(bn, N), min(bk, K)
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    assert a.shape[1] == K and b.shape[1] == r and b.shape[2] == N, \
        (a.shape, b.shape)
    nk = K // bk

    grid = (B, N // bn, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, k, slots: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, slots: (k, j)),
            pl.BlockSpec((1, bk, r), lambda i, j, k, slots: (slots[i], k, 0)),
            pl.BlockSpec((1, r, bn), lambda i, j, k, slots: (slots[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, k, slots: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_slot_kernel, scale=scale, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(slots.astype(jnp.int32), x, w, a, b)
