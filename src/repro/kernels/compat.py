"""Pallas API compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both so the kernels import on every jax in the support window.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
