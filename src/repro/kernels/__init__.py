"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel has: the pallas_call implementation (<name>.py), a pure-jnp
oracle (ref.py), and a dispatching wrapper (ops.py) that falls back to the
oracle off-TPU. See DESIGN.md section 7 for the TPU-adaptation rationale.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gossip_mix import gossip_mix
from repro.kernels.lora_matmul import lora_matmul, slot_lora_matmul
from repro.kernels.rglru_scan import rglru_scan

__all__ = ["ops", "ref", "flash_attention", "gossip_mix", "lora_matmul",
           "slot_lora_matmul", "rglru_scan"]
