"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's contract exactly; tests sweep
shapes/dtypes and assert allclose between kernel (interpret=True on CPU,
compiled on TPU) and these references.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def lora_matmul_ref(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                    scale: float) -> jax.Array:
    """y = x @ w + scale * (x @ a) @ b.
    x: (M, K), w: (K, N), a: (K, r), b: (r, N)."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    y = y + scale * ((x.astype(jnp.float32) @ a.astype(jnp.float32))
                     @ b.astype(jnp.float32))
    return y.astype(x.dtype)


def slot_lora_matmul_ref(x: jax.Array, w: jax.Array, a: jax.Array,
                         b: jax.Array, slots: jax.Array,
                         scale: float) -> jax.Array:
    """y[i] = x[i] @ w + scale * (x[i] @ a[slots[i]]) @ b[slots[i]].
    x: (B, K), w: (K, N), a: (N_ad, K, r), b: (N_ad, r, N), slots: (B,).

    The per-row contractions mirror `models.layers.lora_linear`'s plain
    (x @ a) @ b order so a slot-served adapter reproduces the single-adapter
    decode path bit-for-bit at equal dtypes."""
    y = x @ w
    xa = jnp.einsum("bd,bdr->br", x, a[slots].astype(x.dtype))
    return y + jnp.einsum("br,brf->bf", xa,
                          b[slots].astype(x.dtype)) * scale


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Naive attention. q: (B, H, S, d), k/v: (B, H, L, d) (heads already
    expanded — GQA repeat happens in ops)."""
    B, H, S, d = q.shape
    L = k.shape[2]
    scores = jnp.einsum("bhsd,bhld->bhsl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(d))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(L)[None, :]
    mask = jnp.ones((S, L), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhsl,bhld->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attn_decode_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, table: jax.Array,
                          lengths: jax.Array) -> jax.Array:
    """Single-token decode attention over a paged KV cache.

    q: (B, 1, H, hd); k_pages/v_pages: (n_pages, page_size, KV, hd);
    table: (B, P) int32 logical->physical page map; lengths: (B,) valid
    context per row. Returns (B, 1, H, hd).

    Gathers each row's pages into the contiguous (B, L = P*page_size, KV,
    hd) view and then mirrors `models.attention._attend` LINE FOR LINE
    (same einsum strings, f32 casts, -1e30 masking, sqrt scale), so at
    identical cached values the paged path reproduces the contiguous
    decode path bit-for-bit — the serving-core correctness contract
    asserted by tests/test_paging.py."""
    B, Sq, H, hd = q.shape
    ps, n_kv = k_pages.shape[1], k_pages.shape[2]
    P = table.shape[1]
    L = P * ps
    k = k_pages[table].reshape(B, L, n_kv, hd)
    v = v_pages[table].reshape(B, L, n_kv, hd)
    mask = (jnp.arange(L)[None, :] < lengths[:, None])[:, None, None, None, :]
    G = H // n_kv
    qg = q.reshape(B, Sq, n_kv, G, hd)
    scores = jnp.einsum("bskgh,blkh->bkgsl", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def gossip_mix_ref(w_eff: jax.Array, x: jax.Array) -> jax.Array:
    """y = w_eff @ x. w_eff: (m, m) pre-masked mixing matrix
    (mask*W + (1-mask)*I); x: (m, P) stacked flattened client params."""
    return (w_eff.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)


def gossip_mix_seg_ref(w: jax.Array, x: jax.Array,
                       seg: jax.Array) -> jax.Array:
    """y = seg*(w@x) + (1-seg)*x — per-column-segment W_eff blend.
    w: (m, m) raw mixing matrix; x: (m, P); seg: (1, P) in [0, 1]."""
    x32 = x.astype(jnp.float32)
    y = w.astype(jnp.float32) @ x32
    s = seg.astype(jnp.float32)
    return (s * y + (1.0 - s) * x32).astype(x.dtype)


def gossip_mix_quant_ref(w_off: jax.Array, q: jax.Array, scale: jax.Array,
                         x: jax.Array, w_diag: jax.Array,
                         seg: jax.Array) -> jax.Array:
    """Compressed-gossip contraction, dequantize fused:
    y = seg·(w_diag·x + w_off @ (q·scale)) + (1−seg)·x.
    w_off: (r, m) mixing rows with the diagonal zeroed; q: (m, P) int8 or
    fp8 quantized source rows; scale: (m, 1) f32 per-row scales; x: (r, P)
    fresh full-precision rows; w_diag: (r, 1); seg: (1, P). Mirrors
    `gossip_mix._kernel_quant` operation for operation (same f32 casts,
    same contraction order) so the kernel-vs-ref check is bitwise."""
    z = q.astype(jnp.float32) * scale.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    y = w_diag.astype(jnp.float32) * x32 + w_off.astype(jnp.float32) @ z
    s = seg.astype(jnp.float32)
    return (s * y + (1.0 - s) * x32).astype(x.dtype)


def rglru_scan_ref(a: jax.Array, u: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + u_t (h_{-1}=0), along axis 1.
    a, u: (B, T, W) -> h: (B, T, W)."""
    def step(h, au):
        at, ut = au
        h = at * h + ut
        return h, h
    a32 = a.astype(jnp.float32)
    u32 = u.astype(jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a32, 1, 0),
                                    jnp.moveaxis(u32, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(u.dtype)
