"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's contract exactly; tests sweep
shapes/dtypes and assert allclose between kernel (interpret=True on CPU,
compiled on TPU) and these references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def lora_matmul_ref(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                    scale: float) -> jax.Array:
    """y = x @ w + scale * (x @ a) @ b.
    x: (M, K), w: (K, N), a: (K, r), b: (r, N)."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    y = y + scale * ((x.astype(jnp.float32) @ a.astype(jnp.float32))
                     @ b.astype(jnp.float32))
    return y.astype(x.dtype)


def slot_lora_matmul_ref(x: jax.Array, w: jax.Array, a: jax.Array,
                         b: jax.Array, slots: jax.Array,
                         scale: float) -> jax.Array:
    """y[i] = x[i] @ w + scale * (x[i] @ a[slots[i]]) @ b[slots[i]].
    x: (B, K), w: (K, N), a: (N_ad, K, r), b: (N_ad, r, N), slots: (B,).

    The per-row contractions mirror `models.layers.lora_linear`'s plain
    (x @ a) @ b order so a slot-served adapter reproduces the single-adapter
    decode path bit-for-bit at equal dtypes."""
    y = x @ w
    xa = jnp.einsum("bd,bdr->br", x, a[slots].astype(x.dtype))
    return y + jnp.einsum("br,brf->bf", xa,
                          b[slots].astype(x.dtype)) * scale


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Naive attention. q: (B, H, S, d), k/v: (B, H, L, d) (heads already
    expanded — GQA repeat happens in ops)."""
    B, H, S, d = q.shape
    L = k.shape[2]
    scores = jnp.einsum("bhsd,bhld->bhsl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(d))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(L)[None, :]
    mask = jnp.ones((S, L), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhsl,bhld->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gossip_mix_ref(w_eff: jax.Array, x: jax.Array) -> jax.Array:
    """y = w_eff @ x. w_eff: (m, m) pre-masked mixing matrix
    (mask*W + (1-mask)*I); x: (m, P) stacked flattened client params."""
    return (w_eff.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)


def gossip_mix_seg_ref(w: jax.Array, x: jax.Array,
                       seg: jax.Array) -> jax.Array:
    """y = seg*(w@x) + (1-seg)*x — per-column-segment W_eff blend.
    w: (m, m) raw mixing matrix; x: (m, P); seg: (1, P) in [0, 1]."""
    x32 = x.astype(jnp.float32)
    y = w.astype(jnp.float32) @ x32
    s = seg.astype(jnp.float32)
    return (s * y + (1.0 - s) * x32).astype(x.dtype)


def rglru_scan_ref(a: jax.Array, u: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + u_t (h_{-1}=0), along axis 1.
    a, u: (B, T, W) -> h: (B, T, W)."""
    def step(h, au):
        at, ut = au
        h = at * h + ut
        return h, h
    a32 = a.astype(jnp.float32)
    u32 = u.astype(jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a32, 1, 0),
                                    jnp.moveaxis(u32, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(u.dtype)
