"""Paged-attention decode Pallas kernel (flash-decode over KV pages).

One decode token per batch row attends over a KV cache stored as
fixed-size physical pages shared by all rows: ``k_pages``/``v_pages``
are ``(n_pages, page_size, KV, hd)`` and each row's block table maps its
logical page index to a physical page. The gather happens INSIDE the
kernel via scalar-prefetched block index maps (the same
`PrefetchScalarGridSpec` pattern as `slot_lora_matmul`): grid step
``(b, kv, p)`` DMAs physical page ``table[b, p]``, so page occupancy is
data — growing, shrinking, or remapping a row's pages never changes a
traced shape.

The page sweep is the classic online-softmax accumulation (running max
``m``, normalizer ``l``, unnormalized accumulator ``acc`` in VMEM
scratch, rescaled by ``exp(m_prev - m_new)`` each step, normalized on
the last page). Positions past ``lengths[b]`` mask to -1e30, matching
the masking constant of `models.attention._attend`; page 0 is the
serving core's null page, reachable only through masked-out entries of
an inactive row's table.

Numerics: online softmax reassociates the reduction, so kernel output is
tolerance-equal (not bitwise) to `ref.paged_attn_decode_ref`; the REF
oracle is the one that is bitwise against the contiguous decode path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, page_size: int, n_pseq: int,
                   scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page_size, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    s = jnp.where(k_pos < len_ref[b], s, -1e30)       # (G, page_size)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pexp, v, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(p == n_pseq - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attn_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      table: jax.Array, lengths: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, hd) grouped decode queries; k_pages/v_pages:
    (n_pages, page_size, KV, hd); table: (B, P) int32; lengths: (B,)
    valid context per row (>= 1 for rows whose output is read).
    Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    page_size = k_pages.shape[1]
    P = table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    grid = (B, KV, P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, kv, p, tbl, lens: (b, kv, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, kv, p, tbl, lens: (tbl[b * P + p], 0,
                                                      kv, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, kv, p, tbl, lens: (tbl[b * P + p], 0,
                                                      kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, kv, p, tbl, lens: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, page_size=page_size, n_pseq=P,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table.reshape(-1).astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
