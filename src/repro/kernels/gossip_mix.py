"""Gossip-mixing Pallas kernel — Algorithm 1 lines 7–9 as one fused pass.

y = W_eff @ X with W_eff = mask·W_t + (1−mask)·I (mask folding happens in
ops.py so LORA/FFA/ROLORA/TAD all reduce to a plain blocked matmul), where
X is the (m, P) buffer of all client LoRA factors flattened and
concatenated (both blocks → ONE kernel pass / ONE upstream collective,
the joint-mixing step the paper adds).

With a ``seg`` operand — a (1, P) per-column mask from the MixPlan's a/b
segment layout (core.mixing) — the kernel instead computes
y = seg·(W@X) + (1−seg)·X, i.e. a *per-segment* W_eff: unequal a/b masks
(alternating phases, damped mixing) stay one fused HBM sweep instead of a
per-leaf blend pass after the matmul.

`gossip_mix_quant` is the compressed-gossip variant: the source rows
arrive quantized (int8/fp8 payload + one f32 scale per row, produced by
`core.mixing.quantize_rows`) and the kernel fuses the dequantize into the
same stripe sweep — y = w_diag·x + W_off @ (q·scale), per-column seg
blend — so the reconstruction never materializes an f32 copy of the
halo in HBM.

m (clients) is small (10–64): W_eff stays whole in VMEM; the grid streams
P in bp-wide stripes. VPU/MXU work is trivial — the kernel exists to make
the mixing a single fused HBM sweep instead of per-leaf dispatches.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compat


def _resolve_bp(P: int, bp: int) -> int:
    """The stripe width actually used: the largest divisor of P that is
    <= bp (shrink-to-divisor, e.g. P=768 at bp=512 -> 256). Validation
    raises ValueError — the former asserts vanished under ``python -O``
    and ``bp = min(bp, P)`` alone still tripped on non-multiple P."""
    if P <= 0 or bp <= 0:
        raise ValueError(f"gossip_mix needs positive P and bp, got "
                         f"P={P}, bp={bp}")
    bp = min(bp, P)
    if P % bp:
        bp = math.gcd(P, bp)
    return bp


def _kernel(w_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(w_ref[...].astype(jnp.float32),
                         x_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _kernel_seg(w_ref, x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = jnp.dot(w_ref[...].astype(jnp.float32), x,
                preferred_element_type=jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (s * y + (1.0 - s) * x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def gossip_mix(w_eff: jax.Array, x: jax.Array,
               seg: Optional[jax.Array] = None, *, bp: int = 512,
               interpret: bool = False) -> jax.Array:
    """w_eff: (m, m); x: (m, P) -> (m, P). P padded to bp upstream.
    seg: optional (1, P) per-column blend mask (see module docstring)."""
    m, P = x.shape
    if w_eff.shape != (m, m):
        raise ValueError(f"gossip_mix: w_eff {w_eff.shape} does not match "
                         f"x client axis {m}")
    bp = _resolve_bp(P, bp)
    in_specs = [
        pl.BlockSpec((m, m), lambda j: (0, 0)),
        pl.BlockSpec((m, bp), lambda j: (0, j)),
    ]
    operands = (w_eff, x)
    kernel = _kernel
    if seg is not None:
        if seg.shape != (1, P):
            raise ValueError(f"gossip_mix: seg must be (1, {P}), got "
                             f"{seg.shape}")
        in_specs.append(pl.BlockSpec((1, bp), lambda j: (0, j)))
        operands = (w_eff, x, seg)
        kernel = _kernel_seg
    return pl.pallas_call(
        kernel,
        grid=(P // bp,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, P), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)


def _kernel_quant(w_ref, q_ref, s_ref, x_ref, wd_ref, seg_ref, o_ref):
    z = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    y = wd_ref[...].astype(jnp.float32) * x + jnp.dot(
        w_ref[...].astype(jnp.float32), z,
        preferred_element_type=jnp.float32)
    s = seg_ref[...].astype(jnp.float32)
    o_ref[...] = (s * y + (1.0 - s) * x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def gossip_mix_quant(w_off: jax.Array, q: jax.Array, scale: jax.Array,
                     x: jax.Array, w_diag: jax.Array, seg: jax.Array, *,
                     bp: int = 512, interpret: bool = False) -> jax.Array:
    """Compressed-gossip contraction with the dequantize fused in.

    w_off: (r, m) mixing rows, diagonal zeroed; q: (m, P) int8/fp8
    quantized source rows; scale: (m, 1) f32 per-row scales; x: (r, P)
    fresh full-precision local rows; w_diag: (r, 1) diagonal
    coefficients; seg: (1, P) per-column blend mask. Returns
    seg·(w_diag·x + w_off @ (q·scale)) + (1−seg)·x, shape (r, P).
    P is padded to bp upstream (ops.py); zero-padded q columns
    dequantize to exact zeros."""
    r, m = w_off.shape
    if q.shape[0] != m:
        raise ValueError(f"gossip_mix_quant: q rows {q.shape} do not "
                         f"match w_off columns {m}")
    P = q.shape[1]
    if x.shape != (r, P):
        raise ValueError(f"gossip_mix_quant: x must be ({r}, {P}), got "
                         f"{x.shape}")
    if scale.shape != (m, 1) or w_diag.shape != (r, 1):
        raise ValueError(f"gossip_mix_quant: scale/w_diag must be "
                         f"({m}, 1)/({r}, 1), got {scale.shape}/"
                         f"{w_diag.shape}")
    if seg.shape != (1, P):
        raise ValueError(f"gossip_mix_quant: seg must be (1, {P}), got "
                         f"{seg.shape}")
    bp = _resolve_bp(P, bp)
    return pl.pallas_call(
        _kernel_quant,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((r, m), lambda j: (0, 0)),
            pl.BlockSpec((m, bp), lambda j: (0, j)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
            pl.BlockSpec((r, bp), lambda j: (0, j)),
            pl.BlockSpec((r, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, bp), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r, bp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, P), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w_off, q, scale, x, w_diag, seg)
