"""Gossip-mixing Pallas kernel — Algorithm 1 lines 7–9 as one fused pass.

y = W_eff @ X with W_eff = mask·W_t + (1−mask)·I (mask folding happens in
ops.py so LORA/FFA/ROLORA/TAD all reduce to a plain blocked matmul), where
X is the (m, P) buffer of all client LoRA factors flattened and
concatenated (both blocks → ONE kernel pass / ONE upstream collective,
the joint-mixing step the paper adds).

With a ``seg`` operand — a (1, P) per-column mask from the MixPlan's a/b
segment layout (core.mixing) — the kernel instead computes
y = seg·(W@X) + (1−seg)·X, i.e. a *per-segment* W_eff: unequal a/b masks
(alternating phases, damped mixing) stay one fused HBM sweep instead of a
per-leaf blend pass after the matmul.

m (clients) is small (10–64): W_eff stays whole in VMEM; the grid streams
P in bp-wide stripes. VPU/MXU work is trivial — the kernel exists to make
the mixing a single fused HBM sweep instead of per-leaf dispatches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compat


def _kernel(w_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(w_ref[...].astype(jnp.float32),
                         x_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _kernel_seg(w_ref, x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = jnp.dot(w_ref[...].astype(jnp.float32), x,
                preferred_element_type=jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (s * y + (1.0 - s) * x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def gossip_mix(w_eff: jax.Array, x: jax.Array,
               seg: Optional[jax.Array] = None, *, bp: int = 512,
               interpret: bool = False) -> jax.Array:
    """w_eff: (m, m); x: (m, P) -> (m, P). P padded to bp upstream.
    seg: optional (1, P) per-column blend mask (see module docstring)."""
    m, P = x.shape
    bp = min(bp, P)
    assert P % bp == 0, (P, bp)
    in_specs = [
        pl.BlockSpec((m, m), lambda j: (0, 0)),
        pl.BlockSpec((m, bp), lambda j: (0, j)),
    ]
    operands = (w_eff, x)
    kernel = _kernel
    if seg is not None:
        assert seg.shape == (1, P), (seg.shape, P)
        in_specs.append(pl.BlockSpec((1, bp), lambda j: (0, j)))
        operands = (w_eff, x, seg)
        kernel = _kernel_seg
    return pl.pallas_call(
        kernel,
        grid=(P // bp,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, P), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)
