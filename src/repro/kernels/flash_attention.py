"""Blocked online-softmax attention (flash-style) with causal and
sliding-window masks — the prefill hot-spot (gemma3 / mixtral /
recurrentgemma local layers use windows).

Grid: (B*H, nq, nk) with the KV dimension innermost and sequential
("arbitrary"); running max / sum / accumulator live in VMEM scratch across
KV steps. Mask is computed from absolute block offsets, so causal and
windowed variants share one kernel. Fully-masked KV blocks still run
(grid pruning is a §Perf follow-up on real hardware; interpret-mode
validation is mask-correctness-only).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compat

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _finish():
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, d); k/v: (B, H, L, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    L = k.shape[2]
    bq, bk = min(bq, S), min(bk, L)
    assert S % bq == 0 and L % bk == 0, (S, L, bq, bk)
    nq, nk = S // bq, L // bk
    sm_scale = 1.0 / math.sqrt(d)

    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, L, d)
    vf = v.reshape(B * H, L, d)

    out = pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
