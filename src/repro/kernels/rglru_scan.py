"""RG-LRU diagonal linear recurrence Pallas kernel.

h_t = a_t ⊙ h_{t-1} + u_t over time, carried across time-blocks in VMEM
scratch. Grid: (B, nT) with time sequential; each block does bt in-VMEM
steps with a fori_loop (VPU elementwise — no MXU). This is the TPU-native
shape of the recurrence (contrast: the GPU kernels in the Griffin paper use
warp-level scans; here the parallelism is the W lane dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compat


def _kernel(a_ref, u_ref, o_ref, h_ref, *, bt: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # (bt, W)
    u = u_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + u[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[0])
    h_ref[0, :] = h


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rglru_scan(a: jax.Array, u: jax.Array, *, bt: int = 256,
               interpret: bool = False) -> jax.Array:
    """a, u: (B, T, W) -> h: (B, T, W)."""
    B, T, W = a.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(B, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, W), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, W), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, W), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, W), u.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, u)
