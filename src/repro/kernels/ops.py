"""Jit'd dispatching wrappers around the Pallas kernels.

On TPU the Pallas path compiles natively; elsewhere (this CPU container)
``ops`` falls back to the ref oracles so the framework runs everywhere.
``force="pallas_interpret"`` routes through the kernels in interpret mode
(used by tests to validate kernel bodies on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gossip_mix import gossip_mix as _gossip
from repro.kernels.gossip_mix import gossip_mix_quant as _gossip_quant
from repro.kernels.lora_matmul import lora_matmul as _lora_mm
from repro.kernels.lora_matmul import slot_lora_matmul as _slot_lora_mm
from repro.kernels.paged_attention import paged_attn_decode as _paged_attn
from repro.kernels.rglru_scan import rglru_scan as _rglru

_FORCE: Optional[str] = None   # None | "ref" | "pallas_interpret"


def set_backend(force: Optional[str]) -> None:
    global _FORCE
    assert force in (None, "ref", "pallas_interpret"), force
    _FORCE = force


def _mode() -> str:
    if _FORCE == "ref":
        return "ref"
    if _FORCE == "pallas_interpret":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def lora_matmul(x, w, a, b, scale: float = 1.0):
    m = _mode()
    if m == "ref":
        return ref.lora_matmul_ref(x, w, a, b, scale)
    return _lora_mm(x, w, a, b, scale, interpret=(m == "interpret"))


def slot_lora_matmul(x, w, a, b, slots, scale: float = 1.0):
    """Adapter-pool LoRA matmul: row i applies adapter ``slots[i]``.
    x: (B, K), w: (K, N), a: (N_ad, K, r), b: (N_ad, r, N), slots: (B,)."""
    m = _mode()
    if m == "ref":
        return ref.slot_lora_matmul_ref(x, w, a, b, slots, scale)
    return _slot_lora_mm(x, w, a, b, slots, scale,
                         interpret=(m == "interpret"))


def paged_attn_decode(q, k_pages, v_pages, table, lengths):
    """Single-token decode attention over a paged KV cache (the serving
    core's gather). q: (B, 1, H, hd); k_pages/v_pages: (n_pages,
    page_size, KV, hd); table: (B, P) int32; lengths: (B,). The ref
    oracle is bitwise-identical to the contiguous decode path; the
    Pallas kernel is the flash-decode accumulation (tolerance)."""
    m = _mode()
    if m == "ref":
        return ref.paged_attn_decode_ref(q, k_pages, v_pages, table, lengths)
    B, _, H, hd = q.shape
    n_kv = k_pages.shape[2]
    qg = q.reshape(B, n_kv, H // n_kv, hd)
    out = _paged_attn(qg, k_pages, v_pages, table, lengths,
                      interpret=(m == "interpret"))
    return out.reshape(B, 1, H, hd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, n_kv_heads: Optional[int] = None):
    """q: (B, H, S, d); k/v: (B, KV, L, d) — GQA repeat handled here."""
    if n_kv_heads and n_kv_heads != q.shape[1]:
        rep = q.shape[1] // n_kv_heads
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    m = _mode()
    if m == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=(m == "interpret"))


def gossip_mix_flat(w: jax.Array, x: jax.Array, mask: jax.Array | float = 1.0):
    """Mix a flattened (m, P) client buffer: y = (mask·W + (1−mask)·I) @ x."""
    m_ = x.shape[0]
    eye = jnp.eye(m_, dtype=w.dtype)
    w_eff = mask * w + (1.0 - mask) * eye
    mode = _mode()
    if mode == "ref":
        return ref.gossip_mix_ref(w_eff, x)
    P = x.shape[1]
    bp = 512
    pad = (-P) % bp
    if pad:
        x_p = jnp.pad(x, ((0, 0), (0, pad)))
        return _gossip(w_eff, x_p, interpret=(mode == "interpret"))[:, :P]
    return _gossip(w_eff, x, interpret=(mode == "interpret"))


def gossip_mix_seg(w: jax.Array, x: jax.Array, seg: jax.Array):
    """Mix a flattened (m, P) buffer with a per-column W_eff:
    y = seg·(W@x) + (1−seg)·x, seg: (1, P). This is the MixPlan fast path —
    unequal a/b masks fold into the single fused pass via the plan's
    column-segment layout instead of a per-leaf blend afterwards."""
    mode = _mode()
    if mode == "ref":
        return ref.gossip_mix_seg_ref(w, x, seg)
    P = x.shape[1]
    bp = 512
    pad = (-P) % bp
    if pad:
        x_p = jnp.pad(x, ((0, 0), (0, pad)))
        s_p = jnp.pad(seg, ((0, 0), (0, pad)))
        return _gossip(w, x_p, s_p,
                       interpret=(mode == "interpret"))[:, :P]
    return _gossip(w, x, seg, interpret=(mode == "interpret"))


def gossip_mix_quant(w_off: jax.Array, q: jax.Array, scale: jax.Array,
                     x: jax.Array, w_diag: jax.Array, seg: jax.Array):
    """Compressed-gossip contraction with the dequantize fused in:
    y = seg·(w_diag·x + w_off @ (q·scale)) + (1−seg)·x. w_off: (r, m)
    off-diagonal mixing rows; q: (m, P) int8/fp8 payload; scale: (m, 1)
    f32 per-row scales; x: (r, P) fresh local rows; w_diag: (r, 1);
    seg: (1, P). Zero-padded q/x/seg columns dequantize to exact zeros,
    so padding here and slicing back is lossless."""
    mode = _mode()
    if mode == "ref":
        return ref.gossip_mix_quant_ref(w_off, q, scale, x, w_diag, seg)
    P = x.shape[1]
    bp = 512
    pad = (-P) % bp
    if pad:
        q_p = jnp.pad(q, ((0, 0), (0, pad)))
        x_p = jnp.pad(x, ((0, 0), (0, pad)))
        s_p = jnp.pad(seg, ((0, 0), (0, pad)))
        return _gossip_quant(w_off, q_p, scale, x_p, w_diag, s_p,
                             interpret=(mode == "interpret"))[:, :P]
    return _gossip_quant(w_off, q, scale, x, w_diag, seg,
                         interpret=(mode == "interpret"))


def rglru_scan(a, u):
    m = _mode()
    if m == "ref":
        return ref.rglru_scan_ref(a, u)
    return _rglru(a, u, interpret=(m == "interpret"))
