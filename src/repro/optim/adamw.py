"""AdamW in pure JAX (no optax in this environment).

Matches the paper's optimizer (AdamW, HuggingFace defaults:
b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01). Works on any pytree; the
update is elementwise so client-stacked LoRA trees are per-client AdamW
automatically (each client's moments live in its slice).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


@dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params,
               lr_scale: jax.Array | float = 1.0,
               update_mask=None):
        """Returns (new_params, new_state). ``update_mask`` — pytree or
        callable(path)->scalar gating updates per leaf (alternating LoRA:
        frozen block gets mask 0 and keeps params AND moments)."""
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def leaf_update(path, p, g, mu, nu, mask):
            g32 = g.astype(jnp.float32)
            mu_n = b1 * mu + (1 - b1) * g32
            nu_n = b2 * nu + (1 - b2) * jnp.square(g32)
            mu_hat = mu_n / bc1
            nu_hat = nu_n / bc2
            upd = mu_hat / (jnp.sqrt(nu_hat) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.lr * lr_scale * mask * upd
            # masked leaves keep original moments too
            mu_out = mask * mu_n + (1 - mask) * mu
            nu_out = mask * nu_n + (1 - mask) * nu
            return new_p.astype(p.dtype), mu_out, nu_out

        if update_mask is None:
            masks = jax.tree.map(lambda _: 1.0, params)
        elif callable(update_mask):
            masks = jax.tree_util.tree_map_with_path(
                lambda path, _: update_mask(path), params)
        else:
            masks = update_mask

        flat = jax.tree_util.tree_flatten_with_path(params)
        paths = [p for p, _ in flat[0]]
        ps = [l for _, l in flat[0]]
        gs = jax.tree.leaves(grads)
        mus = jax.tree.leaves(state.mu)
        nus = jax.tree.leaves(state.nu)
        ms = jax.tree.leaves(masks)
        outs = [leaf_update(pa, p, g, mu, nu, mk)
                for pa, p, g, mu, nu, mk in zip(paths, ps, gs, mus, nus, ms)]
        treedef = flat[1]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), AdamWState(step=step, mu=unf(1), nu=unf(2))
