"""Learning-rate schedules (pure functions step -> scale factor)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(step):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))


def cosine_decay(step, total_steps: int, final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    frac = jnp.clip(s / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return final_frac + (1.0 - final_frac) * cos


def linear_warmup_cosine(step, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    w = jnp.clip(s / max(warmup, 1), 0.0, 1.0)
    return w * cosine_decay(jnp.maximum(s - warmup, 0),
                            max(total_steps - warmup, 1), final_frac)
