from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = ["AdamW", "AdamWState", "constant", "cosine_decay",
           "linear_warmup_cosine"]
