"""Sequence classifier for the paper-faithful experiments.

The paper fine-tunes RoBERTa-large (bidirectional encoder) with LoRA on Q/V
and a FROZEN classification head on GLUE tasks. This wrapper reproduces that
shape at any scale: a bidirectional encoder built from the same substrate
layers, mean-pooling, and a frozen linear head. Only the LoRA tree is
trainable — exactly the paper's setting.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models.common import dense_init, embed_tokens, init_mlp, mlp, rmsnorm, zeros
from repro.models.transformer import _init_layer


def encoder_config(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                   vocab_size=512, n_classes=2, lora_rank=4,
                   lora_alpha=8.0) -> ModelConfig:
    return ModelConfig(
        name=f"encoder-cls-{n_layers}L{d_model}d",
        family="decoder",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_ff=d_ff,
        vocab_size=vocab_size,
        pattern=(LayerSpec(kind=ATTN, ffn=DENSE),),
        lora_rank=lora_rank,
        lora_alpha=lora_alpha,
        citation="paper setup §VI-A (RoBERTa-large + LoRA r=8 on Q/V, "
                 "frozen head), reduced for CPU-scale validation",
    )


def init_classifier(key, cfg: ModelConfig, n_classes: int,
                    dtype=jnp.float32) -> dict:
    kE, kL, kH = jax.random.split(key, 3)
    layers = [
        _init_layer(jax.random.fold_in(kL, j), cfg, cfg.pattern[0], dtype,
                    encdec_cross=False)
        for j in range(cfg.n_layers)
    ]
    return {
        "embed": (jax.random.normal(kE, (cfg.vocab_padded, cfg.d_model)) *
                  0.02).astype(dtype),
        "layers": layers,
        "final_norm": zeros(cfg.d_model, dtype=dtype),
        "head": dense_init(kH, cfg.d_model, n_classes, dtype),  # FROZEN
    }


def classifier_forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
                       lora: Optional[dict] = None) -> jax.Array:
    """tokens: (..., S) -> class logits (..., n_classes). Bidirectional."""
    x = embed_tokens(params["embed"], tokens) * math.sqrt(cfg.d_model)
    lo_layers = (lora or {}).get("layers", [None] * cfg.n_layers)
    for j, p in enumerate(params["layers"]):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        lj = lo_layers[j] or {}
        x = x + attn_mod.attn_forward(p["attn"], cfg, h, causal=False,
                                      lora=lj.get("attn"))
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    pooled = jnp.mean(x, axis=-2)
    return pooled @ params["head"]


def classifier_loss(params: dict, cfg: ModelConfig, tokens: jax.Array,
                    labels: jax.Array, lora: Optional[dict] = None,
                    per_client: bool = False):
    """Mean CE. With ``per_client`` also returns the per-leading-index
    (per-client) mean-loss vector: its entries are shard-local reductions,
    so they are bitwise identical on every process grid — the round loop
    reports loss from this vector (host-reduced, one fixed order) while
    the scalar (whose reduction XLA may decompose differently per grid)
    feeds only the gradient, where summation order cannot matter."""
    logits = classifier_forward(params, cfg, tokens, lora).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per = lse - tgt
    loss = jnp.mean(per)
    if not per_client:
        return loss
    vec = per.reshape(per.shape[0], -1).mean(axis=-1) if per.ndim > 1 \
        else per
    return loss, vec


def classifier_accuracy(params: dict, cfg: ModelConfig, tokens: jax.Array,
                        labels: jax.Array, lora: Optional[dict] = None):
    logits = classifier_forward(params, cfg, tokens, lora)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
