"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: norm -> two input branches (gate branch with GeLU; recurrence branch
with short temporal conv + RG-LRU) -> elementwise merge -> out projection.

RG-LRU recurrence (diagonal, per-channel):
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (decay in (0,1), c=8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over the time axis (log-depth,
shardable); decode carries (h, conv buffer) state.  LoRA targets the in/out
projections (the technique applies to any linear map — DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, zeros
from repro.models.layers import lora_linear, shard_act

_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a ~ uniform-ish decay in (0.9, 0.999)
    lam = jax.random.uniform(ks[5], (w,), minval=2.0, maxval=6.0)
    return {
        "w_in_x": dense_init(ks[0], d, w, dtype),     # recurrence branch
        "w_in_g": dense_init(ks[1], d, w, dtype),     # gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, w)) *
                   0.1).astype(dtype),
        "conv_b": zeros(w, dtype=dtype),
        "w_gate_r": dense_init(ks[3], w, w, dtype),   # recurrence gate
        "w_gate_i": dense_init(ks[4], w, w, dtype),   # input gate
        "b_gate_r": zeros(w, dtype=dtype),
        "b_gate_i": zeros(w, dtype=dtype),
        "lam": lam.astype(dtype),
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv over time. x: (..., S, w); w: (K, w).
    With ``state`` (..., K-1, w) from decode, prepends it instead of zeros."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((*x.shape[:-2], K - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=-2)
    out = sum(xp[..., k:k + x.shape[-2], :] * w[k] for k in range(K))
    return out + b, xp[..., -(K - 1):, :]


def _rglru_gates(params: dict, xr: jax.Array):
    r = jax.nn.sigmoid(xr @ params["w_gate_r"] + params["b_gate_r"])
    i = jax.nn.sigmoid(xr @ params["w_gate_i"] + params["b_gate_i"])
    log_a = (-_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) *
             r.astype(jnp.float32))                  # log a_t  (<0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * (i.astype(jnp.float32) * xr.astype(jnp.float32))
    return a, u


def rglru_scan(a: jax.Array, u: jax.Array, h0: jax.Array | None = None):
    """Solve h_t = a_t h_{t-1} + u_t over axis -2 via associative scan."""
    if h0 is not None:
        u = u.at[..., 0, :].add(a[..., 0, :] * h0)

    def comb(c1, c2):
        (a1, u1), (a2, u2) = c1, c2
        return a1 * a2, a2 * u1 + u2

    a_c, h = jax.lax.associative_scan(comb, (a, u), axis=-2)
    return h


def rglru_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                  lora: dict | None = None):
    """x: (..., S, d) -> (..., S, d). Full-sequence path."""
    scale = cfg.lora_alpha / cfg.lora_rank
    xr = lora_linear(x, params["w_in_x"], (lora or {}).get("w_in_x"), scale)
    xg = lora_linear(x, params["w_in_g"], (lora or {}).get("w_in_g"), scale)
    xr, _ = _causal_conv(xr, params["conv_w"], params["conv_b"])
    a, u = _rglru_gates(params, xr)
    h = rglru_scan(a, u).astype(x.dtype)
    merged = h * jax.nn.gelu(xg)
    out = lora_linear(merged, params["w_out"], (lora or {}).get("w_out"), scale)
    return shard_act(out)


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": zeros(batch, w, dtype=jnp.float32),
        "conv": zeros(batch, cfg.conv1d_width - 1, w, dtype=dtype),
    }


def rglru_state_spec(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    w = cfg.rglru_width or cfg.d_model
    f = jax.ShapeDtypeStruct
    return {"h": f((batch, w), jnp.float32),
            "conv": f((batch, cfg.conv1d_width - 1, w), dtype)}


def rglru_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict,
                 lora: dict | None = None):
    """x: (B, 1, d); O(1) per-token state update."""
    scale = cfg.lora_alpha / cfg.lora_rank
    xr = lora_linear(x, params["w_in_x"], (lora or {}).get("w_in_x"), scale)
    xg = lora_linear(x, params["w_in_g"], (lora or {}).get("w_in_g"), scale)
    xr, conv_state = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                  state["conv"])
    a, u = _rglru_gates(params, xr)          # (B, 1, w)
    h = a[:, 0] * state["h"] + u[:, 0]       # (B, w)
    merged = (h[:, None].astype(x.dtype)) * jax.nn.gelu(xg)
    out = lora_linear(merged, params["w_out"], (lora or {}).get("w_out"), scale)
    return shard_act(out), {"h": h, "conv": conv_state}
