"""Grouped-query attention: global / sliding-window / cross, train + decode.

Memory strategy (dry-run-safe at 32k prefill):
 - queries are chunked with lax.scan when S >= _CHUNK_THRESHOLD;
 - chunk bodies are rematerialized (jax.checkpoint) so AD through the scan
   does not retain per-chunk score tensors;
 - scores shard over kv-heads ("model") when divisible, else over the KV
   length ("seq") — sequence-parallel softmax via GSPMD collectives;
 - sliding-window prefill restricts each q-chunk to a banded KV slice.

Decode uses a rolling cache: {"k": (B, L, KV, hd), "v": ..., "t": ()} with
write slot t % L; keys are stored post-RoPE (absolute positions at write
time), so rolling overwrite needs no re-rotation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import axis_size, logical
from repro.models.common import apply_rope, dense_init, rmsnorm, zeros
from repro.models.layers import lora_linear, shard_act

_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, hd = cfg.d_model, cfg.hd
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, q_dim, dtype),
        "wk": dense_init(ks[1], d, kv_dim, dtype),
        "wv": dense_init(ks[2], d, kv_dim, dtype),
        "wo": dense_init(ks[3], q_dim, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros(q_dim, dtype=dtype)
        p["bk"] = zeros(kv_dim, dtype=dtype)
        p["bv"] = zeros(kv_dim, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Score/attend core (grouped heads, no kv materialized repeat)
# ---------------------------------------------------------------------------

def _scores_spec(n_kv: int, n_groups: int):
    """Sharding for scores (B, KV, G, Sq, L): TP over kv-heads when they
    divide the model axis, else over head-groups (MQA: KV=1, G=heads),
    else fully LOCAL (batch only). Never shard L: sequence-sharded softmax
    made GSPMD all-gather K/V slices inside the q-chunk scan (measured
    ~180 GB/step in the gemma3 dry-run — EXPERIMENTS.md §Perf iter 3)."""
    model_n = axis_size("model")
    if model_n > 1 and n_kv % model_n == 0:
        return ("batch", "model", None, None, None)
    if model_n > 1 and n_groups % model_n == 0:
        return ("batch", None, "model", None, None)
    # Non-divisible heads: leave scores unconstrained. History (§Perf):
    # forced-replicated fallback gathered probs/masks (~255 GB/step,
    # iter 5); forced q-dim sharding exploded qwen2 prefill to 2.3e3 s
    # (pair-B iter 1, REFUTED). The input-side fix (replicating q/k/v per
    # layer, pair-B iter 2) steers GSPMD instead.
    return None


def _attend(q, k, v, mask, n_kv: int):
    """q: (B, Sq, H, hd); k/v: (B, L, KV, hd); mask broadcastable to
    (B, 1, 1, Sq, L) or None. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    L = k.shape[1]
    G = H // n_kv
    qg = q.reshape(B, Sq, n_kv, G, hd)
    scores = jnp.einsum("bskgh,blkh->bkgsl", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    spec = _scores_spec(n_kv, G)
    if spec is not None:
        scores = logical(scores, *spec)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _band_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(Sq, L) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def attn_forward(params: dict, cfg: ModelConfig, x: jax.Array, *,
                 window: Optional[int] = None, causal: bool = True,
                 lora: Optional[dict] = None, positions=None,
                 memory: Optional[jax.Array] = None,
                 return_kv: bool = False):
    """x: (..., S, d). Cross-attention when ``memory`` is given (K/V from
    memory, no RoPE, bidirectional over memory)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    lq = (lora or {}).get("wq")
    lk = (lora or {}).get("wk")
    lv = (lora or {}).get("wv")
    hd = cfg.hd

    q = lora_linear(x, params["wq"], lq, scale, params.get("bq"))
    kv_src = memory if memory is not None else x
    k = lora_linear(kv_src, params["wk"], lk, scale, params.get("bk"))
    v = lora_linear(kv_src, params["wv"], lv, scale, params.get("bv"))

    lead = x.shape[:-2]          # leading dims (e.g. clients) beyond batch
    S = x.shape[-2]
    L = kv_src.shape[-2]
    q = q.reshape(*lead, S, cfg.n_heads, hd)
    k = k.reshape(*lead, L, cfg.n_kv_heads, hd)
    v = v.reshape(*lead, L, cfg.n_kv_heads, hd)

    if memory is None:  # self-attention: RoPE
        if positions is None:
            positions = jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # collapse leading dims to one batch axis for the core
    B = math.prod(lead) if lead else 1
    qf = q.reshape(B, S, cfg.n_heads, hd)
    kf = k.reshape(B, L, cfg.n_kv_heads, hd)
    vf = v.reshape(B, L, cfg.n_kv_heads, hd)

    G = cfg.n_heads // cfg.n_kv_heads
    model_n = axis_size("model")
    if (model_n > 1 and cfg.n_kv_heads % model_n and G % model_n
            and memory is None):
        # heads don't divide the TP axis: replicate q/k/v ONCE per layer
        # (cheap: per-layer gather) so GSPMD cannot partial-sum the hd
        # contraction and all-reduce full score tensors per q-chunk
        # (measured 1.68 TB/step on qwen2 prefill — §Perf pair-B iter 2)
        rep = lambda z: logical(z, "batch", *((None,) * (z.ndim - 1)))
        qf, kf, vf = rep(qf), rep(kf), rep(vf)

    if memory is not None:
        out = _attend(qf, kf, vf, None, cfg.n_kv_heads)
    elif S < _CHUNK_THRESHOLD:
        mask = _band_mask(jnp.arange(S), jnp.arange(L), causal=causal,
                          window=window)
        out = _attend(qf, kf, vf, mask[None, None, None], cfg.n_kv_heads)
    else:
        out = _chunked_attend(qf, kf, vf, cfg.n_kv_heads, causal=causal,
                              window=window)

    out = out.reshape(*lead, S, cfg.n_heads * hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    out = shard_act(out)
    if return_kv:
        return out, (k, v)
    return out


def _chunked_attend(q, k, v, n_kv: int, *, causal: bool,
                    window: Optional[int]):
    """lax.scan over q chunks; banded KV slice when windowed."""
    B, S, H, hd = q.shape
    L = k.shape[1]
    C = _Q_CHUNK if L <= 8192 else _Q_CHUNK // 4   # bound live score bytes
    n_chunks = S // C
    assert S % C == 0, (S, C)

    if window is not None and causal and L == S:
        # round the band up to a multiple of C for static slicing
        band = min(L, (math.ceil(window / C) + 1) * C)
    else:
        band = None

    @jax.checkpoint
    def body(_, idx):
        q_start = idx * C
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, C, axis=1)
        q_pos = q_start + jnp.arange(C)
        if band is not None:
            k_start = jnp.maximum(q_start + C - band, 0)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, band, axis=1)
            k_pos = k_start + jnp.arange(band)
        else:
            kc, vc, k_pos = k, v, jnp.arange(L)
        mask = _band_mask(q_pos, k_pos, causal=causal, window=window)
        out = _attend(qc, kc, vc, mask[None, None, None], n_kv)
        return None, out

    _, chunks = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # chunks: (n_chunks, B, C, H, hd) -> (B, S, H, hd)
    return jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Decode (one token, rolling cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.float32) -> dict:
    """Rolling KV cache with PER-SLOT position counters "t" (B,) — each
    batch row is an independent serving slot (continuous batching:
    launch/serving.py admits/evicts requests per row)."""
    L = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": zeros(batch, L, kv, hd, dtype=dtype),
        "v": zeros(batch, L, kv, hd, dtype=dtype),
        "t": jnp.zeros((batch,), dtype=jnp.int32),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct version of init_cache (dry-run, no allocation)."""
    L = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    f = jax.ShapeDtypeStruct
    return {
        "k": f((batch, L, kv, hd), dtype),
        "v": f((batch, L, kv, hd), dtype),
        "t": f((batch,), jnp.int32),
    }


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, dtype=jnp.float32) -> dict:
    """Paged KV cache (serving core): physical pages shared by all slots,
    per-slot position counters. The logical->physical block table lives at
    the cache top level (`transformer.init_cache(paging=...)`) because one
    table serves every paged layer. Physical page 0 is the null page —
    free slots' table rows point at it and no active slot ever reads it."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "kp": zeros(n_pages, page_size, kv, hd, dtype=dtype),
        "vp": zeros(n_pages, page_size, kv, hd, dtype=dtype),
        "t": jnp.zeros((batch,), dtype=jnp.int32),
    }


def paged_cache_spec(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct version of init_paged_cache (dry-run)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    f = jax.ShapeDtypeStruct
    return {
        "kp": f((n_pages, page_size, kv, hd), dtype),
        "vp": f((n_pages, page_size, kv, hd), dtype),
        "t": f((batch,), jnp.int32),
    }


def attn_decode(params: dict, cfg: ModelConfig, x: jax.Array, cache: dict, *,
                window: Optional[int] = None, lora: Optional[dict] = None,
                cross_kv: Optional[tuple] = None,
                pages: Optional[dict] = None):
    """x: (B, 1, d). Returns (out, new_cache). With ``cross_kv`` (k, v) the
    layer is cross-attention (static memory KV, cache untouched). A cache
    carrying "kp"/"vp" is paged (serving core) and additionally needs
    ``pages`` = {"table": (B, P) int32}."""
    scale = cfg.lora_alpha / cfg.lora_rank
    hd = cfg.hd
    B = x.shape[0]
    q = lora_linear(x, params["wq"], (lora or {}).get("wq"), scale,
                    params.get("bq"))
    q = q.reshape(B, 1, cfg.n_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = _attend(q, k, v, None, cfg.n_kv_heads)
        out = out.reshape(B, 1, cfg.n_heads * hd)
        out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
        return shard_act(out), cache

    t = cache["t"]                                     # (B,) per-slot pos
    k_new = lora_linear(x, params["wk"], (lora or {}).get("wk"), scale,
                        params.get("bk")).reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = lora_linear(x, params["wv"], (lora or {}).get("wv"), scale,
                        params.get("bv")).reshape(B, 1, cfg.n_kv_heads, hd)
    pos = t[:, None].astype(jnp.float32)               # (B, 1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)

    if "kp" in cache:
        return _paged_decode_core(cfg, q, k_new, v_new, cache, pages,
                                  params, lora, scale)

    L = cache["k"].shape[1]
    slot = (t % L).astype(jnp.int32)                   # (B,)
    rows = jnp.arange(B)
    k_cache = cache["k"].at[rows, slot].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, slot].set(
        v_new[:, 0].astype(cache["v"].dtype))
    k_cache = logical(k_cache, "batch", "seq", None, None)
    v_cache = logical(v_cache, "batch", "seq", None, None)

    valid = jnp.arange(L)[None, :] < jnp.minimum(t + 1, L)[:, None]  # (B,L)
    mask = valid[:, None, None, None, :]
    out = _attend(q, k_cache, v_cache, mask, cfg.n_kv_heads)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    new_cache = {"k": k_cache, "v": v_cache, "t": t + 1}
    return shard_act(out), new_cache


def _paged_decode_core(cfg: ModelConfig, q, k_new, v_new, cache: dict,
                       pages: dict, params: dict, lora, scale: float):
    """Paged tail of attn_decode: scatter this token's K/V into the slot's
    current page, then attend over the block-table view. Inactive rows
    (all-zero table row) scatter onto the null page 0, which no active
    row's table references — their output is garbage the engine discards.
    At identical contexts the ref path is bitwise equal to the contiguous
    branch above: the gathered (B, L, KV, hd) view holds the same values,
    masks, and einsum shapes (tests/test_paging.py asserts this)."""
    from repro.kernels import ops   # deferred: kernels import jax.pallas

    B = q.shape[0]
    t = cache["t"]
    table = pages["table"]                             # (B, P)
    ps = cache["kp"].shape[1]
    P = table.shape[1]
    L = P * ps
    rows = jnp.arange(B)
    phys = table[rows, jnp.clip(t // ps, 0, P - 1)]    # (B,)
    off = t % ps
    kp = cache["kp"].at[phys, off].set(k_new[:, 0].astype(cache["kp"].dtype))
    vp = cache["vp"].at[phys, off].set(v_new[:, 0].astype(cache["vp"].dtype))
    lengths = jnp.minimum(t + 1, L)
    out = ops.paged_attn_decode(q, kp, vp, table, lengths)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    new_cache = {"kp": kp, "vp": vp, "t": t + 1}
    return shard_act(out), new_cache


# ---------------------------------------------------------------------------
# Chunked prefill (serving core: one slot's prompt, C tokens per step)
# ---------------------------------------------------------------------------

def _chunk_qkv(params: dict, cfg: ModelConfig, x, pos, lora, scale):
    """Shared head of both chunk paths: projections + RoPE at absolute
    positions. x: (1, C, d); pos: (C,) int32."""
    hd = cfg.hd
    C = x.shape[1]
    lo = lora or {}
    q = lora_linear(x, params["wq"], lo.get("wq"), scale,
                    params.get("bq")).reshape(1, C, cfg.n_heads, hd)
    k_new = lora_linear(x, params["wk"], lo.get("wk"), scale,
                        params.get("bk")).reshape(1, C, cfg.n_kv_heads, hd)
    v_new = lora_linear(x, params["wv"], lo.get("wv"), scale,
                        params.get("bv")).reshape(1, C, cfg.n_kv_heads, hd)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)
    return q, k_new, v_new


def _chunk_out(params: dict, cfg: ModelConfig, out, lora, scale):
    out = out.reshape(1, -1, cfg.n_heads * cfg.hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    return shard_act(out)


def attn_chunk_paged(params: dict, cfg: ModelConfig, x, cache: dict,
                     table_row, slot, start, limit, *,
                     lora: Optional[dict] = None):
    """One prefill chunk into a PAGED layer cache. x: (1, C, d) chunk of
    one slot's prompt; table_row: (P,) the slot's block-table row; slot /
    start / limit: () int32 — batch row, absolute chunk offset, and total
    real (unpadded) prefill length. Pad positions (>= limit) write nothing
    (masked to the old value) and their outputs are garbage the caller
    drops. Returns (out (1, C, d_q), new layer cache)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    C = x.shape[1]
    pos = start + jnp.arange(C)                        # (C,) absolute
    q, k_new, v_new = _chunk_qkv(params, cfg, x, pos, lora, scale)

    ps = cache["kp"].shape[1]
    P = table_row.shape[0]
    L = P * ps
    pos_c = jnp.clip(pos, 0, L - 1)                    # pads stay in range
    phys = table_row[pos_c // ps]                      # (C,)
    off = pos_c % ps
    valid_w = (pos < limit)[:, None, None]
    kw = jnp.where(valid_w, k_new[0].astype(cache["kp"].dtype),
                   cache["kp"][phys, off])
    vw = jnp.where(valid_w, v_new[0].astype(cache["vp"].dtype),
                   cache["vp"][phys, off])
    kp = cache["kp"].at[phys, off].set(kw)
    vp = cache["vp"].at[phys, off].set(vw)

    k_all = kp[table_row].reshape(1, L, cfg.n_kv_heads, cfg.hd)
    v_all = vp[table_row].reshape(1, L, cfg.n_kv_heads, cfg.hd)
    k_pos = jnp.arange(L)
    mask = (k_pos[None, :] <= pos[:, None]) & (k_pos[None, :] < limit)
    out = _attend(q, k_all, v_all, mask[None, None, None], cfg.n_kv_heads)

    t_new = cache["t"].at[slot].set(jnp.minimum(start + C, limit))
    return (_chunk_out(params, cfg, out, lora, scale),
            {"kp": kp, "vp": vp, "t": t_new})


def attn_chunk_rolling(params: dict, cfg: ModelConfig, x, cache: dict,
                       slot, start, limit, *, lora: Optional[dict] = None):
    """One prefill chunk into a ROLLING (contiguous) layer cache of length
    L = the layer's window (or max_len for global layers). The slot's
    buffer holds positions start-L..start-1 at entry (slot p%L); the chunk
    attends its banded context, then writes back its last min(C, L) real
    positions. Matches decode semantics: key position k is visible to
    query position s iff 0 <= k <= s and s - k < L."""
    scale = cfg.lora_alpha / cfg.lora_rank
    C = x.shape[1]
    L = cache["k"].shape[1]
    pos = start + jnp.arange(C)
    q, k_new, v_new = _chunk_qkv(params, cfg, x, pos, lora, scale)

    s_idx = jnp.arange(L)
    ctx_pos = start - L + ((s_idx - start) % L)        # position held at
    #                                                    buffer slot s_idx
    k_all = jnp.concatenate([cache["k"][slot][None], k_new], axis=1)
    v_all = jnp.concatenate([cache["v"][slot][None], v_new], axis=1)
    k_pos = jnp.concatenate([ctx_pos, pos])            # (L + C,)
    mask = ((k_pos[None, :] <= pos[:, None]) &
            (k_pos[None, :] >= 0) &
            (k_pos[None, :] < limit) &
            (pos[:, None] - k_pos[None, :] < L))
    out = _attend(q, k_all, v_all, mask[None, None, None], cfg.n_kv_heads)

    # write-back, one gather per buffer slot j: the LATEST real chunk
    # position p with p % L == j (pads and wrapped-over positions never
    # land; duplicate-index scatters would be order-unspecified, a gather
    # is deterministic). e = exclusive end of real positions this chunk.
    e = jnp.minimum(limit, start + C)
    last = (e - 1) - ((e - 1 - s_idx) % L)             # latest p == j (mod L)
    w_valid = (last >= start)[:, None, None]           # p inside this chunk?
    idx = jnp.clip(last - start, 0, C - 1)
    kw = jnp.where(w_valid, k_new[0, idx].astype(cache["k"].dtype),
                   cache["k"][slot])
    vw = jnp.where(w_valid, v_new[0, idx].astype(cache["v"].dtype),
                   cache["v"][slot])
    k_cache = cache["k"].at[slot].set(kw)
    v_cache = cache["v"].at[slot].set(vw)

    t_new = cache["t"].at[slot].set(jnp.minimum(start + C, limit))
    return (_chunk_out(params, cfg, out, lora, scale),
            {"k": k_cache, "v": v_cache, "t": t_new})
