"""Grouped-query attention: global / sliding-window / cross, train + decode.

Memory strategy (dry-run-safe at 32k prefill):
 - queries are chunked with lax.scan when S >= _CHUNK_THRESHOLD;
 - chunk bodies are rematerialized (jax.checkpoint) so AD through the scan
   does not retain per-chunk score tensors;
 - scores shard over kv-heads ("model") when divisible, else over the KV
   length ("seq") — sequence-parallel softmax via GSPMD collectives;
 - sliding-window prefill restricts each q-chunk to a banded KV slice.

Decode uses a rolling cache: {"k": (B, L, KV, hd), "v": ..., "t": ()} with
write slot t % L; keys are stored post-RoPE (absolute positions at write
time), so rolling overwrite needs no re-rotation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import axis_size, logical
from repro.models.common import apply_rope, dense_init, rmsnorm, zeros
from repro.models.layers import lora_linear, shard_act

_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, hd = cfg.d_model, cfg.hd
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, q_dim, dtype),
        "wk": dense_init(ks[1], d, kv_dim, dtype),
        "wv": dense_init(ks[2], d, kv_dim, dtype),
        "wo": dense_init(ks[3], q_dim, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros(q_dim, dtype=dtype)
        p["bk"] = zeros(kv_dim, dtype=dtype)
        p["bv"] = zeros(kv_dim, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Score/attend core (grouped heads, no kv materialized repeat)
# ---------------------------------------------------------------------------

def _scores_spec(n_kv: int, n_groups: int):
    """Sharding for scores (B, KV, G, Sq, L): TP over kv-heads when they
    divide the model axis, else over head-groups (MQA: KV=1, G=heads),
    else fully LOCAL (batch only). Never shard L: sequence-sharded softmax
    made GSPMD all-gather K/V slices inside the q-chunk scan (measured
    ~180 GB/step in the gemma3 dry-run — EXPERIMENTS.md §Perf iter 3)."""
    model_n = axis_size("model")
    if model_n > 1 and n_kv % model_n == 0:
        return ("batch", "model", None, None, None)
    if model_n > 1 and n_groups % model_n == 0:
        return ("batch", None, "model", None, None)
    # Non-divisible heads: leave scores unconstrained. History (§Perf):
    # forced-replicated fallback gathered probs/masks (~255 GB/step,
    # iter 5); forced q-dim sharding exploded qwen2 prefill to 2.3e3 s
    # (pair-B iter 1, REFUTED). The input-side fix (replicating q/k/v per
    # layer, pair-B iter 2) steers GSPMD instead.
    return None


def _attend(q, k, v, mask, n_kv: int):
    """q: (B, Sq, H, hd); k/v: (B, L, KV, hd); mask broadcastable to
    (B, 1, 1, Sq, L) or None. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    L = k.shape[1]
    G = H // n_kv
    qg = q.reshape(B, Sq, n_kv, G, hd)
    scores = jnp.einsum("bskgh,blkh->bkgsl", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    spec = _scores_spec(n_kv, G)
    if spec is not None:
        scores = logical(scores, *spec)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _band_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(Sq, L) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def attn_forward(params: dict, cfg: ModelConfig, x: jax.Array, *,
                 window: Optional[int] = None, causal: bool = True,
                 lora: Optional[dict] = None, positions=None,
                 memory: Optional[jax.Array] = None,
                 return_kv: bool = False):
    """x: (..., S, d). Cross-attention when ``memory`` is given (K/V from
    memory, no RoPE, bidirectional over memory)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    lq = (lora or {}).get("wq")
    lk = (lora or {}).get("wk")
    lv = (lora or {}).get("wv")
    hd = cfg.hd

    q = lora_linear(x, params["wq"], lq, scale, params.get("bq"))
    kv_src = memory if memory is not None else x
    k = lora_linear(kv_src, params["wk"], lk, scale, params.get("bk"))
    v = lora_linear(kv_src, params["wv"], lv, scale, params.get("bv"))

    lead = x.shape[:-2]          # leading dims (e.g. clients) beyond batch
    S = x.shape[-2]
    L = kv_src.shape[-2]
    q = q.reshape(*lead, S, cfg.n_heads, hd)
    k = k.reshape(*lead, L, cfg.n_kv_heads, hd)
    v = v.reshape(*lead, L, cfg.n_kv_heads, hd)

    if memory is None:  # self-attention: RoPE
        if positions is None:
            positions = jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # collapse leading dims to one batch axis for the core
    B = math.prod(lead) if lead else 1
    qf = q.reshape(B, S, cfg.n_heads, hd)
    kf = k.reshape(B, L, cfg.n_kv_heads, hd)
    vf = v.reshape(B, L, cfg.n_kv_heads, hd)

    G = cfg.n_heads // cfg.n_kv_heads
    model_n = axis_size("model")
    if (model_n > 1 and cfg.n_kv_heads % model_n and G % model_n
            and memory is None):
        # heads don't divide the TP axis: replicate q/k/v ONCE per layer
        # (cheap: per-layer gather) so GSPMD cannot partial-sum the hd
        # contraction and all-reduce full score tensors per q-chunk
        # (measured 1.68 TB/step on qwen2 prefill — §Perf pair-B iter 2)
        rep = lambda z: logical(z, "batch", *((None,) * (z.ndim - 1)))
        qf, kf, vf = rep(qf), rep(kf), rep(vf)

    if memory is not None:
        out = _attend(qf, kf, vf, None, cfg.n_kv_heads)
    elif S < _CHUNK_THRESHOLD:
        mask = _band_mask(jnp.arange(S), jnp.arange(L), causal=causal,
                          window=window)
        out = _attend(qf, kf, vf, mask[None, None, None], cfg.n_kv_heads)
    else:
        out = _chunked_attend(qf, kf, vf, cfg.n_kv_heads, causal=causal,
                              window=window)

    out = out.reshape(*lead, S, cfg.n_heads * hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    out = shard_act(out)
    if return_kv:
        return out, (k, v)
    return out


def _chunked_attend(q, k, v, n_kv: int, *, causal: bool,
                    window: Optional[int]):
    """lax.scan over q chunks; banded KV slice when windowed."""
    B, S, H, hd = q.shape
    L = k.shape[1]
    C = _Q_CHUNK if L <= 8192 else _Q_CHUNK // 4   # bound live score bytes
    n_chunks = S // C
    assert S % C == 0, (S, C)

    if window is not None and causal and L == S:
        # round the band up to a multiple of C for static slicing
        band = min(L, (math.ceil(window / C) + 1) * C)
    else:
        band = None

    @jax.checkpoint
    def body(_, idx):
        q_start = idx * C
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, C, axis=1)
        q_pos = q_start + jnp.arange(C)
        if band is not None:
            k_start = jnp.maximum(q_start + C - band, 0)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, band, axis=1)
            k_pos = k_start + jnp.arange(band)
        else:
            kc, vc, k_pos = k, v, jnp.arange(L)
        mask = _band_mask(q_pos, k_pos, causal=causal, window=window)
        out = _attend(qc, kc, vc, mask[None, None, None], n_kv)
        return None, out

    _, chunks = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # chunks: (n_chunks, B, C, H, hd) -> (B, S, H, hd)
    return jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Decode (one token, rolling cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.float32) -> dict:
    """Rolling KV cache with PER-SLOT position counters "t" (B,) — each
    batch row is an independent serving slot (continuous batching:
    launch/serving.py admits/evicts requests per row)."""
    L = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": zeros(batch, L, kv, hd, dtype=dtype),
        "v": zeros(batch, L, kv, hd, dtype=dtype),
        "t": jnp.zeros((batch,), dtype=jnp.int32),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct version of init_cache (dry-run, no allocation)."""
    L = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    f = jax.ShapeDtypeStruct
    return {
        "k": f((batch, L, kv, hd), dtype),
        "v": f((batch, L, kv, hd), dtype),
        "t": f((batch,), jnp.int32),
    }


def attn_decode(params: dict, cfg: ModelConfig, x: jax.Array, cache: dict, *,
                window: Optional[int] = None, lora: Optional[dict] = None,
                cross_kv: Optional[tuple] = None):
    """x: (B, 1, d). Returns (out, new_cache). With ``cross_kv`` (k, v) the
    layer is cross-attention (static memory KV, cache untouched)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    hd = cfg.hd
    B = x.shape[0]
    q = lora_linear(x, params["wq"], (lora or {}).get("wq"), scale,
                    params.get("bq"))
    q = q.reshape(B, 1, cfg.n_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = _attend(q, k, v, None, cfg.n_kv_heads)
        out = out.reshape(B, 1, cfg.n_heads * hd)
        out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
        return shard_act(out), cache

    t = cache["t"]                                     # (B,) per-slot pos
    k_new = lora_linear(x, params["wk"], (lora or {}).get("wk"), scale,
                        params.get("bk")).reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = lora_linear(x, params["wv"], (lora or {}).get("wv"), scale,
                        params.get("bv")).reshape(B, 1, cfg.n_kv_heads, hd)
    pos = t[:, None].astype(jnp.float32)               # (B, 1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = (t % L).astype(jnp.int32)                   # (B,)
    rows = jnp.arange(B)
    k_cache = cache["k"].at[rows, slot].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, slot].set(
        v_new[:, 0].astype(cache["v"].dtype))
    k_cache = logical(k_cache, "batch", "seq", None, None)
    v_cache = logical(v_cache, "batch", "seq", None, None)

    valid = jnp.arange(L)[None, :] < jnp.minimum(t + 1, L)[:, None]  # (B,L)
    mask = valid[:, None, None, None, :]
    out = _attend(q, k_cache, v_cache, mask, cfg.n_kv_heads)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    out = lora_linear(out, params["wo"], (lora or {}).get("wo"), scale)
    new_cache = {"k": k_cache, "v": v_cache, "t": t + 1}
    return shard_act(out), new_cache
