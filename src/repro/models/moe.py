"""Mixture-of-Experts FFN: routed top-k + optional shared experts
(DeepSeekMoE / Moonlight fine-grained style; Mixtral when shared=0).

Dense-einsum formulation: every expert runs on every token, gated by the
router's top-k weights. This is the standard TPU-friendly dense-MoE lowering
(no gather/scatter data-dependence; FLOPs are dense but the *routing math*
and load-balance aux loss are faithful). Expert weights are stacked
(E, d, e_ff) and shard e_ff over the "model" axis (expert-tensor parallel) +
d over "fsdp" — expert counts (8, 64) need not divide the mesh.

A `dispatch="fused"` variant folds combine weights into the down-projection
contraction (no per-expert output tensor) — kept for §Perf comparison:
identical numerics, different lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical
from repro.models.common import dense_init, init_mlp, mlp
from repro.models.layers import shard_act


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, dtype),
        "w_gate": (jax.random.normal(ks[1], (E, d, e_ff)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, e_ff)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, e_ff, d)) *
                   (1.0 / jnp.sqrt(e_ff))).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, e_ff * cfg.n_shared_experts, dtype)
    return p


def router_probs(params: dict, cfg: ModelConfig, x: jax.Array):
    """Returns (combine_weights (..., E), aux_loss scalar)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)   # renormalize
    combine = jnp.zeros_like(probs)
    combine = jnp.put_along_axis(combine, top_idx, top_w, axis=-1,
                                 inplace=False)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = cfg.n_experts
    dims = tuple(range(probs.ndim - 1))
    frac_tokens = jnp.mean((combine > 0).astype(jnp.float32), axis=dims)
    frac_probs = jnp.mean(probs, axis=dims)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return combine.astype(x.dtype), aux


from repro.dist.sharding import axis_size

_DISPATCH = ["dense"]   # module default; launch code overrides


def set_dispatch(mode: str) -> None:
    assert mode in ("dense", "fused"), mode
    _DISPATCH[0] = mode


def _hg_spec(E: int, ndim: int):
    """Intermediate (..., E, e_ff) sharding: expert-parallel over "model"
    when E divides it (each device computes only its local experts on all
    tokens — dense-EP, the TPU-native MoE layout), else e_ff TP."""
    names = ["batch"] + [None] * (ndim - 1)
    if axis_size("model") > 1 and E % axis_size("model") == 0:
        names[-2] = "model"
    else:
        names[-1] = "model"
    return names


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array,
            dispatch: str | None = None):
    """x: (..., d) -> (out (..., d), aux_loss)."""
    dispatch = dispatch or _DISPATCH[0]
    combine, aux = router_probs(params, cfg, x)
    if dispatch == "dense":
        # every expert everywhere, gated: (..., E, e_ff)
        hg = jnp.einsum("...d,edf->...ef", x, params["w_gate"])
        hu = jnp.einsum("...d,edf->...ef", x, params["w_up"])
        hg = logical(hg, *_hg_spec(cfg.n_experts, hg.ndim))
        h = jax.nn.silu(hg) * hu
        per_exp = jnp.einsum("...ef,efd->...ed", h, params["w_down"])
        out = jnp.einsum("...ed,...e->...d", per_exp, combine)
    elif dispatch == "fused":
        # fold the combine weight into the down-projection contraction: the
        # (..., E, d) per-expert output tensor (the §Perf-measured memory
        # bomb: 17 GB/device for moonshot train_4k) never materializes, and
        # with expert-sharded weights the contraction over E psums across
        # the model axis — dense expert parallelism.
        hg = jnp.einsum("...d,edf->...ef", x, params["w_gate"])
        hu = jnp.einsum("...d,edf->...ef", x, params["w_up"])
        hg = logical(hg, *_hg_spec(cfg.n_experts, hg.ndim))
        h = jax.nn.silu(hg) * hu * combine[..., None].astype(x.dtype)
        out = jnp.einsum("...ef,efd->...d", h, params["w_down"])
    else:
        raise ValueError(dispatch)
    if cfg.n_shared_experts:
        out = out + mlp(params["shared"], x)
    return shard_act(out), aux * cfg.router_aux_coef
