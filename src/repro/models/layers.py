"""Linear application with optional LoRA path + activation sharding helpers.

LoRA convention (paper: ΔW = B·A; our storage is transposed to match the
(d_in, d_out) weight layout): a: (d_in, r), b: (r, d_out),
ΔW = a @ b, y = x@W + scale * (x@a)@b, scale = alpha / r.

A LoRA leaf may carry a leading *client* axis (m, d_in, r) when the input
carries a matching leading client axis (federated stacked evaluation) and/or
a leading scan-group axis handled by lax.scan slicing upstream.

Multi-adapter serving (repro.api.serving) passes leaves with an *adapter
pool* axis plus a per-batch-row slot map: {"a": (N, d_in, r),
"b": (N, r, d_out), "slot": (B,)} — row i of the activation applies adapter
``slot[i]``, dispatched through `kernels.ops.slot_lora_matmul` (in-kernel
gather on TPU, jnp oracle elsewhere). The slot map rides inside the lora
dict so the whole decode stack needs no extra plumbing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical


def lora_linear(x: jax.Array, w: jax.Array, lora: Optional[dict] = None,
                scale: float = 1.0, bias: Optional[jax.Array] = None):
    if lora is not None and "slot" in lora:
        return _slot_lora_linear(x, w, lora, scale, bias)
    y = jnp.einsum("...d,df->...f", x, w)
    if lora is not None:
        # compute the low-rank path in the activation dtype (bf16 on pod):
        # f32 master copies live in the optimizer; promoting x to f32 here
        # made GSPMD all-gather full activations (see EXPERIMENTS.md §Perf).
        a = lora["a"].astype(x.dtype)
        b = lora["b"].astype(x.dtype)
        if a.ndim == 3:
            # client-stacked LoRA: x (m, ..., d), a (m, d, r), b (m, r, f)
            xa = jnp.einsum("m...d,mdr->m...r", x, a)
            y = y + jnp.einsum("m...r,mrf->m...f", xa, b) * scale
        else:
            y = y + ((x @ a) @ b) * scale
    if bias is not None:
        y = y + bias
    return y


def _slot_lora_linear(x: jax.Array, w: jax.Array, lora: dict, scale: float,
                      bias: Optional[jax.Array]):
    """Adapter-pool application: leaf {"a": (N, d, r), "b": (N, r, f),
    "slot": (B,)}, x: (B, S, d) or (B, d) — row i applies adapter slot[i].
    The S == 1 decode hot path goes through the fused slot kernel; longer
    sequences (adapter-aware prefill) take the gather+einsum route."""
    from repro.kernels import ops   # deferred: kernels import jax.pallas

    a = lora["a"].astype(x.dtype)
    b = lora["b"].astype(x.dtype)
    slot = lora["slot"].astype(jnp.int32)
    if x.ndim == 2:
        y = ops.slot_lora_matmul(x, w, a, b, slot, scale)
    elif x.ndim == 3 and x.shape[1] == 1:
        y = ops.slot_lora_matmul(x[:, 0], w, a, b, slot, scale)[:, None]
    else:
        y = jnp.einsum("...d,df->...f", x, w)
        xa = jnp.einsum("bsd,bdr->bsr", x, a[slot])
        y = y + jnp.einsum("bsr,brf->bsf", xa, b[slot]) * scale
    if bias is not None:
        y = y + bias
    return y


def shard_act(x: jax.Array, last: Optional[str] = None) -> jax.Array:
    """Constrain an activation: leading dim over batch/clients; block
    outputs stay unsharded on d (Megatron all-reduced row-parallel output),
    intermediates pass last="model".

    When the bound axis map defines "seq_act" (sequence parallelism —
    §Perf variant), residual-stream activations additionally shard the
    sequence dim: all-reduces become reduce-scatter + all-gather pairs and
    the remat carry is stored sequence-sharded."""
    names: list = [None] * x.ndim
    if x.ndim >= 2:
        names[0] = "batch"
    if x.ndim >= 3 and last is None:
        names[-2] = "seq_act"   # unmapped in the baseline -> no-op
    names[-1] = last
    return logical(x, *names)
