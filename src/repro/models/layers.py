"""Linear application with optional LoRA path + activation sharding helpers.

LoRA convention (paper: ΔW = B·A; our storage is transposed to match the
(d_in, d_out) weight layout): a: (d_in, r), b: (r, d_out),
ΔW = a @ b, y = x@W + scale * (x@a)@b, scale = alpha / r.

A LoRA leaf may carry a leading *client* axis (m, d_in, r) when the input
carries a matching leading client axis (federated stacked evaluation) and/or
a leading scan-group axis handled by lax.scan slicing upstream.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical


def lora_linear(x: jax.Array, w: jax.Array, lora: Optional[dict] = None,
                scale: float = 1.0, bias: Optional[jax.Array] = None):
    y = jnp.einsum("...d,df->...f", x, w)
    if lora is not None:
        # compute the low-rank path in the activation dtype (bf16 on pod):
        # f32 master copies live in the optimizer; promoting x to f32 here
        # made GSPMD all-gather full activations (see EXPERIMENTS.md §Perf).
        a = lora["a"].astype(x.dtype)
        b = lora["b"].astype(x.dtype)
        if a.ndim == 3:
            # client-stacked LoRA: x (m, ..., d), a (m, d, r), b (m, r, f)
            xa = jnp.einsum("m...d,mdr->m...r", x, a)
            y = y + jnp.einsum("m...r,mrf->m...f", xa, b) * scale
        else:
            y = y + ((x @ a) @ b) * scale
    if bias is not None:
        y = y + bias
    return y


def shard_act(x: jax.Array, last: Optional[str] = None) -> jax.Array:
    """Constrain an activation: leading dim over batch/clients; block
    outputs stay unsharded on d (Megatron all-reduced row-parallel output),
    intermediates pass last="model".

    When the bound axis map defines "seq_act" (sequence parallelism —
    §Perf variant), residual-stream activations additionally shard the
    sequence dim: all-reduces become reduce-scatter + all-gather pairs and
    the remat carry is stored sequence-sharded."""
    names: list = [None] * x.ndim
    if x.ndim >= 2:
        names[0] = "batch"
    if x.ndim >= 3 and last is None:
        names[-2] = "seq_act"   # unmapped in the baseline -> no-op
    names[-1] = last
    return logical(x, *names)
