"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential recurrence with hidden mixing).

TPU adaptation (DESIGN.md §3/§7): the mLSTM parallel form is *chunkwise* —
quadratic only within chunks of length 256, with a stabilized (C, n, m)
matrix-memory state carried across chunks by lax.scan. This preserves the
O(S·C) compute/memory profile that makes xLSTM long_500k-capable, instead of
the O(S²) fully-parallel form.

Stabilized chunkwise mLSTM math (per head; f = sigmoid(f̃), i = exp(ĩ)):
  lf[t]  = Σ_{s<=t} log f[s]    (within-chunk cumulative log forget)
  m_loc[t] = max_{s<=t}(lf[t] - lf[s] + ĩ[s])
  m[t]   = max(m_prev + lf[t], m_loc[t])        (running stabilizer)
  intra  = Σ_s exp(lf[t]-lf[s]+ĩ[s]-m[t]) (qₜ·k_s/√dh) v_s
  inter  = exp(m_prev + lf[t] - m[t]) qₜ·C_prev
  n[t]   = matching normalizer; h[t] = (intra+inter)/max(|n[t]|, exp(-m[t]))
  state update uses the chunk-final stabilizer.

LoRA targets the q/k/v projections (paper recipe on any linear map).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, zeros
from repro.models.layers import lora_linear, shard_act
from repro.models.rglru import _causal_conv

_CHUNK = 256
_NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================

def _mlstm_dims(cfg: ModelConfig):
    inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    return inner, nh, inner // nh


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    inner, nh, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up_x": dense_init(ks[0], d, inner, dtype),
        "w_up_g": dense_init(ks[1], d, inner, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, inner)) *
                   0.1).astype(dtype),
        "conv_b": zeros(inner, dtype=dtype),
        "wq": dense_init(ks[3], inner, inner, dtype),
        "wk": dense_init(ks[4], inner, inner, dtype),
        "wv": dense_init(ks[5], inner, inner, dtype),
        "w_igate": dense_init(ks[6], inner, nh, dtype),
        "w_fgate": dense_init(ks[7], inner, nh, dtype),
        "b_igate": zeros(nh, dtype=dtype),
        # forget-gate bias init: strongly remember
        "b_fgate": (jnp.ones(nh) * 3.0).astype(dtype),
        "w_down": dense_init(jax.random.fold_in(key, 9), inner, d, dtype),
    }


def _mlstm_chunk(q, k, v, li, lfc, state):
    """One chunk. q/k/v: (B, nh, C, dh) f32; li/lfc: (B, nh, C) log-i and
    within-chunk cumulative log-f; state: (C_mat (B,nh,dh,dh), n (B,nh,dh),
    m (B,nh)). Returns (h (B,nh,C,dh), new_state)."""
    Bc = q.shape[2]
    dh = q.shape[-1]
    C_mat, n_vec, m_prev = state

    # pairwise decay: D[t,s] = lfc[t] - lfc[s] + li[s]  (s <= t)
    D = lfc[..., :, None] - lfc[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((Bc, Bc), dtype=bool))
    D = jnp.where(tri, D, _NEG)
    m_loc = jnp.max(D, axis=-1)                                # (B,nh,C)
    m_t = jnp.maximum(m_prev[..., None] + lfc, m_loc)          # (B,nh,C)

    w_intra = jnp.exp(D - m_t[..., None])                      # (B,nh,C,C)
    s_qk = jnp.einsum("bhtd,bhsd->bhts", q, k) / math.sqrt(dh)
    intra = jnp.einsum("bhts,bhsd->bhtd", w_intra * s_qk, v)
    n_intra = jnp.einsum("bhts,bhts->bht", w_intra, s_qk)

    w_inter = jnp.exp(m_prev[..., None] + lfc - m_t)           # (B,nh,C)
    inter = jnp.einsum("bhtd,bhde->bhte", q, C_mat) * w_inter[..., None]
    n_inter = jnp.einsum("bhtd,bhd->bht", q, n_vec) * w_inter

    n_tot = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_t))
    h = (intra + inter) / denom[..., None]

    # ---- state update at chunk end (stabilizer m_last) ----
    lf_last = lfc[..., -1]                                     # (B,nh)
    m_last = m_t[..., -1]
    # contribution of each s: exp(lf_last - lfc[s] + li[s] - m_last)
    w_upd = jnp.exp(lf_last[..., None] - lfc + li - m_last[..., None])
    C_new = (C_mat * jnp.exp(m_prev + lf_last - m_last)[..., None, None] +
             jnp.einsum("bhs,bhsd,bhse->bhde", w_upd, k, v))
    n_new = (n_vec * jnp.exp(m_prev + lf_last - m_last)[..., None] +
             jnp.einsum("bhs,bhsd->bhd", w_upd, k))
    return h, (C_new, n_new, m_last)


def mlstm_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                  lora: dict | None = None):
    """x: (..., S, d) -> (..., S, d)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    inner, nh, dh = _mlstm_dims(cfg)
    lead, S = x.shape[:-2], x.shape[-2]
    B = math.prod(lead) if lead else 1

    xu = lora_linear(x, params["w_up_x"], (lora or {}).get("w_up_x"), scale)
    xg = lora_linear(x, params["w_up_g"], (lora or {}).get("w_up_g"), scale)
    xc, _ = _causal_conv(xu, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)

    q = lora_linear(xc, params["wq"], (lora or {}).get("wq"), scale)
    k = lora_linear(xc, params["wk"], (lora or {}).get("wk"), scale)
    v = lora_linear(xu, params["wv"], (lora or {}).get("wv"), scale)
    ig = (xc @ params["w_igate"] + params["b_igate"]).astype(jnp.float32)
    fg = (xc @ params["w_fgate"] + params["b_fgate"]).astype(jnp.float32)

    def heads(z):
        return jnp.moveaxis(z.reshape(B, S, nh, dh), 1, 2).astype(jnp.float32)

    q, k, v = heads(q), heads(k), heads(v)
    li = jnp.moveaxis(ig.reshape(B, S, nh), 1, 2)              # log i = ĩ
    lf = jnp.moveaxis(jax.nn.log_sigmoid(fg).reshape(B, S, nh), 1, 2)

    C = min(_CHUNK, S)
    n_chunks = S // C
    assert S % C == 0, (S, C)

    q_c = jnp.moveaxis(q.reshape(B, nh, n_chunks, C, dh), 2, 0)
    k_c = jnp.moveaxis(k.reshape(B, nh, n_chunks, C, dh), 2, 0)
    v_c = jnp.moveaxis(v.reshape(B, nh, n_chunks, C, dh), 2, 0)
    li_c = jnp.moveaxis(li.reshape(B, nh, n_chunks, C), 2, 0)
    lf_c = jnp.moveaxis(lf.reshape(B, nh, n_chunks, C), 2, 0)

    state0 = (jnp.zeros((B, nh, dh, dh), jnp.float32),
              jnp.zeros((B, nh, dh), jnp.float32),
              jnp.full((B, nh), 0.0, jnp.float32))

    @jax.checkpoint
    def body(state, inp):
        qc, kc, vc, lic, lfcc = inp
        lfc_cum = jnp.cumsum(lfcc, axis=-1)
        h, new_state = _mlstm_chunk(qc, kc, vc, lic, lfc_cum, state)
        return new_state, h

    _, hs = jax.lax.scan(body, state0, (q_c, k_c, v_c, li_c, lf_c))
    # hs: (n_chunks, B, nh, C, dh) -> (B, S, inner)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, nh, S, dh)
    h = jnp.moveaxis(h, 1, 2).reshape(*lead, S, inner).astype(x.dtype)

    out = h * jax.nn.silu(xg)
    out = lora_linear(out, params["w_down"], (lora or {}).get("w_down"), scale)
    return shard_act(out)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    inner, nh, dh = _mlstm_dims(cfg)
    return {
        "C": zeros(batch, nh, dh, dh, dtype=jnp.float32),
        "n": zeros(batch, nh, dh, dtype=jnp.float32),
        "m": zeros(batch, nh, dtype=jnp.float32),
        "conv": zeros(batch, cfg.conv1d_width - 1, inner, dtype=dtype),
    }


def mlstm_state_spec(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    inner, nh, dh = _mlstm_dims(cfg)
    f = jax.ShapeDtypeStruct
    return {"C": f((batch, nh, dh, dh), jnp.float32),
            "n": f((batch, nh, dh), jnp.float32),
            "m": f((batch, nh), jnp.float32),
            "conv": f((batch, cfg.conv1d_width - 1, inner), dtype)}


def mlstm_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict,
                 lora: dict | None = None):
    """x: (B, 1, d); O(1) recurrent update."""
    scale = cfg.lora_alpha / cfg.lora_rank
    inner, nh, dh = _mlstm_dims(cfg)
    B = x.shape[0]
    xu = lora_linear(x, params["w_up_x"], (lora or {}).get("w_up_x"), scale)
    xg = lora_linear(x, params["w_up_g"], (lora or {}).get("w_up_g"), scale)
    xc, conv_state = _causal_conv(xu, params["conv_w"], params["conv_b"],
                                  state["conv"])
    xc = jax.nn.silu(xc)
    q = lora_linear(xc, params["wq"], (lora or {}).get("wq"), scale)
    k = lora_linear(xc, params["wk"], (lora or {}).get("wk"), scale)
    v = lora_linear(xu, params["wv"], (lora or {}).get("wv"), scale)
    q = q.reshape(B, nh, dh).astype(jnp.float32)
    k = k.reshape(B, nh, dh).astype(jnp.float32)
    v = v.reshape(B, nh, dh).astype(jnp.float32)
    li = (xc @ params["w_igate"] + params["b_igate"]) \
        .reshape(B, nh).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(xc @ params["w_fgate"] + params["b_fgate"]) \
        .reshape(B, nh).astype(jnp.float32)

    m_new = jnp.maximum(lf + state["m"], li)
    f_sc = jnp.exp(lf + state["m"] - m_new)
    i_sc = jnp.exp(li - m_new)
    C_new = (state["C"] * f_sc[..., None, None] +
             i_sc[..., None, None] * k[..., :, None] * v[..., None, :])
    n_new = state["n"] * f_sc[..., None] + i_sc[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new) / math.sqrt(dh)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)) / math.sqrt(dh)
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, inner).astype(x.dtype)
    out = h * jax.nn.silu(xg)
    out = lora_linear(out, params["w_down"], (lora or {}).get("w_down"), scale)
    return shard_act(out), {"C": C_new, "n": n_new, "m": m_new,
                            "conv": conv_state}


# ===========================================================================
# sLSTM
# ===========================================================================

def _slstm_dims(cfg: ModelConfig):
    nh = cfg.n_heads
    return nh, cfg.d_model // nh


def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    nh, dh = _slstm_dims(cfg)
    pf = cfg.slstm_proj_factor
    up = int(d * pf)
    ks = jax.random.split(key, 5)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),         # i f z o
        "r_gates": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) /
                    math.sqrt(dh)).astype(dtype),              # block-diag R
        "b_gates": jnp.concatenate(
            [zeros(d), jnp.ones(d) * 3.0, zeros(2 * d)]).astype(dtype),
        "w_ffn_gate": dense_init(ks[2], d, up, dtype),
        "w_ffn_up": dense_init(ks[3], d, up, dtype),
        "w_ffn_down": dense_init(ks[4], up, d, dtype),
    }


def _slstm_cell(params, x_t, state):
    """x_t: (B, 4d) pre-computed Wx contribution; state: dict of (B,nh,dh)."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    B, nh, dh = h.shape
    rec = jnp.einsum("bhd,hdo->bho", h, params["r_gates"])     # (B,nh,4dh)
    gates = x_t.reshape(B, nh, 4 * dh) + rec
    it, ft, zt, ot = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(lf + m - m_new)
    c_new = f_sc * c + i_sc * jnp.tanh(zt)
    n_new = f_sc * n + i_sc
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                  lora: dict | None = None):
    """x: (..., S, d); sequential lax.scan over time (sLSTM is inherently
    recurrent — hidden-state mixing forbids a parallel form)."""
    scale = cfg.lora_alpha / cfg.lora_rank
    nh, dh = _slstm_dims(cfg)
    lead, S, d = x.shape[:-2], x.shape[-2], x.shape[-1]
    B = math.prod(lead) if lead else 1

    wx = lora_linear(x, params["w_gates"], (lora or {}).get("w_gates"),
                     scale, params["b_gates"])                 # (...,S,4d)
    wx = wx.reshape(B, S, 4 * d)
    state0 = {k: jnp.zeros((B, nh, dh), jnp.float32) for k in "cnh"}
    state0["m"] = jnp.zeros((B, nh, dh), jnp.float32)

    def body(state, x_t):
        new = _slstm_cell(params, x_t, state)
        return new, new["h"]

    _, hs = jax.lax.scan(body, state0, jnp.moveaxis(wx, 0, 1))
    h = jnp.moveaxis(hs, 0, 1).reshape(*lead, S, d).astype(x.dtype)

    # post-cell gated FFN (proj factor 4/3)
    g = jax.nn.silu(h @ params["w_ffn_gate"]) * (h @ params["w_ffn_up"])
    out = g @ params["w_ffn_down"]
    return shard_act(out)


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    nh, dh = _slstm_dims(cfg)
    s = {k: zeros(batch, nh, dh, dtype=jnp.float32) for k in "cnhm"}
    return s


def slstm_state_spec(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    nh, dh = _slstm_dims(cfg)
    f = jax.ShapeDtypeStruct
    return {k: f((batch, nh, dh), jnp.float32) for k in "cnhm"}


def slstm_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict,
                 lora: dict | None = None):
    scale = cfg.lora_alpha / cfg.lora_rank
    B, _, d = x.shape
    wx = lora_linear(x, params["w_gates"], (lora or {}).get("w_gates"),
                     scale, params["b_gates"])[:, 0]           # (B, 4d)
    new = _slstm_cell(params, wx, state)
    h = new["h"].reshape(B, 1, d).astype(x.dtype)
    g = jax.nn.silu(h @ params["w_ffn_gate"]) * (h @ params["w_ffn_up"])
    out = g @ params["w_ffn_down"]
    return shard_act(out), new
