"""Model assembly: pattern-scanned decoder, whisper enc-dec, VLM.

Layers are given by ``cfg.pattern`` repeated ``cfg.n_groups`` times (params
stacked with a leading group axis, iterated by lax.scan — keeps HLO size
independent of depth) plus an explicit ``tail`` for patterns that do not
divide n_layers (recurrentgemma 26 = 8*3 + 2, gemma3 26 = 4*6 + 2).

The LoRA tree mirrors the params tree at the adapted weight leaves
({"a": (d_in,r), "b": (r,d_out)}), optionally with a leading client axis for
stacked federated evaluation (see repro.core.lora).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, CROSS, DENSE, MLSTM, MOE, NONE, RGLRU,
                                SLSTM, LayerSpec, ModelConfig)
from repro.dist.sharding import logical
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import embed_tokens, init_mlp, mlp, rmsnorm, unembed, zeros
from repro.models.layers import shard_act


# ===========================================================================
# Init
# ===========================================================================

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype,
                encdec_cross: bool) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": zeros(d, dtype=dtype)}
    if spec.kind in (ATTN, CROSS):
        p["attn"] = attn_mod.init_attn(ks[0], cfg, dtype)
    elif spec.kind == RGLRU:
        p["rglru"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    elif spec.kind == MLSTM:
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg, dtype)
    elif spec.kind == SLSTM:
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg, dtype)
    if encdec_cross and spec.kind == ATTN:
        p["norm_cross"] = zeros(d, dtype=dtype)
        p["cross"] = attn_mod.init_attn(ks[1], cfg, dtype)
    if spec.ffn == DENSE:
        p["norm2"] = zeros(d, dtype=dtype)
        p["ffn"] = init_mlp(ks[2], d, cfg.d_ff, dtype)
    elif spec.ffn == MOE:
        p["norm2"] = zeros(d, dtype=dtype)
        p["moe"] = moe_mod.init_moe(ks[2], cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Full parameter pytree for any assigned architecture."""
    d = cfg.d_model
    kE, kU, kG, kT, kenc = jax.random.split(key, 5)
    params: dict = {
        "embed": (jax.random.normal(kE, (cfg.vocab_padded, d)) *
                  0.02).astype(dtype),
        "final_norm": zeros(d, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(kU, (d, cfg.vocab_padded)) /
                             math.sqrt(d)).astype(dtype)
    encdec = cfg.family == "encdec"

    # scanned groups: per pattern position, leaves stacked (n_groups, ...)
    groups = []
    for j, spec in enumerate(cfg.pattern):
        per_group = [
            _init_layer(jax.random.fold_in(kG, j * 1000 + g), cfg, spec,
                        dtype, encdec)
            for g in range(cfg.n_groups)
        ]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                      if cfg.n_groups > 1 else
                      jax.tree.map(lambda x: x[None], per_group[0]))
    params["groups"] = groups
    params["tail"] = [
        _init_layer(jax.random.fold_in(kT, j), cfg, spec, dtype, encdec)
        for j, spec in enumerate(cfg.tail_pattern)
    ]

    if encdec:
        enc_layers = [
            _init_layer(jax.random.fold_in(kenc, j), cfg,
                        LayerSpec(kind=ATTN, ffn=DENSE), dtype, False)
            for j in range(cfg.enc_layers)
        ]
        params["encoder"] = {"layers": enc_layers,
                             "norm": zeros(d, dtype=dtype)}
    return params


def param_specs(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct tree of init_params without allocating (dry-run)."""
    return jax.eval_shape(partial(init_params, cfg=cfg, dtype=dtype),
                          jax.random.key(0))


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================

def _apply_layer(p: dict, cfg: ModelConfig, spec: LayerSpec, x, *,
                 memory, positions, lora: Optional[dict], encdec_cross: bool):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    # under sequence parallelism, re-materialize the full sequence ONCE per
    # sublayer here (Megatron-SP all-gather point); otherwise each q-chunk
    # slice gathers on its own (measured 937 GB/step on gemma3 — §Perf)
    h = logical(h, "batch", *((None,) * (h.ndim - 1)))
    lo = lora or {}
    if spec.kind == ATTN:
        y = attn_mod.attn_forward(p["attn"], cfg, h, window=spec.window,
                                  causal=True, lora=lo.get("attn"),
                                  positions=positions)
    elif spec.kind == CROSS:
        y = attn_mod.attn_forward(p["attn"], cfg, h, memory=memory,
                                  lora=lo.get("attn"))
    elif spec.kind == RGLRU:
        y = rglru_mod.rglru_forward(p["rglru"], cfg, h, lora=lo.get("rglru"))
    elif spec.kind == MLSTM:
        y = xlstm_mod.mlstm_forward(p["mlstm"], cfg, h, lora=lo.get("mlstm"))
    elif spec.kind == SLSTM:
        y = xlstm_mod.slstm_forward(p["slstm"], cfg, h, lora=lo.get("slstm"))
    else:
        raise ValueError(spec.kind)
    x = x + y.astype(x.dtype)
    if encdec_cross and spec.kind == ATTN:
        h = rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        y = attn_mod.attn_forward(p["cross"], cfg, h, memory=memory,
                                  lora=lo.get("cross"))
        x = x + y.astype(x.dtype)
    if spec.ffn == DENSE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act).astype(x.dtype)
    elif spec.ffn == MOE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, a = moe_mod.moe_ffn(p["moe"], cfg, h)
        x = x + y.astype(x.dtype)
        aux = aux + a
    return x, aux


def _encoder_forward(params: dict, cfg: ModelConfig, frontend: jax.Array,
                     lora: Optional[dict]):
    """Bidirectional encoder over stubbed frontend embeddings (whisper)."""
    x = frontend
    enc_lora = (lora or {}).get("encoder", {}) or {}
    for j, p in enumerate(params["encoder"]["layers"]):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        lo = enc_lora.get("layers", [None] * 99)
        lj = lo[j] if isinstance(lo, list) and j < len(lo) else None
        x = x + attn_mod.attn_forward(p["attn"], cfg, h, causal=False,
                                      lora=(lj or {}).get("attn"))
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act)
    return rmsnorm(x, params["encoder"]["norm"], cfg.norm_eps)


def hidden_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                   frontend: Optional[jax.Array] = None,
                   lora: Optional[dict] = None, remat: bool = True):
    """Backbone only: returns (hidden (..., S, d) post-final-norm, aux)."""
    x = embed_tokens(params["embed"], tokens) * math.sqrt(cfg.d_model)
    x = shard_act(x, None)
    S = tokens.shape[-1]
    positions = jnp.arange(S)
    encdec = cfg.family == "encdec"

    memory = None
    if encdec:
        memory = _encoder_forward(params, cfg, frontend, lora)
    elif cfg.family == "vlm":
        memory = frontend

    lo = lora or {}
    lo_groups = lo.get("groups", [None] * len(cfg.pattern))
    aux_total = jnp.zeros((), jnp.float32)

    # --- scanned pattern groups ---
    def group_body(carry, xs):
        x, aux = carry
        for j, spec in enumerate(cfg.pattern):
            x, a = _apply_layer(xs[0][j], cfg, spec, x, memory=memory,
                                positions=positions,
                                lora=xs[1][j] if xs[1] is not None else None,
                                encdec_cross=encdec)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    has_lora = any(g is not None for g in lo_groups)
    xs = (params["groups"], lo_groups if has_lora else None)
    (x, aux_total), _ = jax.lax.scan(
        body, (x, aux_total), xs,
        length=cfg.n_groups)

    # --- tail layers ---
    lo_tail = lo.get("tail", [None] * cfg.tail_len)
    for j, spec in enumerate(cfg.tail_pattern):
        x, a = _apply_layer(params["tail"][j], cfg, spec, x, memory=memory,
                            positions=positions, lora=lo_tail[j],
                            encdec_cross=encdec)
        aux_total = aux_total + a

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            frontend: Optional[jax.Array] = None,
            lora: Optional[dict] = None, remat: bool = True):
    """Full forward: (logits (..., S, V_pad), aux). Materializes logits —
    use lm_loss (chunked CE) for training at scale."""
    x, aux = hidden_forward(params, cfg, tokens, frontend=frontend,
                            lora=lora, remat=remat)
    logits = unembed(x, params.get("unembed", params["embed"]),
                     tied=cfg.tie_embeddings, softcap=cfg.logit_softcap)
    return logits, aux


# ===========================================================================
# Loss — chunked fused cross-entropy
# ===========================================================================

_CE_CHUNK = 512


def _chunk_ce(x_chunk, tgt_chunk, head, cfg: ModelConfig):
    """x: (..., C, d), tgt: (..., C) -> summed CE over the chunk.
    Never materializes more than (..., C, V) logits; f32 reduction."""
    logits = unembed(x_chunk, head, tied=cfg.tie_embeddings,
                     softcap=cfg.logit_softcap).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # target term as a one-hot contraction: local on the vocab-sharded dim
    # (take_along_axis backward scatter-adds across shards — §Perf iter 3)
    onehot = jax.nn.one_hot(tgt_chunk, cfg.vocab_padded,
                            dtype=logits.dtype)
    tgt = jnp.einsum("...v,...v->...", logits, onehot)
    per = lse - tgt
    if per.ndim <= 1:
        return jnp.sum(per)
    # per-leading-index partial sums (clients in the DFL round) — the
    # cross-index combine happens once, replicated, in `lm_loss`, so the
    # loss scalar has one arithmetic order on every process grid
    return jnp.sum(per, axis=tuple(range(1, per.ndim)))


def lm_loss(params: dict, cfg: ModelConfig, tokens: jax.Array,
            targets: jax.Array, *, frontend=None, lora=None,
            remat: bool = True, per_client: bool = False):
    """Next-token CE over the *logical* vocab (padded ids masked out).

    The unembed + softmax-CE is computed in sequence chunks under lax.scan
    (rematerialized), so full-sequence logits over huge vocabs (gemma3:
    262k) are never resident — the fix for the 210 GB/device dry-run bomb
    (EXPERIMENTS.md §Perf notes).

    CE accumulates per-leading-index (per-client) partial sums; the
    scalar is their flat combine. With ``per_client`` the return is
    ((loss, aux-tuple), per_client_mean_vec): the vector entries are
    shard-local, hence bitwise identical on every process grid — the DFL
    round reports loss from it host-side while the scalar feeds only the
    gradient (the MoE aux term keeps its plain mean; MoE archs are
    outside the multihost parity surface)."""
    x, aux = hidden_forward(params, cfg, tokens, frontend=frontend,
                            lora=lora, remat=remat)
    head = params.get("unembed", params["embed"])
    S = x.shape[-2]
    C = min(_CE_CHUNK, S)
    n_tok = targets.size
    lead = x.shape[:-2]

    if S % C != 0 or S <= C:
        total = _chunk_ce(x, targets, head, cfg)
    else:
        nc = S // C
        xc = jnp.moveaxis(x.reshape(*lead, nc, C, x.shape[-1]), -3, 0)
        tc = jnp.moveaxis(targets.reshape(*lead, nc, C), -2, 0)

        @jax.checkpoint
        def body(acc, inp):
            xi, ti = inp
            return acc + _chunk_ce(xi, ti, head, cfg), None

        total, _ = jax.lax.scan(
            body, jnp.zeros(lead[:1], jnp.float32), (xc, tc))

    ce = jnp.sum(total) / n_tok
    out = ce + aux, (ce, aux)
    if not per_client:
        return out
    vec = total / (n_tok // total.shape[0]) if total.ndim \
        else total[None] / n_tok
    return out, vec


# ===========================================================================
# Decode (one token through the whole stack)
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32, *, specs_only: bool = False,
               memory: Optional[jax.Array] = None, params=None,
               paging: Optional[tuple] = None) -> dict:
    """Cache pytree. ``specs_only`` returns ShapeDtypeStructs (dry-run).
    Cross-attention KV is precomputed at prefill; here it is allocated
    (zeros / specs) with the right shape.

    ``paging`` = (n_pages, page_size) switches GLOBAL attention layers
    (window=None) to the serving core's paged storage: their K/V live in
    a shared physical page pool and the cache gains a top-level
    ``"pages": {"table": (batch, P) int32}`` block table (P = max_len /
    page_size logical pages per slot, one table shared by every paged
    layer). Windowed layers keep their rolling caches — already O(window)
    memory, and an identical code path keeps them bitwise-trivially equal
    to the non-paged engine."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    f = jax.ShapeDtypeStruct
    if paging is not None:
        n_pages, page_size = paging
        if max_len % page_size != 0:
            raise ValueError(f"paged cache needs max_len % page_size == 0, "
                             f"got {max_len} % {page_size}")

    def attn_cache(window):
        if paging is not None and window is None:
            if specs_only:
                return attn_mod.paged_cache_spec(cfg, batch, n_pages,
                                                 page_size, dtype)
            return attn_mod.init_paged_cache(cfg, batch, n_pages, page_size,
                                             dtype)
        if specs_only:
            return attn_mod.cache_spec(cfg, batch, max_len, window, dtype)
        return attn_mod.init_cache(cfg, batch, max_len, window, dtype)

    def cross_cache():
        M = cfg.n_frontend_tokens
        if specs_only:
            return {"ck": f((batch, M, kv, hd), dtype),
                    "cv": f((batch, M, kv, hd), dtype)}
        return {"ck": zeros(batch, M, kv, hd, dtype=dtype),
                "cv": zeros(batch, M, kv, hd, dtype=dtype)}

    def layer_cache(spec: LayerSpec) -> dict:
        c: dict = {}
        if spec.kind == ATTN:
            c["kv"] = attn_cache(spec.window)
            if cfg.family == "encdec":
                c["cross"] = cross_cache()
        elif spec.kind == CROSS:
            c["cross"] = cross_cache()
        elif spec.kind == RGLRU:
            c["state"] = (rglru_mod.rglru_state_spec(cfg, batch, dtype)
                          if specs_only else
                          rglru_mod.init_rglru_state(cfg, batch, dtype))
        elif spec.kind == MLSTM:
            c["state"] = (xlstm_mod.mlstm_state_spec(cfg, batch, dtype)
                          if specs_only else
                          xlstm_mod.init_mlstm_state(cfg, batch, dtype))
        elif spec.kind == SLSTM:
            c["state"] = (xlstm_mod.slstm_state_spec(cfg, batch, dtype)
                          if specs_only else
                          xlstm_mod.init_slstm_state(cfg, batch, dtype))
        return c

    def stack_caches(spec: LayerSpec):
        one = layer_cache(spec)
        G = cfg.n_groups
        if specs_only:
            return jax.tree.map(
                lambda s: f((G, *s.shape), s.dtype), one,
                is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (G, *a.shape)), one)

    cache = {
        "groups": [stack_caches(spec) for spec in cfg.pattern],
        "tail": [layer_cache(spec) for spec in cfg.tail_pattern],
    }
    if paging is not None:
        P = max_len // page_size
        cache["pages"] = {"table": (f((batch, P), jnp.int32) if specs_only
                                    else jnp.zeros((batch, P), jnp.int32))}
    return cache


def _decode_layer(p: dict, cfg: ModelConfig, spec: LayerSpec, x, cache, *,
                  lora: Optional[dict], encdec_cross: bool,
                  pages: Optional[dict] = None):
    lo = lora or {}
    new_cache = dict(cache)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == ATTN:
        y, new_kv = attn_mod.attn_decode(p["attn"], cfg, h, cache["kv"],
                                         window=spec.window,
                                         lora=lo.get("attn"), pages=pages)
        new_cache["kv"] = new_kv
    elif spec.kind == CROSS:
        y, _ = attn_mod.attn_decode(p["attn"], cfg, h, {},
                                    cross_kv=(cache["cross"]["ck"],
                                              cache["cross"]["cv"]),
                                    lora=lo.get("attn"))
    elif spec.kind == RGLRU:
        y, st = rglru_mod.rglru_decode(p["rglru"], cfg, h, cache["state"],
                                       lora=lo.get("rglru"))
        new_cache["state"] = st
    elif spec.kind == MLSTM:
        y, st = xlstm_mod.mlstm_decode(p["mlstm"], cfg, h, cache["state"],
                                       lora=lo.get("mlstm"))
        new_cache["state"] = st
    elif spec.kind == SLSTM:
        y, st = xlstm_mod.slstm_decode(p["slstm"], cfg, h, cache["state"],
                                       lora=lo.get("slstm"))
        new_cache["state"] = st
    else:
        raise ValueError(spec.kind)
    x = x + y.astype(x.dtype)
    if encdec_cross and spec.kind == ATTN:
        h = rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        y, _ = attn_mod.attn_decode(p["cross"], cfg, h, {},
                                    cross_kv=(cache["cross"]["ck"],
                                              cache["cross"]["cv"]),
                                    lora=lo.get("cross"))
        x = x + y.astype(x.dtype)
    if spec.ffn == DENSE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act).astype(x.dtype)
    elif spec.ffn == MOE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h)
        x = x + y.astype(x.dtype)
    return x, new_cache


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict, *, lora: Optional[dict] = None):
    """tokens: (B, 1) -> (logits (B, 1, V_pad), new_cache). A cache built
    with ``paging`` carries its block table in ``cache["pages"]``; the
    table is data threaded through unchanged (decode never re-maps
    pages), so occupancy changes stay inside the one compiled step."""
    x = embed_tokens(params["embed"], tokens) * math.sqrt(cfg.d_model)
    encdec = cfg.family == "encdec"
    pages = cache.get("pages")
    lo = lora or {}
    lo_groups = lo.get("groups", [None] * len(cfg.pattern))
    has_lora = any(g is not None for g in lo_groups)

    def body(x, xs):
        gp, gc, gl = xs
        new_gc = []
        for j, spec in enumerate(cfg.pattern):
            x, nc = _decode_layer(gp[j], cfg, spec, x, gc[j],
                                  lora=gl[j] if gl is not None else None,
                                  encdec_cross=encdec, pages=pages)
            new_gc.append(nc)
        return x, new_gc

    xs = (params["groups"], cache["groups"],
          lo_groups if has_lora else None)
    x, new_group_caches = jax.lax.scan(body, x, xs, length=cfg.n_groups)

    lo_tail = lo.get("tail", [None] * cfg.tail_len)
    new_tail = []
    for j, spec in enumerate(cfg.tail_pattern):
        x, nc = _decode_layer(params["tail"][j], cfg, spec, x,
                              cache["tail"][j], lora=lo_tail[j],
                              encdec_cross=encdec, pages=pages)
        new_tail.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]),
                     tied=cfg.tie_embeddings, softcap=cfg.logit_softcap)
    new_cache = {"groups": new_group_caches, "tail": new_tail}
    if pages is not None:
        new_cache["pages"] = pages
    return logits, new_cache


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill covers pure-attention decoders (the serving-core
    archs). Recurrent kinds would need sequential state threading per
    chunk and enc-dec/VLM need memory plumbing — both fall back to the
    engine's teacher-forced prefill-by-decode."""
    specs = list(cfg.pattern) + list(cfg.tail_pattern)
    return (cfg.family not in ("encdec", "vlm") and
            all(s.kind == ATTN for s in specs))


def _chunk_prefill_layer(p: dict, cfg: ModelConfig, spec: LayerSpec, x,
                         cache, slot, start, limit, *,
                         lora: Optional[dict], pages: Optional[dict]):
    lo = lora or {}
    new_cache = dict(cache)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.kind != ATTN:
        raise NotImplementedError(
            f"chunked prefill supports attention-only decoders, got layer "
            f"kind {spec.kind!r} (see supports_chunked_prefill)")
    kv = cache["kv"]
    if "kp" in kv:
        y, new_kv = attn_mod.attn_chunk_paged(
            p["attn"], cfg, h, kv, pages["table"][slot], slot, start, limit,
            lora=lo.get("attn"))
    else:
        y, new_kv = attn_mod.attn_chunk_rolling(
            p["attn"], cfg, h, kv, slot, start, limit, lora=lo.get("attn"))
    new_cache["kv"] = new_kv
    x = x + y.astype(x.dtype)
    if spec.ffn == DENSE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act).astype(x.dtype)
    elif spec.ffn == MOE:
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h)
        x = x + y.astype(x.dtype)
    return x, new_cache


def chunk_prefill_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                       cache: dict, slot, start, limit, *,
                       lora: Optional[dict] = None) -> dict:
    """Stream one slot's prompt chunk into the serving cache.

    tokens: (1, C) — C is the engine's fixed chunk size (pad the final
    chunk; pads past ``limit`` neither write KV nor produce used output).
    slot / start / limit: () int32 — the batch row being prefilled, the
    chunk's absolute position offset, and the total real prefill length.
    Returns the new cache only (the engine teacher-forces the final
    prompt token through decode_step, which emits the first logits), so
    one compiled chunk trace serves every prompt length."""
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill unsupported for {cfg.name} "
            f"(attention-only decoders; see supports_chunked_prefill)")
    x = embed_tokens(params["embed"], tokens) * math.sqrt(cfg.d_model)
    pages = cache.get("pages")
    lo = lora or {}
    lo_groups = lo.get("groups", [None] * len(cfg.pattern))
    has_lora = any(g is not None for g in lo_groups)

    def body(x, xs):
        gp, gc, gl = xs
        new_gc = []
        for j, spec in enumerate(cfg.pattern):
            x, nc = _chunk_prefill_layer(
                gp[j], cfg, spec, x, gc[j], slot, start, limit,
                lora=gl[j] if gl is not None else None, pages=pages)
            new_gc.append(nc)
        return x, new_gc

    xs = (params["groups"], cache["groups"],
          lo_groups if has_lora else None)
    x, new_group_caches = jax.lax.scan(body, x, xs, length=cfg.n_groups)

    lo_tail = lo.get("tail", [None] * cfg.tail_len)
    new_tail = []
    for j, spec in enumerate(cfg.tail_pattern):
        x, nc = _chunk_prefill_layer(params["tail"][j], cfg, spec, x,
                                     cache["tail"][j], slot, start, limit,
                                     lora=lo_tail[j], pages=pages)
        new_tail.append(nc)

    new_cache = {"groups": new_group_caches, "tail": new_tail}
    if pages is not None:
        new_cache["pages"] = pages
    return new_cache


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            frontend: Optional[jax.Array] = None,
            lora: Optional[dict] = None):
    """Forward over the prompt; returns last-position logits only (serving).
    Unembeds ONLY the final hidden state — (B, S, V) logits are never
    materialized. (Cache build from prefill activations is exercised in
    serve.py at small scale; the 32k dry-run lowers this step.)"""
    x, _ = hidden_forward(params, cfg, tokens, frontend=frontend, lora=lora,
                          remat=False)
    return unembed(x[..., -1, :], params.get("unembed", params["embed"]),
                   tied=cfg.tie_embeddings, softcap=cfg.logit_softcap)
