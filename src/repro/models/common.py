"""Shared building blocks: init, norms, RoPE, gated MLP."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out)) * scale).astype(dtype)


def zeros(*shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype)


def ones(*shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype=dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d, d_ff, dtype),
        "wi_up": dense_init(k2, d, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d, dtype),
    }


def mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h_gate = x @ params["wi_gate"]
    h_up = x @ params["wi_up"]
    # NB: None dims in a sharding constraint mean REPLICATED — the batch
    # dim must be named or GSPMD all-gathers the client axis (§Perf iter 4)
    h_gate = logical(h_gate, "batch", *((None,) * (h_gate.ndim - 2)),
                     "model")
    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = act_fn(h_gate) * h_up
    out = h @ params["wo"]
    # row-parallel output: all-reduced, unsharded on d (sequence-sharded
    # under the seq-parallel §Perf variant)
    from repro.models.layers import shard_act
    return shard_act(out)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed, tokens, axis=0)


def unembed(x: jax.Array, embed_or_w: jax.Array, tied: bool,
            softcap: float = 0.0) -> jax.Array:
    if tied:
        logits = x @ embed_or_w.T
    else:
        logits = x @ embed_or_w
    logits = logical(logits, "batch", *((None,) * (logits.ndim - 2)),
                     "model")
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
