"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh):
  compute    = global_FLOPs / (chips × peak_FLOP/s)
  memory     = global_HBM_bytes / (chips × HBM_bw)
  collective = per_device_collective_bytes / link_bw
               (== global collective bytes / (chips × link_bw))

Sources:
  * FLOPs / HBM bytes — a jaxpr walker that recurses into scan/while/pjit
    with trip-count multipliers. XLA's compiled.cost_analysis() counts
    while bodies ONCE (verified empirically), so it undercounts scanned
    layer stacks by ~n_groups×; we report it alongside for reference.
  * Collective bytes — parsed from the partitioned HLO text
    (compiled.as_text()): per-computation sums of collective-op sizes,
    multiplied through while-loop known_trip_count backend configs.

Byte model (HBM term): matmul-dominated traffic — dot_general operands +
results, gather/scatter traffic, top-level I/O; elementwise chains are
assumed fused (XLA does on TPU). This is a *model*, stated as such in
EXPERIMENTS.md.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (task spec).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from functools import reduce

import jax
import numpy as np


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link


# ===========================================================================
# jaxpr cost walker
# ===========================================================================

_ELEMENTWISE_FLOP_PRIMS = {
    "exp", "log", "tanh", "logistic", "sin", "cos", "rsqrt", "sqrt",
    "add", "sub", "mul", "div", "max", "min", "pow", "integer_pow",
    "erf", "cumsum", "cumlogsumexp",
}
_TRAFFIC_PRIMS = {
    "gather", "scatter", "scatter-add", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "sort",
}


def _size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = reduce(lambda a, b: a * b, (lhs.shape[d] for d in lc), 1)
    return 2 * int(np.prod(out.shape)) * int(k)


def jaxpr_cost(closed_jaxpr) -> dict:
    """Walk a ClosedJaxpr: returns {"flops", "bytes"} (global, scan-aware)."""

    def walk(jaxpr, mult: float):
        flops = 0.0
        byts = 0.0
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "dot_general":
                flops += mult * _dot_flops(eqn)
                byts += mult * (sum(_size_bytes(v.aval) for v in eqn.invars) +
                                sum(_size_bytes(v.aval) for v in eqn.outvars))
            elif prim == "scan":
                inner = eqn.params["jaxpr"].jaxpr
                f, b = walk(inner, mult * eqn.params["length"])
                flops += f
                byts += b
            elif prim == "while":
                # without a static trip count, count the body once (rare in
                # this codebase — all loops are scans)
                f, b = walk(eqn.params["body_jaxpr"].jaxpr, mult)
                flops += f
                byts += b
            elif prim == "cond":
                branch_costs = [walk(br.jaxpr, mult)
                                for br in eqn.params["branches"]]
                f, b = max(branch_costs)
                flops += f
                byts += b
            elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                          "custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr", "checkpoint"):
                sub = (eqn.params.get("jaxpr") or
                       eqn.params.get("call_jaxpr") or
                       eqn.params.get("fun_jaxpr"))
                if sub is not None:
                    inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    f, b = walk(inner, mult)
                    flops += f
                    byts += b
            elif prim in _TRAFFIC_PRIMS:
                byts += mult * (sum(_size_bytes(v.aval) for v in eqn.invars) +
                                sum(_size_bytes(v.aval) for v in eqn.outvars))
            elif prim in _ELEMENTWISE_FLOP_PRIMS:
                flops += mult * sum(_size_bytes(v.aval) //
                                    max(v.aval.dtype.itemsize, 1)
                                    for v in eqn.outvars)
        return flops, byts

    f, b = walk(closed_jaxpr.jaxpr, 1.0)
    # top-level I/O traffic
    io = (sum(_size_bytes(v.aval) for v in closed_jaxpr.jaxpr.invars) +
          sum(_size_bytes(v.aval) for v in closed_jaxpr.jaxpr.outvars))
    return {"flops": float(f), "bytes": float(b + io)}


# ===========================================================================
# HLO collective parser
# ===========================================================================

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_OP_RE = re.compile(r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]\S*))\s+([\w\-]+)\(")
_WHILE_RE = re.compile(
    r"while\(.*?body=(%?[\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\"\':{ ]+n[\"\': ]+(\d+)')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device collective bytes, trip-count aware.

    Returns {"total": bytes, "by_type": {...}, "ops": count}.
    """
    # --- split into computations ---
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            name = stripped.split(" ")[0].lstrip("%")
            if name == "ENTRY":
                name = stripped.split(" ")[1].lstrip("%")
            cur = name
            comps[cur] = []
        elif stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)

    # --- per-computation raw collective bytes + while edges ---
    raw: dict[str, dict] = {}
    edges: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        by_type = {c: 0 for c in _COLLECTIVES}
        ops = 0
        edge_list = []
        for ln in lines:
            for coll in _COLLECTIVES:
                if f" {coll}(" in ln or f"= {coll}(" in ln:
                    lhs = ln.split(f"{coll}(")[0]
                    by_type[coll] += _shape_bytes(lhs)
                    ops += 1
                    break
            if " while(" in ln:
                mb = _WHILE_RE.search(ln)
                mt = _TRIP_RE.search(ln)
                if mb:
                    trip = int(mt.group(1)) if mt else 1
                    edge_list.append((mb.group(1).lstrip("%"), trip))
        raw[name] = {"by_type": by_type, "ops": ops}
        edges[name] = edge_list

    # --- entry computation ---
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split(" ")[1].lstrip("%")
            break
    if entry is None:
        entry = next(iter(comps), None)

    # HLO splits fusions/regions into separate computations that are
    # *called* rather than while-looped; calls/fusions of computation C have
    # C inlined cost-wise. We approximate: accumulate via while edges from
    # the entry; called computations (fusion/conditional bodies) with
    # collectives are rare — add any computation not reachable via while
    # edges once.
    memo: dict[str, tuple[dict, int]] = {}

    def total_of(name, depth=0) -> tuple[dict, int]:
        if name in memo or depth > 50 or name not in raw:
            return memo.get(name, ({c: 0 for c in _COLLECTIVES}, 0))
        by_type = dict(raw[name]["by_type"])
        ops = raw[name]["ops"]
        for child, trip in edges.get(name, []):
            cb, co = total_of(child, depth + 1)
            for c in _COLLECTIVES:
                by_type[c] += cb[c] * trip
            ops += co * trip
        memo[name] = (by_type, ops)
        return memo[name]

    reachable: set[str] = set()

    def mark(name, depth=0):
        if name in reachable or depth > 50:
            return
        reachable.add(name)
        for child, _ in edges.get(name, []):
            mark(child, depth + 1)

    if entry:
        mark(entry)
    by_type, ops = total_of(entry) if entry else ({c: 0 for c in
                                                   _COLLECTIVES}, 0)
    # add un-reached computations once (e.g. conditional branches)
    for name in raw:
        if name not in reachable and raw[name]["ops"]:
            # skip while condition/body already handled via edges? bodies are
            # reachable; conditions rarely hold collectives — include once.
            for c in _COLLECTIVES:
                by_type[c] += raw[name]["by_type"][c]
            ops += raw[name]["ops"]

    return {"total": float(sum(by_type.values())),
            "by_type": {k: float(v) for k, v in by_type.items()},
            "ops": int(ops)}


# ===========================================================================
# Roofline assembly
# ===========================================================================

def model_flops(cfg, n_tokens: int, *, training: bool) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens.
    Inference (forward only) uses 2·N·D."""
    n = cfg.active_param_count()
    per_tok = 6 * n if training else 2 * n
    return float(per_tok) * n_tokens


def roofline_report(*, flops: float, hbm_bytes: float,
                    coll_bytes_per_device: float, n_chips: int,
                    model_fl: float, hw: HW = HW()) -> dict:
    t_compute = flops / (n_chips * hw.peak_flops)
    t_memory = hbm_bytes / (n_chips * hw.hbm_bw)
    t_coll = coll_bytes_per_device / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    total = max(terms.values())
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "step_time_bound_s": total,
        "model_flops": model_fl,
        "useful_compute_ratio": (model_fl / flops) if flops else 0.0,
        "mfu_bound": (model_fl / (n_chips * hw.peak_flops)) / total
        if total else 0.0,
    }
