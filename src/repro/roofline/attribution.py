"""Collective attribution: WHERE do the bytes go?

Turns a partitioned HLO module into a ranked table of
(collective type, op_name, shape) -> trip-count-multiplied bytes.
This is the tool that found every §Perf lever in EXPERIMENTS.md: sharding
bugs show up as absurd entries (full-batch gathers, f32 score all-reduces)
long before any hardware run would.

Usage:
    lowered = jax.jit(step).lower(*specs)
    rows = attribute_collectives(lowered.compile().as_text())
    print(format_table(rows))
"""
from __future__ import annotations

import collections
import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "s16": 2,
    "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_BODY_RE = re.compile(r"body=(%?[\w\.\-]+)")
_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


@dataclass
class CollectiveRow:
    kind: str
    op_name: str
    shape: str
    bytes_total: float      # trip-multiplied, per device
    occurrences: int


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            name = s.split(" ")[0].lstrip("%")
            if name == "ENTRY":
                name = s.split(" ")[1].lstrip("%")
            cur = name
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(s)
    return comps


def attribute_collectives(hlo_text: str, top: int = 20) -> list[CollectiveRow]:
    comps = _split_computations(hlo_text)

    # while-edge graph -> per-computation execution multiplier
    edges = collections.defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                mb = _BODY_RE.search(ln)
                mt = _TRIP_RE.search(ln)
                if mb:
                    edges[name].append((mb.group(1).lstrip("%"),
                                        int(mt.group(1)) if mt else 1))
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split(" ")[1].lstrip("%")
            break
    mult: dict[str, int] = collections.defaultdict(int)

    def walk(name, m, depth=0):
        if depth > 40:
            return
        mult[name] += m
        for child, trip in edges.get(name, []):
            walk(child, m * trip, depth + 1)

    if entry:
        walk(entry, 1)

    agg: dict[tuple, list] = {}
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        for ln in lines:
            for kind in _COLLECTIVES:
                if f" {kind}(" in ln:
                    lhs = ln.split(f"{kind}(")[0]
                    b = _shape_bytes(lhs) * m
                    mo = _OPNAME_RE.search(ln)
                    op = re.sub(r"\s*stack_frame_id.*", "",
                                mo.group(1)) if mo else "?"
                    sh = _SHAPE_RE.search(lhs)
                    key = (kind, op[-100:], sh.group(0) if sh else "?")
                    if key not in agg:
                        agg[key] = [0.0, 0]
                    agg[key][0] += b
                    agg[key][1] += m
                    break

    rows = [CollectiveRow(kind=k, op_name=o, shape=s, bytes_total=v[0],
                          occurrences=v[1])
            for (k, o, s), v in agg.items()]
    rows.sort(key=lambda r: -r.bytes_total)
    return rows[:top]


def format_table(rows: list[CollectiveRow]) -> str:
    out = [f"{'GB':>9} {'x':>6} {'kind':<18} {'shape':<26} op_name (tail)"]
    for r in rows:
        out.append(f"{r.bytes_total/1e9:9.2f} {r.occurrences:>6} "
                   f"{r.kind:<18} {r.shape:<26} …{r.op_name[-70:]}")
    return "\n".join(out)
