"""Multi-adapter serving benchmark -> BENCH_serving.json.

Measures the continuous-batching engine's decode throughput (tokens/s)
over n_slots x n_adapters, against the merged-adapter baseline (adapter
folded into the base weights — zero per-token adapter cost, but ONE model
per adapter), and asserts the one-compile invariant: a fixed-capacity
`AdapterPool` serves 1, 4, or 8 distinct adapters through a single traced
decode_step, so the multi-adapter column's overhead is pure per-slot
gather + rank-r matmul work, never recompilation.

A second section drives the paged serving core under synthetic Poisson
traffic (seeded exponential inter-arrivals, mixed prompt lengths and
adapters, a page pool deliberately smaller than n_slots x max_len so
eviction is live): tok/s, p50/p99 request latency, TTFT, and the maximum
number of simultaneously decoding streams sustained — the scheduler /
page-pool counterpart of the steady-state rows above.

  PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.serving import AdapterPool, ServingSession
from repro.configs import get_config
from repro.core.lora import build_lora_tree, merge_lora
from repro.launch.serving import ServeEngine

_ARCH = "gemma3-1b"
_N_POOL = 8                   # distinct adapters in the pool


def _random_stacked_lora(params, cfg, n: int):
    """n distinct nonzero adapters stacked on axis -3 (b-factors are zero
    at init, so randomize both to make adapters actually differ)."""
    tree = build_lora_tree(jax.random.key(7), params, cfg, n_clients=n)
    c = [0]

    def fill(x):
        c[0] += 1
        return 0.05 * jax.random.normal(jax.random.key(c[0]), x.shape)
    return jax.tree.map(fill, tree)


def _drain(engine, prompts, adapters, gen: int) -> float:
    """Submit one request per prompt (adapter i mod len(adapters)) and
    drain; returns generated tokens/s."""
    for i, p in enumerate(prompts):
        engine.submit(p, max_new=gen,
                      adapter=adapters[i % len(adapters)] if adapters
                      else None)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    return len(prompts) * gen / dt


def traffic(params, cfg, stacked, *, n_slots: int = 4, n_requests: int = 24,
            rate: float = 0.5, seed: int = 0, quick: bool = True) -> dict:
    """Poisson open-loop traffic through the paged + chunked-prefill +
    DRR-scheduled path. ``rate`` is the mean arrival rate in requests per
    engine tick; the page pool holds ~60% of full per-slot coverage so
    bursts trigger preemption-by-eviction rather than OOM."""
    gen = 12 if quick else 32
    page_size = 8
    max_len = 64 if quick else 128
    pages_full = n_slots * (max_len // page_size)
    n_pages = 1 + max(max_len // page_size,
                      int(0.4 * pages_full))        # contention by design
    pool = AdapterPool.from_stacked(stacked, consensus=False)
    serving = ServingSession(model_cfg=cfg, params=params, adapters=pool,
                             n_slots=n_slots, max_len=max_len, paged=True,
                             page_size=page_size, n_pages=n_pages,
                             prefill_chunk=page_size)
    eng = serving.engine
    names = [f"client_{i}" for i in range(_N_POOL)]

    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    prompt_lens = rng.integers(2, 20, size=n_requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in prompt_lens]

    # warmup: compile decode + chunk steps outside the timed window
    serving.generate(prompts[0], adapter=names[0], max_new=2)

    nxt = 0
    max_streams = 0
    t0 = time.perf_counter()
    while nxt < n_requests or eng.scheduler.n_queued or \
            any(s.req is not None for s in eng.slots):
        while nxt < n_requests and arrive[nxt] <= eng.ticks:
            serving.submit(prompts[nxt], adapter=names[nxt % len(names)],
                           max_new=gen)
            nxt += 1
        max_streams = max(max_streams, eng.tick())
    dt = time.perf_counter() - t0

    m = serving.metrics()
    done = [r for r in eng.requests.values() if r.done]
    tok_total = sum(len(r.tokens_out) for r in done)
    out = {
        "n_requests": n_requests, "rate_per_tick": rate,
        "n_slots": n_slots, "page_size": page_size, "n_pages": n_pages,
        "gen_tokens": gen,
        "tok_s": round(tok_total / dt, 2),
        "latency_p50_ms": round(m["latency_s"]["p50"] * 1e3, 2),
        "latency_p99_ms": round(m["latency_s"]["p99"] * 1e3, 2),
        "ttft_p50_ms": round(m["ttft_s"]["p50"] * 1e3, 2),
        "max_streams": max_streams,
        "preemptions": m["preemptions"],
        "device_steps": m["device_steps"],
        "compile_count": serving.compile_count,
        "prefill_compile_count": eng.prefill.compile_count,
    }
    assert m["completed"] == n_requests + 1          # +1 warmup
    return out


def run(quick: bool = True, json_path: str = "BENCH_serving.json") -> dict:
    cfg = get_config(_ARCH).reduced()
    params = tf_init(cfg)
    stacked = _random_stacked_lora(params, cfg, _N_POOL)
    gen = 16 if quick else 32
    prompt_len = 4 if quick else 16
    rng = np.random.default_rng(0)

    rows = []
    one_compile = True
    for n_slots in (4, 8):
        prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
                   .astype(np.int32) for _ in range(n_slots)]
        max_len = prompt_len + gen + 8

        # merged baseline: adapter 0 folded into the base weights
        merged = merge_lora(params, jax.tree.map(lambda x: x[..., 0, :, :],
                                                 stacked), cfg)
        eng_m = ServeEngine(merged, cfg, n_slots=n_slots, max_len=max_len)
        _drain(eng_m, prompts, None, gen)          # warmup/compile
        tok_m = _drain(eng_m, prompts, None, gen)
        rows.append({"n_slots": n_slots, "mode": "merged", "n_adapters": 1,
                     "tok_s": round(tok_m, 2)})

        # multi-adapter: ONE engine, ONE compile across every n_adapters
        pool = AdapterPool.from_stacked(stacked, consensus=False)
        serving = ServingSession(model_cfg=cfg, params=params,
                                 adapters=pool, n_slots=n_slots,
                                 max_len=max_len)
        names = [f"client_{i}" for i in range(_N_POOL)]
        _drain(serving.engine, prompts, names, gen)   # warmup/compile
        for n_adapters in (1, 4, 8):
            tok = _drain(serving.engine, prompts, names[:n_adapters], gen)
            overhead = (tok_m / tok - 1.0) * 100.0
            rows.append({"n_slots": n_slots, "mode": "multi",
                         "n_adapters": n_adapters, "tok_s": round(tok, 2),
                         "overhead_vs_merged_pct": round(overhead, 1)})
        if serving.compile_count != 1:
            one_compile = False
    assert one_compile, "decode_step retraced across adapter counts"

    print(f"{'slots':>5} {'mode':>7} {'n_ad':>4} {'tok/s':>9} "
          f"{'vs merged':>9}")
    for r in rows:
        ov = r.get("overhead_vs_merged_pct")
        print(f"{r['n_slots']:>5} {r['mode']:>7} {r['n_adapters']:>4} "
              f"{r['tok_s']:>9.1f} {(f'{ov:+.1f}%' if ov is not None else '—'):>9}")
    print(f"one compiled decode_step across n_adapters in {{1,4,8}}: "
          f"{one_compile}")

    tr = traffic(params, cfg, stacked, quick=quick)
    print(f"traffic: {tr['n_requests']} reqs @ {tr['rate_per_tick']}/tick "
          f"-> {tr['tok_s']:.1f} tok/s, p50 {tr['latency_p50_ms']:.0f} ms, "
          f"p99 {tr['latency_p99_ms']:.0f} ms, max {tr['max_streams']} "
          f"streams, {tr['preemptions']} preemptions")
    assert tr["compile_count"] == 1, "traffic path retraced decode_step"

    result = {"arch": _ARCH, "backend": jax.default_backend(),
              "gen_tokens": gen, "rows": rows, "one_compile": one_compile,
              "traffic": tr}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {json_path}")
    return result


def tf_init(cfg):
    from repro.models import transformer as tf
    return tf.init_params(jax.random.key(0), cfg)


if __name__ == "__main__":
    run(quick=True)
