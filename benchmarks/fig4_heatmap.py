"""Paper Fig. 4/5: accuracy gain of TAD-LoRA over the LoRA baseline on MNLI
across (p, T) — the non-monotonic (U-shaped-in-T) landscape."""
from __future__ import annotations

from benchmarks.common import Setting, mean_over_seeds, sweep

T_GRID = (1, 2, 3, 5, 10, 15)
P_GRID = (0.5, 0.1, 0.02)
SEEDS = (0, 1)


def run(quick: bool = True):
    seeds = SEEDS[:1] if quick else SEEDS
    t_grid = (1, 3, 10) if quick else T_GRID
    settings = [Setting(method="tad", task="mnli", p=p, T=T, seed=s)
                for p in P_GRID for T in t_grid for s in seeds]
    settings += [Setting(method="lora", task="mnli", p=p, T=1, seed=s)
                 for p in P_GRID for s in seeds]
    results = sweep(settings)

    print("\n=== Fig.4: TAD−LoRA accuracy gain on MNLI over (p, T) ===")
    corner = "p\\T"
    print(f"{corner:>6} " + " ".join(f"{T:>8}" for T in t_grid))
    grid = {}
    # absolute accuracies ride along for the regression gate: gains hover
    # near zero in strong regimes, and a near-zero baseline can't anchor a
    # ratio-based check
    absolute = {}
    for p in P_GRID:
        base = mean_over_seeds(results, seeds=list(seeds), method="lora",
                               task="mnli", p=p)[0]
        row = []
        best = float("-inf")
        for T in t_grid:
            acc = mean_over_seeds(results, seeds=list(seeds), method="tad",
                                  task="mnli", p=p, T=T)[0]
            row.append(acc - base)
            grid[(p, T)] = acc - base
            best = max(best, acc)
        absolute[p] = {"lora_acc": base, "tad_best_acc": best}
        print(f"{p:>6} " + " ".join(f"{g:+8.4f}" for g in row))
    return {"grid": {f"{p}|{T}": g for (p, T), g in grid.items()},
            "absolute": {str(p): a for p, a in absolute.items()}}


if __name__ == "__main__":
    run(quick=False)
