"""Round-loop overhead: `repro.api.Session` vs the hand-wired legacy loop.

The api redesign replaced seven hand-wired round loops with one Session;
this benchmark proves the abstraction adds no dispatch overhead. Both
sides drive the SAME jitted round function (m=10 clients, 60 rounds,
the benchmark-harness classifier at reduced width): the legacy side is
the pre-redesign loop body (iterate batches, sample W, static masks,
call round_fn), the Session side is `Session.run()` with no callbacks.
Per-round wall time is the min over repetitions; the result goes to
BENCH_round_loop.json as part of the repo's recorded perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.api import DFLConfig, Session
from repro.core import make_topology, round_masks
from repro.data import federated_batches, label_skew_partitions

M = 10
ROUNDS = 60
MODEL_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _config(rounds: int) -> DFLConfig:
    return DFLConfig(model="encoder", task="sst2", model_kw=MODEL_KW,
                     n_clients=M, p=0.5, method="tad", T=3, rounds=rounds,
                     local_steps=2, batch_size=8, lr=1e-3, seed=0)


def _legacy_loop(session: Session, rounds: int) -> float:
    """The pre-api loop body, wired around the session's own compiled
    round — measures exactly the loop/dispatch difference."""
    cfg = session.config
    session.reset_state()
    parts = label_skew_partitions(session.task.n_classes, cfg.n_clients)
    topo = make_topology(cfg.topology, cfg.n_clients, cfg.p, seed=cfg.seed)
    lora, opt_state = session.lora, session.opt.init(session.lora)
    t0 = time.perf_counter()
    for t, batch in enumerate(federated_batches(
            session.task, parts, cfg.batch_size, cfg.local_steps, rounds,
            seed=cfg.data_seed)):
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks(cfg.method, t, cfg.T).as_array()
        lora, opt_state, metrics = session.round_fn(
            session.base, lora, opt_state,
            jax.tree.map(jnp.asarray, batch), W, masks)
    jax.block_until_ready(lora)
    return time.perf_counter() - t0


def _session_loop(session: Session, rounds: int) -> float:
    session.reset_state()
    t0 = time.perf_counter()
    session.run(rounds)
    return time.perf_counter() - t0


def run(quick: bool = True, json_path: str | None = None) -> dict:
    rounds = ROUNDS
    # min over interleaved reps: per-round work is ~6ms on CPU, so the
    # floor needs several reps to shake scheduler noise out of a ±3% band
    reps = 5 if quick else 9
    session = Session(_config(rounds))

    # one warmup pass each (compile + caches), then timed reps interleaved
    # with the in-pair order ALTERNATING (LS, SL, LS, ...): interleaving
    # spreads slow drift across both sides, alternation cancels the
    # within-pair bias a monotone load ramp would otherwise put on
    # whichever loop runs second
    _legacy_loop(session, 5)
    _session_loop(session, 5)
    legacy_ts, sess_ts = [], []
    for r in range(reps):
        if r % 2 == 0:
            legacy_ts.append(_legacy_loop(session, rounds))
            sess_ts.append(_session_loop(session, rounds))
        else:
            sess_ts.append(_session_loop(session, rounds))
            legacy_ts.append(_legacy_loop(session, rounds))
    legacy, sess = min(legacy_ts), min(sess_ts)

    legacy_us = legacy / rounds * 1e6
    sess_us = sess / rounds * 1e6
    overhead_pct = (sess_us - legacy_us) / legacy_us * 100.0
    payload = {
        "backend": jax.default_backend(),
        "m": M, "rounds": rounds, "reps": reps,
        "legacy_us_per_round": round(legacy_us, 1),
        "session_us_per_round": round(sess_us, 1),
        "overhead_pct": round(overhead_pct, 2),
    }
    print("\n=== round-loop dispatch overhead (Session vs legacy loop) ===")
    print("loop,us_per_round")
    print(f"legacy,{legacy_us:.1f}")
    print(f"session,{sess_us:.1f}")
    print(f"overhead: {overhead_pct:+.2f}%")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="more repetitions")
    ap.add_argument("--json", default="BENCH_round_loop.json")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
