"""Kernel microbenchmarks: wall-time of the dispatch path on this backend
(CPU -> jnp reference; interpret-mode checked for correctness only — Pallas
timing is meaningless off-TPU) + analytic kernel roofline on v5e, plus the
mixing-lowering comparison (per-leaf oracle vs MixPlan fused path) that
feeds BENCH_mixing.json — the start of the repo's recorded perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing
from repro.kernels import ops
from repro.roofline.analysis import HW


def _time(fn, *args, iters=5):
    """Mean wall us/call. Readies the warmup AND every timed result (a
    single block on the last iteration lets earlier dispatches overlap the
    timer and under-report)."""
    jax.block_until_ready(fn(*args))  # compile + warmup
    t0 = time.perf_counter()
    results = [fn(*args) for _ in range(iters)]
    jax.block_until_ready(results)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def _synthetic_lora_tree(key, m: int, P: int, d: int = 512, r: int = 8):
    """Many-leaved client-stacked LoRA tree with ~P columns per client —
    the shape regime where per-leaf dispatch overhead dominates. Mirrors
    the real layout: plain (m, d, r) a/b pairs plus one group-stacked
    (G, m, d, r) pair."""
    pair_cols = 2 * d * r
    n_pairs = max(1, P // pair_cols)
    g_pairs = max(1, n_pairs // 8)        # 1/8 of pairs in one (G, ...) leaf
    n_plain = max(1, n_pairs - g_pairs)
    layers = []
    for i in range(n_plain):
        k = jax.random.fold_in(key, i)
        layers.append({"wq": {
            "a": jax.random.normal(jax.random.fold_in(k, 0), (m, d, r)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (m, r, d)),
        }})
    kg = jax.random.fold_in(key, 10_000)
    stacked = {"wv": {
        "a": jax.random.normal(jax.random.fold_in(kg, 0), (g_pairs, m, d, r)),
        "b": jax.random.normal(jax.random.fold_in(kg, 1), (g_pairs, m, r, d)),
    }}
    return {"groups": [stacked], "tail": layers}


def mixing_bench(quick: bool = True):
    """per-leaf vs planned-fused mixing wall time over (m, P) grid.

    Masks are passed as traced scalars — exactly how the compiled DFL
    round feeds them (method/phase may not trigger recompilation), so
    per_leaf pays its real per-leaf blend rather than letting XLA
    constant-fold literal 1.0 masks away."""
    rows = []
    log_ps = (18, 20) if quick else (18, 20, 22)
    one = jnp.float32(1.0)
    # which lowering mix_tree_planned picks on this backend (flat kernel
    # under mesh/TPU vs cache-local per-slot dots) — recorded per row so
    # the perf trajectory stays comparable across backends
    lowering = "flat" if mixing.use_flat_lowering() else "per_slot"
    for m in (10, 64):
        for log_p in log_ps:
            P = 1 << log_p
            key = jax.random.fold_in(jax.random.key(7), m * 100 + log_p)
            tree = _synthetic_lora_tree(key, m, P)
            n_leaves = len(jax.tree.leaves(tree))
            W = jnp.full((m, m), 1.0 / m, jnp.float32)
            per_leaf = jax.jit(
                lambda W, t, a, b: mixing.mix_tree(W, t, a, b))
            planned = jax.jit(
                lambda W, t, a, b: mixing.mix_tree_planned(W, t, a, b))
            us_pl = _time(per_leaf, W, tree, one, one, iters=3)
            us_fu = _time(planned, W, tree, one, one, iters=3)
            rows.append({"m": m, "log2_P": log_p, "n_leaves": n_leaves,
                         "lowering": lowering,
                         "per_leaf_us": round(us_pl, 1),
                         "fused_us": round(us_fu, 1),
                         "speedup": round(us_pl / us_fu, 3)})
    return rows


def run(quick: bool = True, json_path: str | None = None):
    hw = HW()
    key = jax.random.key(0)
    rows = []

    def k(i):
        return jax.random.fold_in(key, i)

    # lora_matmul: M=K=N=1024, r=8
    M = K = N = 512 if quick else 1024
    x = jax.random.normal(k(1), (M, K), jnp.float32)
    w = jax.random.normal(k(2), (K, N), jnp.float32)
    a = jax.random.normal(k(3), (K, 8)) * 0.1
    b = jax.random.normal(k(4), (8, N)) * 0.1
    us = _time(lambda *t: ops.lora_matmul(*t, 2.0), x, w, a, b)
    flops = 2 * M * K * N + 2 * M * K * 8 + 2 * M * 8 * N
    rows.append(("lora_matmul", us, f"v5e_roofline_us={flops/hw.peak_flops*1e6:.1f}"))

    # flash_attention
    S = 512 if quick else 1024
    q = jax.random.normal(k(5), (1, 4, S, 64), jnp.float32)
    kk = jax.random.normal(k(6), (1, 4, S, 64), jnp.float32)
    v = jax.random.normal(k(7), (1, 4, S, 64), jnp.float32)
    us = _time(lambda *t: ops.flash_attention(*t, causal=True), q, kk, v)
    flops = 2 * 2 * 4 * S * S * 64
    rows.append(("flash_attention", us,
                 f"v5e_roofline_us={flops/hw.peak_flops*1e6:.1f}"))

    # gossip_mix: m=10 clients, P = 1M params
    P = 1 << (18 if quick else 20)
    W = jnp.ones((10, 10)) / 10
    xs = jax.random.normal(k(8), (10, P), jnp.float32)
    us = _time(lambda *t: ops.gossip_mix_flat(*t, 1.0), W, xs)
    byts = 10 * P * 4 * 2
    rows.append(("gossip_mix", us,
                 f"v5e_hbm_us={byts/hw.hbm_bw*1e6:.1f}"))

    # rglru_scan
    T, Wd = (512, 256) if quick else (2048, 512)
    aa = jax.nn.sigmoid(jax.random.normal(k(9), (4, T, Wd)))
    uu = jax.random.normal(k(10), (4, T, Wd)) * 0.1
    us = _time(ops.rglru_scan, aa, uu)
    byts = 4 * T * Wd * 4 * 3
    rows.append(("rglru_scan", us, f"v5e_hbm_us={byts/hw.hbm_bw*1e6:.1f}"))

    print("\n=== kernel microbench (CPU dispatch path) ===")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    mix_rows = mixing_bench(quick=quick)
    print("\n=== mixing lowering (per-leaf oracle vs MixPlan fused) ===")
    print("m,log2_P,n_leaves,per_leaf_us,fused_us,speedup")
    for r in mix_rows:
        print(f"{r['m']},{r['log2_P']},{r['n_leaves']},"
              f"{r['per_leaf_us']:.1f},{r['fused_us']:.1f},{r['speedup']}")

    result = {n: {"us": u, "derived": d} for n, u, d in rows}
    result["mixing"] = mix_rows
    if json_path:
        payload = {
            "backend": jax.default_backend(),
            "quick": quick,
            "kernels": {n: {"us": u, "derived": d} for n, u, d in rows},
            "mixing": mix_rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"\nwrote {json_path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full grids (adds the P=2^22 mixing column)")
    ap.add_argument("--json", default="",
                    help="write BENCH_mixing.json-style payload here")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
