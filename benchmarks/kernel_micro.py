"""Kernel microbenchmarks: wall-time of the dispatch path on this backend
(CPU -> jnp reference; interpret-mode checked for correctness only — Pallas
timing is meaningless off-TPU) + analytic kernel roofline on v5e."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.roofline.analysis import HW


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quick: bool = True):
    hw = HW()
    key = jax.random.key(0)
    rows = []

    # lora_matmul: M=K=N=1024, r=8
    M = K = N = 512 if quick else 1024
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32)
    a = jax.random.normal(key, (K, 8)) * 0.1
    b = jax.random.normal(key, (8, N)) * 0.1
    us = _time(lambda *t: ops.lora_matmul(*t, 2.0), x, w, a, b)
    flops = 2 * M * K * N + 2 * M * K * 8 + 2 * M * 8 * N
    rows.append(("lora_matmul", us, f"v5e_roofline_us={flops/hw.peak_flops*1e6:.1f}"))

    # flash_attention
    S = 512 if quick else 1024
    q = jax.random.normal(key, (1, 4, S, 64), jnp.float32)
    us = _time(lambda *t: ops.flash_attention(*t, causal=True), q, q, q)
    flops = 2 * 2 * 4 * S * S * 64
    rows.append(("flash_attention", us,
                 f"v5e_roofline_us={flops/hw.peak_flops*1e6:.1f}"))

    # gossip_mix: m=10 clients, P = 1M params
    P = 1 << (18 if quick else 20)
    W = jnp.ones((10, 10)) / 10
    xs = jax.random.normal(key, (10, P), jnp.float32)
    us = _time(lambda *t: ops.gossip_mix_flat(*t, 1.0), W, xs)
    byts = 10 * P * 4 * 2
    rows.append(("gossip_mix", us,
                 f"v5e_hbm_us={byts/hw.hbm_bw*1e6:.1f}"))

    # rglru_scan
    T, Wd = (512, 256) if quick else (2048, 512)
    aa = jax.nn.sigmoid(jax.random.normal(key, (4, T, Wd)))
    uu = jax.random.normal(key, (4, T, Wd)) * 0.1
    us = _time(ops.rglru_scan, aa, uu)
    byts = 4 * T * Wd * 4 * 3
    rows.append(("rglru_scan", us, f"v5e_hbm_us={byts/hw.hbm_bw*1e6:.1f}"))

    print("\n=== kernel microbench (CPU dispatch path) ===")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return {n: {"us": u, "derived": d} for n, u, d in rows}


if __name__ == "__main__":
    run(quick=False)
