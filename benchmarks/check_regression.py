"""Bench-regression gate: the perf trajectory finally enforces something.

Compares the freshly-emitted ``BENCH_*.json`` artifacts of a CI bench run
against committed baselines and fails (exit 1) when any tracked metric
regresses by more than the threshold (default 25%, overridable with
``--threshold`` or the ``REPRO_BENCH_TOLERANCE`` env var, e.g. "0.4").
Also refuses a ``bench_summary.json`` containing failed benchmarks.

  python -m benchmarks.check_regression --baseline-dir .bench-baseline
  python -m benchmarks.check_regression --baseline-git HEAD   # via git show

Tracked metrics per artifact (direction-aware):

  BENCH_mixing.json      fused_us per (m, P) mixing point   (lower better)
  BENCH_round_loop.json  session_us_per_round               (lower better)
  BENCH_scenarios.json   us_per_round per scenario          (lower better)
  BENCH_serving.json     tok_s per (n_slots, mode, n_adapters) (higher)
                         + Poisson-traffic tok_s / max_streams (higher)
                         and latency p50/p99 ms                (lower)
  BENCH_multihost.json   rounds_per_s per (mix_comm, grid size) and the
                         within-mode scale_vs_1p at N>1       (higher)
  BENCH_figs.json        absolute per-(p, method) accuracies of the
                         fig2/3/4 pass on the streaming data layer and
                         fig4's per-p LoRA/TAD-best accs      (higher)
  BENCH_control.json     FMMC spectral gap per graph family   (higher)
                         + closed-loop final loss per regime  (lower)

Baselines missing on either side are reported but never fail the gate
(a NEW artifact has no baseline yet; deleting one is caught by review).
Imports nothing heavy — the gate must run in milliseconds at the end of a
CI job.

Caveat the threshold encodes: tracked metrics are wall-clock, and the
committed baselines were measured on whatever box last regenerated them —
a runner-class machine differing from it by more than the band will fail
honestly-unchanged code. When that happens, regenerate the baselines from
a CI artifact of a known-good run (or widen ``REPRO_BENCH_TOLERANCE`` for
that runner class) rather than deleting the gate.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Callable, Dict, Tuple

# metric value + direction: "lower" = regression when current > baseline,
# "higher" = regression when current < baseline
Metrics = Dict[str, Tuple[float, str]]


def _mixing(doc) -> Metrics:
    out: Metrics = {}
    for row in doc.get("mixing", []):
        key = f"mixing_m{row['m']}_P{row['log2_P']}_fused_us"
        out[key] = (float(row["fused_us"]), "lower")
    return out


def _round_loop(doc) -> Metrics:
    return {"round_loop_session_us": (float(doc["session_us_per_round"]),
                                      "lower")}


def _scenarios(doc) -> Metrics:
    return {f"scenario_{row['scenario']}_us": (float(row["us_per_round"]),
                                               "lower")
            for row in doc.get("scenarios", [])}


def _serving(doc) -> Metrics:
    out: Metrics = {}
    for row in doc.get("rows", []):
        key = (f"serving_s{row['n_slots']}_{row['mode']}"
               f"{row['n_adapters']}_tok_s")
        out[key] = (float(row["tok_s"]), "higher")
    tr = doc.get("traffic")
    if tr:
        out["serving_traffic_tok_s"] = (float(tr["tok_s"]), "higher")
        out["serving_traffic_p50_ms"] = (float(tr["latency_p50_ms"]),
                                         "lower")
        out["serving_traffic_p99_ms"] = (float(tr["latency_p99_ms"]),
                                         "lower")
        out["serving_traffic_max_streams"] = (float(tr["max_streams"]),
                                              "higher")
    return out


def _multihost(doc) -> Metrics:
    out: Metrics = {}
    for row in doc.get("rows", []):
        n = row["n_processes"]
        mode = row.get("mix_comm")
        if mode is None:           # pre-mix_comm artifact (legacy baseline)
            out[f"multihost_{n}p_rounds_per_s"] = (
                float(row["rounds_per_s"]), "higher")
            continue
        quant = row.get("mix_quant", "off")
        if quant != "off":         # quantized rows track separately
            mode = f"{mode}_{quant}"
        out[f"multihost_{mode}_{n}p_rounds_per_s"] = (
            float(row["rounds_per_s"]), "higher")
        if n > 1 and "scale_vs_1p" in row:
            # within-mode scaling efficiency: losing it means the sparse
            # comm path stopped paying for itself, even if absolute
            # rounds/s moved for unrelated reasons
            out[f"multihost_{mode}_{n}p_scale_vs_1p"] = (
                float(row["scale_vs_1p"]), "higher")
    return out


def _figs(doc) -> Metrics:
    out: Metrics = {}
    for row in doc.get("fig2_rows", []):
        p = row["p"]
        for method, acc in row.items():
            if method == "p":
                continue
            out[f"figs_fig2_p{p}_{method}_acc"] = (float(acc), "higher")
    # the fig3 monotone-trend bit stays in the artifact for inspection but
    # is NOT a gated metric: it can legitimately be 0/False on the reduced
    # quick grid, and a zero can't anchor a ratio-based check
    for p, accs in doc.get("fig4_absolute", {}).items():
        out[f"figs_fig4_p{p}_lora_acc"] = (float(accs["lora_acc"]),
                                           "higher")
        out[f"figs_fig4_p{p}_tad_best_acc"] = (float(accs["tad_best_acc"]),
                                               "higher")
    return out


def _control(doc) -> Metrics:
    out: Metrics = {}
    for row in doc.get("families", []):
        out[f"control_fmmc_gap_{row['family']}"] = (float(row["fmmc_gap"]),
                                                    "higher")
    for row in doc.get("closed_loop", []):
        out[f"control_{row['regime']}_closed_loss"] = (
            float(row["closed_final_loss"]), "lower")
        out[f"control_{row['regime']}_oracle_loss"] = (
            float(row["oracle_final_loss"]), "lower")
    return out


TRACKED: Dict[str, Callable] = {
    "BENCH_mixing.json": _mixing,
    "BENCH_round_loop.json": _round_loop,
    "BENCH_scenarios.json": _scenarios,
    "BENCH_serving.json": _serving,
    "BENCH_multihost.json": _multihost,
    "BENCH_figs.json": _figs,
    "BENCH_control.json": _control,
}


def compare(baseline: Metrics, current: Metrics,
            threshold: float) -> Tuple[list, list]:
    """-> (regressions, notes). A regression is a tracked metric moving
    past ``threshold`` in its bad direction; metrics present on only one
    side become notes."""
    regressions, notes = [], []
    for name, (base, direction) in sorted(baseline.items()):
        if name not in current:
            notes.append(f"metric {name} missing from current run")
            continue
        cur = current[name][0]
        if base <= 0:
            notes.append(f"metric {name} has non-positive baseline {base}")
            continue
        ratio = cur / base
        bad = ratio > 1.0 + threshold if direction == "lower" \
            else ratio < 1.0 - threshold
        if bad:
            regressions.append(
                f"{name}: {base:g} -> {cur:g} "
                f"({(ratio - 1.0) * 100.0:+.1f}%, allowed ±{threshold:.0%},"
                f" {direction} is better)")
    for name in sorted(set(current) - set(baseline)):
        notes.append(f"new metric {name} (no baseline yet)")
    return regressions, notes


def _load_json(path: str):
    with open(path) as f:
        return json.load(f)


def _load_baseline(name: str, baseline_dir: str, git_ref: str):
    if baseline_dir:
        path = os.path.join(baseline_dir, name)
        return _load_json(path) if os.path.exists(path) else None
    try:
        blob = subprocess.run(["git", "show", f"{git_ref}:{name}"],
                              capture_output=True, text=True, check=True)
        return json.loads(blob.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default="",
                    help="directory holding baseline BENCH_*.json (CI "
                         "snapshots the checkout before the bench run)")
    ap.add_argument("--baseline-git", default="HEAD",
                    help="git ref to read baselines from when no "
                         "--baseline-dir is given")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--summary", default="",
                    help="bench_summary.json to refuse on failed entries")
    ap.add_argument("--artifacts", default="",
                    help="comma-separated BENCH_*.json names this job "
                         "actually regenerated; others are ignored (an "
                         "unscoped gate would 'verify' stale committed "
                         "artifacts against themselves)")
    ap.add_argument("--threshold",
                    type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOLERANCE",
                                                 "0.25")),
                    help="allowed fractional slowdown (default 0.25)")
    args = ap.parse_args(argv)

    failures = []
    if args.summary and os.path.exists(args.summary):
        bad = [row["name"] for row in _load_json(args.summary)
               if row.get("failed")]
        if bad:
            failures.append(f"bench_summary has failed benchmarks: {bad}")

    tracked = dict(TRACKED)
    if args.artifacts:
        names = [n.strip() for n in args.artifacts.split(",") if n.strip()]
        unknown = [n for n in names if n not in TRACKED]
        if unknown:
            print(f"[gate] unknown artifact(s) {unknown}; "
                  f"tracked: {sorted(TRACKED)}", file=sys.stderr)
            return 2
        tracked = {n: TRACKED[n] for n in names}

    checked = 0
    for name, extract in tracked.items():
        cur_path = os.path.join(args.current_dir, name)
        if not os.path.exists(cur_path):
            print(f"[gate] {name}: not produced by this run — skipped")
            continue
        base_doc = _load_baseline(name, args.baseline_dir, args.baseline_git)
        if base_doc is None:
            print(f"[gate] {name}: no committed baseline — skipped")
            continue
        regressions, notes = compare(extract(base_doc),
                                     extract(_load_json(cur_path)),
                                     args.threshold)
        checked += 1
        for note in notes:
            print(f"[gate] {name}: {note}")
        if regressions:
            failures.append(f"{name}:\n  " + "\n  ".join(regressions))
        else:
            print(f"[gate] {name}: OK "
                  f"(within ±{args.threshold:.0%})")

    if checked == 0:
        # a gate that watched nothing must not go green: a typo'd
        # --baseline-dir or a bench step writing elsewhere would otherwise
        # pass vacuously (the --only lesson, applied here)
        failures.append(
            "0 artifacts checked — no tracked BENCH_*.json had both a "
            "current file and a baseline (check --baseline-dir / "
            "--current-dir / --artifacts)")
    if failures:
        print("\nBENCH REGRESSION GATE FAILED\n" + "\n".join(failures),
              file=sys.stderr)
        return 1
    print(f"[gate] passed ({checked} artifacts checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
