"""Scenario throughput: rounds/sec for every communication condition in
`repro.scenarios.SCENARIO_MATRIX`.

All scenarios share ONE compiled round (W_t is data — the config only
changes how the (m, m) matrix is sampled), so the spread across rows
isolates the host-side schedule cost (graph sampling, Metropolis weights,
churn bookkeeping) on top of the fixed device round. The result goes to
BENCH_scenarios.json as part of the repo's recorded perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.api import DFLConfig, Session
from repro.scenarios import SCENARIO_MATRIX

M = 8
ROUNDS = 40
MODEL_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)


def _config(sc, rounds: int) -> DFLConfig:
    return DFLConfig(model="encoder", task="sst2", model_kw=MODEL_KW,
                     n_clients=M, method="tad", T=3, rounds=rounds,
                     local_steps=2, batch_size=8, lr=1e-3, seed=0,
                     **sc.config_kw())


def run(quick: bool = True, json_path: str | None = None) -> dict:
    rounds = ROUNDS if quick else 3 * ROUNDS
    reps = 3 if quick else 5
    rows = []
    round_fns = set()
    for sc in SCENARIO_MATRIX:
        session = Session(_config(sc, rounds))
        round_fns.add(session.round_fn)
        session.run(5)                       # warmup: compile + caches
        best = float("inf")
        for _ in range(reps):
            session.reset_state()
            t0 = time.perf_counter()
            session.run(rounds)
            best = min(best, time.perf_counter() - t0)
        us = best / rounds * 1e6
        rows.append({"scenario": sc.name, "topology": sc.topology,
                     "schedule": sc.scenario,
                     "us_per_round": round(us, 1),
                     "rounds_per_s": round(1e6 / us, 1)})
    payload = {
        "backend": jax.default_backend(),
        "m": M, "rounds": rounds, "reps": reps,
        "one_compiled_round": len(round_fns) == 1,
        "scenarios": rows,
    }
    print("\n=== scenario throughput (shared compiled round) ===")
    print(f"{'scenario':>20} {'us_per_round':>14} {'rounds_per_s':>14}")
    for r in rows:
        print(f"{r['scenario']:>20} {r['us_per_round']:>14} "
              f"{r['rounds_per_s']:>14}")
    print(f"one compiled round across all scenarios: "
          f"{payload['one_compiled_round']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="longer runs, more repetitions")
    ap.add_argument("--json", default="BENCH_scenarios.json")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
