"""Paper Fig. 2: average test accuracy vs communication probability p for
LORA / FFA-LORA / ROLORA / TAD-LORA.

Protocol notes (faithful to §VI): RoLoRA uses per-round alternation (T=1,
"following the original paper"); TAD-LoRA's switching interval is selected
in hindsight per (task, p) from the divisor grid — §VI-D: "the best
switching intervals are selected in hindsight to characterize the
performance landscape". Claims: all methods comparable under strong
communication; TAD's gains grow as p shrinks; RoLoRA degrades fastest.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Setting, mean_over_seeds, sweep

METHODS = ("lora", "ffa", "rolora", "tad")
P_GRID = (0.5, 0.1, 0.02)
TASKS = ("sst2", "mnli")
SEEDS = (0, 1)
T_GRID = (1, 2, 3, 5, 10, 15)       # divisors of the paper's R=150
T_BY_METHOD = {"lora": 1, "ffa": 1, "rolora": 1}


def tad_hindsight_acc(results, *, task, p, seeds, t_grid):
    """Best-T accuracy (paper's hindsight selection)."""
    accs = [mean_over_seeds(results, seeds=seeds, method="tad", task=task,
                            p=p, T=T)[0] for T in t_grid]
    return float(np.nanmax(accs))


def run(quick: bool = True):
    seeds = list(SEEDS[:1] if quick else SEEDS)
    t_grid = (1, 3, 10) if quick else T_GRID
    settings = [Setting(method=m, task=t, p=p, T=T_BY_METHOD[m], seed=s)
                for m in METHODS[:3] for p in P_GRID for t in TASKS
                for s in seeds]
    settings += [Setting(method="tad", task=t, p=p, T=T, seed=s)
                 for p in P_GRID for t in TASKS for T in t_grid
                 for s in seeds]
    results = sweep(settings)

    rows = []
    print("\n=== Fig.2: mean accuracy across tasks vs p "
          "(TAD: hindsight T per task,p) ===")
    print(f"{'p':>6} " + " ".join(f"{m:>8}" for m in METHODS))
    for p in P_GRID:
        row = {"p": p}
        for m in METHODS[:3]:
            accs = [mean_over_seeds(results, seeds=seeds, method=m,
                                    task=t, p=p)[0] for t in TASKS]
            row[m] = float(np.mean(accs))
        row["tad"] = float(np.mean(
            [tad_hindsight_acc(results, task=t, p=p, seeds=seeds,
                               t_grid=t_grid) for t in TASKS]))
        rows.append(row)
        print(f"{p:>6} " + " ".join(f"{row[m]:8.4f}" for m in METHODS))

    weak = rows[-1]
    gain_vs_rolora = weak["tad"] - weak["rolora"]
    gain_vs_lora = weak["tad"] - weak["lora"]
    print(f"\nweak-regime (p={P_GRID[-1]}): TAD−RoLoRA = {gain_vs_rolora:+.4f}"
          f", TAD−LoRA = {gain_vs_lora:+.4f}")
    return {"rows": rows, "tad_gain_vs_rolora_weak": gain_vs_rolora,
            "tad_gain_vs_lora_weak": gain_vs_lora}


if __name__ == "__main__":
    run(quick=False)
