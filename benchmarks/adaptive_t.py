"""Beyond-paper benchmark: ONLINE adaptive-T vs fixed-T vs hindsight-best.

The paper's §VII names online T selection as future work; this benchmark
runs the `AdaptiveSchedule` (spectral ρ̂ estimator, no oracle access)
against (a) the naive fixed T=1, (b) the hindsight-best fixed T from the
fig3 sweep, across communication regimes on MNLI. Both regimes run
through one `repro.api.Session` — only the `MaskSchedule` differs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DEFAULT_ROUNDS, Setting, mean_over_seeds,
                               sweep)
from repro.api import AdaptiveSchedule, Session

T_GRID = (1, 2, 3, 5, 10, 15)


def run_adaptive(task_name: str, p: float, seed: int, *, c: float = 0.35,
                 rounds: int = DEFAULT_ROUNDS) -> dict:
    setting = Setting(method="tad", task=task_name, p=p, T=1, seed=seed,
                      rounds=rounds)
    schedule = AdaptiveSchedule("tad", c=c, t_max=15)
    session = Session(setting.config(), schedule=schedule)
    session.run()
    ev = session.evaluate()
    return {"acc": ev["acc"], "T_final": schedule.T,
            "T_mean": float(np.mean(schedule.t_trace)),
            "rho_hat": schedule.rho_hat}


def run(quick: bool = True):
    seeds = (0,) if quick else (0, 1)
    p_grid = (0.5, 0.02) if quick else (0.5, 0.1, 0.02)
    t_grid = (1, 3, 10) if quick else T_GRID

    # fixed-T baselines from the shared cache
    fixed = sweep([Setting(method="tad", task="mnli", p=p, T=T, seed=s)
                   for p in p_grid for T in t_grid for s in seeds],
                  verbose=False)

    print("\n=== adaptive-T (online, no oracle) vs fixed T on MNLI ===")
    print(f"{'p':>6} {'T=1':>8} {'best-T':>8} {'(T)':>5} {'adaptive':>9} "
          f"{'T̂ mean':>7} {'ρ̂':>6}")
    out = {}
    for p in p_grid:
        t1 = mean_over_seeds(fixed, seeds=list(seeds), method="tad",
                             task="mnli", p=p, T=1)[0]
        best_T, best = max(
            ((T, mean_over_seeds(fixed, seeds=list(seeds), method="tad",
                                 task="mnli", p=p, T=T)[0])
             for T in t_grid), key=lambda kv: kv[1])
        ad = [run_adaptive("mnli", p, s) for s in seeds]
        acc_ad = float(np.mean([a["acc"] for a in ad]))
        print(f"{p:>6} {t1:>8.4f} {best:>8.4f} {best_T:>5} {acc_ad:>9.4f} "
              f"{ad[0]['T_mean']:>7.1f} {ad[0]['rho_hat']:>6.3f}")
        out[p] = {"fixed_T1": t1, "hindsight_best": best,
                  "hindsight_T": best_T, "adaptive": acc_ad,
                  "adaptive_T_mean": ad[0]["T_mean"],
                  "rho_hat": ad[0]["rho_hat"]}
    return out


if __name__ == "__main__":
    run(quick=False)
