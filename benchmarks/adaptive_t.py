"""Beyond-paper benchmark: ONLINE adaptive-T vs fixed-T vs hindsight-best.

The paper's §VII names online T selection as future work; this benchmark
runs the AdaptiveTController (spectral ρ̂ estimator, no oracle access)
against (a) the naive fixed T=1, (b) the hindsight-best fixed T from the
fig3 sweep, across communication regimes on MNLI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BATCH, DEFAULT_LOCAL_STEPS, DEFAULT_ROUNDS,
                               EVAL_N, N_CLIENTS, Setting, _build_fns,
                               cached_run, mean_over_seeds, sweep)
from repro.core import make_topology
from repro.core.adaptive import AdaptiveTController, adaptive_round_masks
from repro.data import federated_batches, label_skew_partitions
from repro.data.synthetic import eval_batch

T_GRID = (1, 2, 3, 5, 10, 15)


def run_adaptive(task_name: str, p: float, seed: int, *, c: float = 0.35,
                 rounds: int = DEFAULT_ROUNDS) -> dict:
    task, cfg, base, lora0, opt, get_round_fn, acc_fn = _build_fns(task_name)
    parts = label_skew_partitions(task.n_classes, N_CLIENTS)
    topo = make_topology("complete", N_CLIENTS, p, seed=seed)
    round_fn = get_round_fn(DEFAULT_LOCAL_STEPS)
    ctrl = AdaptiveTController(c=c, t_max=15)
    lora, opt_state = lora0, opt.init(lora0)
    t_trace = []
    for batch in federated_batches(task, parts, BATCH, DEFAULT_LOCAL_STEPS,
                                   rounds, seed=seed + 17):
        W = np.asarray(topo.sample())
        ctrl.observe_mixing_matrix(W)
        masks = adaptive_round_masks(ctrl, "tad").as_array()
        t_trace.append(ctrl.T)
        lora, opt_state, _ = round_fn(base, lora, opt_state,
                                      jax.tree.map(jnp.asarray, batch),
                                      jnp.asarray(W, jnp.float32), masks)
    test = eval_batch(task, EVAL_N, seed=9999)
    toks, labs = jnp.asarray(test["tokens"]), jnp.asarray(test["labels"])
    accs = [float(acc_fn(base, toks, labs,
                         jax.tree.map(lambda x: x[..., i, :, :], lora)))
            for i in range(N_CLIENTS)]
    return {"acc": float(np.mean(accs)), "T_final": ctrl.T,
            "T_mean": float(np.mean(t_trace)),
            "rho_hat": float(np.sqrt(ctrl.rho_sq))}


def run(quick: bool = True):
    seeds = (0,) if quick else (0, 1)
    p_grid = (0.5, 0.02) if quick else (0.5, 0.1, 0.02)
    t_grid = (1, 3, 10) if quick else T_GRID

    # fixed-T baselines from the shared cache
    fixed = sweep([Setting(method="tad", task="mnli", p=p, T=T, seed=s)
                   for p in p_grid for T in t_grid for s in seeds],
                  verbose=False)

    print("\n=== adaptive-T (online, no oracle) vs fixed T on MNLI ===")
    print(f"{'p':>6} {'T=1':>8} {'best-T':>8} {'(T)':>5} {'adaptive':>9} "
          f"{'T̂ mean':>7} {'ρ̂':>6}")
    out = {}
    for p in p_grid:
        t1 = mean_over_seeds(fixed, seeds=list(seeds), method="tad",
                             task="mnli", p=p, T=1)[0]
        best_T, best = max(
            ((T, mean_over_seeds(fixed, seeds=list(seeds), method="tad",
                                 task="mnli", p=p, T=T)[0])
             for T in t_grid), key=lambda kv: kv[1])
        ad = [run_adaptive("mnli", p, s) for s in seeds]
        acc_ad = float(np.mean([a["acc"] for a in ad]))
        print(f"{p:>6} {t1:>8.4f} {best:>8.4f} {best_T:>5} {acc_ad:>9.4f} "
              f"{ad[0]['T_mean']:>7.1f} {ad[0]['rho_hat']:>6.3f}")
        out[p] = {"fixed_T1": t1, "hindsight_best": best,
                  "hindsight_T": best_T, "adaptive": acc_ad,
                  "adaptive_T_mean": ad[0]["T_mean"],
                  "rho_hat": ad[0]["rho_hat"]}
    return out


if __name__ == "__main__":
    run(quick=False)
