"""Benchmark harness entry point — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --paper    # full sweeps
  PYTHONPATH=src python -m benchmarks.run --only fig2,theory
  PYTHONPATH=src python -m benchmarks.run --json out.json   # machine-readable

Each module prints its own table and returns a result dict; a final
``name,us_per_call,derived`` CSV line per benchmark summarizes wall time
and the headline derived quantity. ``--json`` additionally writes the
summary rows as ``[{name, us, headline, failed}]``; the "kernels" bench
also records the mixing perf trajectory to ``--mixing-json``
(BENCH_mixing.json by default).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

BENCHES = ("fig2", "table1", "fig3", "fig4", "figs", "table3", "table5",
           "theory", "adaptive", "kernels", "roofline", "round_loop",
           "scenarios", "serving", "multihost", "control")


def _headline(name: str, result) -> str:
    try:
        if name == "fig2":
            return f"tad_gain_vs_rolora_weak={result['tad_gain_vs_rolora_weak']:+.4f}"
        if name == "table1":
            return f"weak_best={result['weak_best']}"
        if name == "fig3":
            return f"tstar_monotone={result['monotone_trend']}"
        if name == "fig4":
            vals = list(result["grid"].values())
            return f"max_gain={max(vals):+.4f}"
        if name == "figs":
            return (f"tad_gain_weak={result['fig2_tad_gain_vs_rolora_weak']:+.4f},"
                    f"tstar_monotone={result['fig3_monotone_trend']}")
        if name == "table5":
            return f"tad_ring_avg={result['tad']['avg']:.4f}"
        if name == "table3":
            return f"weak_best={result['best']}"
        if name == "theory":
            return (f"cross_1/T={result['cross_decreases_with_T']},"
                    f"cross_vs_p={result['cross_grows_as_p_shrinks']}")
        if name == "adaptive":
            worst = min(v["adaptive"] - v["fixed_T1"]
                        for v in result.values())
            return f"adaptive_vs_T1_worstcase={worst:+.4f}"
        if name == "kernels":
            mix = result.get("mixing") or []
            best = max((r["speedup"] for r in mix), default=0.0)
            return (f"n_kernels={len(result) - ('mixing' in result)},"
                    f"mix_speedup_max={best:.2f}x")
        if name == "roofline":
            ok = sum(1 for v in result.values() if v == "ok")
            return f"combos_ok={ok}"
        if name == "round_loop":
            return f"session_overhead={result['overhead_pct']:+.2f}%"
        if name == "scenarios":
            rps = [r["rounds_per_s"] for r in result["scenarios"]]
            return (f"n_scenarios={len(rps)},min_rps={min(rps):.0f},"
                    f"one_compile={result['one_compiled_round']}")
        if name == "serving":
            ovs = [r["overhead_vs_merged_pct"] for r in result["rows"]
                   if r["mode"] == "multi"]
            return (f"multi_vs_merged_worst={max(ovs):+.1f}%,"
                    f"one_compile={result['one_compile']}")
        if name == "multihost":
            rps = {r["n_processes"]: r["rounds_per_s"]
                   for r in result["rows"]}
            return (f"rps_1p={rps.get(1, 0):.1f},rps_2p={rps.get(2, 0):.1f},"
                    f"rps_4p={rps.get(4, 0):.1f},"
                    f"parity={result['loss_parity_across_grids']}")
        if name == "control":
            worst = min(r["fmmc_gap"] - r["metropolis_gap"]
                        for r in result["families"])
            return (f"fmmc_gain_min={worst:+.4f},"
                    f"within_5pct={result['all_within_5pct']}")
    except Exception:
        pass
    return "done"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full sweeps (slower; paper-scale grids)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(BENCHES))
    ap.add_argument("--json", default="",
                    help="write per-benchmark summary rows to this path")
    ap.add_argument("--mixing-json", default="BENCH_mixing.json",
                    help="where the kernels bench records the mixing "
                         "perf trajectory ('' disables)")
    ap.add_argument("--round-loop-json", default="BENCH_round_loop.json",
                    help="where the round_loop bench records the Session "
                         "overhead trajectory ('' disables)")
    ap.add_argument("--scenarios-json", default="BENCH_scenarios.json",
                    help="where the scenarios bench records per-scenario "
                         "throughput ('' disables)")
    ap.add_argument("--serving-json", default="BENCH_serving.json",
                    help="where the serving bench records multi-adapter "
                         "decode throughput ('' disables)")
    ap.add_argument("--multihost-json", default="BENCH_multihost.json",
                    help="where the multihost bench records process-grid "
                         "throughput ('' disables)")
    ap.add_argument("--figs-json", default="BENCH_figs.json",
                    help="where the figs bench records the fig2/3/4 "
                         "accuracy trajectory ('' disables)")
    ap.add_argument("--control-json", default="BENCH_control.json",
                    help="where the control bench records the closed-loop "
                         "and FMMC-gap trajectory ('' disables)")
    args = ap.parse_args()
    quick = not args.paper
    selected = [b.strip() for b in args.only.split(",") if b.strip()] \
        or list(BENCHES)
    # a typo'd --only must fail loudly, not pass vacuously: validate
    # BEFORE the (slow) benchmark imports so CI steps die in milliseconds
    unknown = [b for b in selected if b not in BENCHES]
    if unknown:
        print(f"unknown benchmark(s) {', '.join(map(repr, unknown))}; "
              f"known: {','.join(BENCHES)}", file=sys.stderr)
        sys.exit(2)

    from benchmarks import (adaptive_t, control, fig2_acc_vs_p, fig3_tstar,
                            fig4_heatmap, figs, kernel_micro, multihost,
                            roofline_report, round_loop, scenarios, serving,
                            table1_regimes, table3_weak_avg, table5_ring,
                            theory_crossterm)
    mods = {"fig2": fig2_acc_vs_p, "table1": table1_regimes,
            "fig3": fig3_tstar, "fig4": fig4_heatmap, "figs": figs,
            "table3": table3_weak_avg, "table5": table5_ring,
            "theory": theory_crossterm, "adaptive": adaptive_t,
            "kernels": kernel_micro, "roofline": roofline_report,
            "round_loop": round_loop, "scenarios": scenarios,
            "serving": serving, "multihost": multihost, "control": control}

    csv_rows = []
    json_rows = []
    failed = []
    for name in selected:
        print(f"\n{'='*70}\n## {name}  ({mods[name].__doc__.splitlines()[0]})"
              f"\n{'='*70}", flush=True)
        kwargs = {}
        if name == "kernels" and args.mixing_json:
            kwargs["json_path"] = args.mixing_json
        if name == "round_loop" and args.round_loop_json:
            kwargs["json_path"] = args.round_loop_json
        if name == "scenarios" and args.scenarios_json:
            kwargs["json_path"] = args.scenarios_json
        if name == "serving" and args.serving_json:
            kwargs["json_path"] = args.serving_json
        if name == "multihost" and args.multihost_json:
            kwargs["json_path"] = args.multihost_json
        if name == "figs" and args.figs_json:
            kwargs["json_path"] = args.figs_json
        if name == "control" and args.control_json:
            kwargs["json_path"] = args.control_json
        t0 = time.time()
        try:
            result = mods[name].run(quick=quick, **kwargs)
            us = (time.time() - t0) * 1e6
            headline = _headline(name, result)
            csv_rows.append(f"{name},{us:.0f},{headline}")
            json_rows.append({"name": name, "us": round(us),
                              "headline": headline, "failed": False})
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            csv_rows.append(f"{name},0,FAILED:{type(e).__name__}")
            json_rows.append({"name": name, "us": 0,
                              "headline": f"FAILED:{type(e).__name__}",
                              "failed": True})

    print(f"\n{'='*70}\n## summary (name,us_per_call,derived)\n{'='*70}")
    for row in csv_rows:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_rows, f, indent=1)
        print(f"wrote {args.json}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
