"""Multi-process DFL throughput over a simulated process grid.

Spawns ``repro.launch.cluster --simulate N`` for N in {1, 2, 4} local CPU
processes (gloo collectives) on one shared `DFLConfig` (m = 8 clients, the
benchmark-harness classifier) and records each grid's rounds/s plus the
per-round gossip collective payload (`mix_allgather_bytes_per_round` —
what each process receives: the other processes' client shards of the
stacked LoRA state). The result goes to BENCH_multihost.json as part of
the repo's perf trajectory.

On a single CPU box the grids share the same silicon, so rounds/s is
expected to *drop* as N grows — the point of the trajectory is the cost
of the real cross-process collective path (spawn + gloo + all-gather),
not a scaling claim; `scale_vs_1p` makes the ratio explicit and the CI
regression gate pins it.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

PROC_GRID = (1, 2, 4)
M = 8


def _worker_args(rounds: int, json_path: str) -> list:
    return ["--preset", "classifier", "--clients", str(M),
            "--rounds", str(rounds), "--local-steps", "2",
            "--interval", "2", "--p", "0.5", "--seed", "0",
            "--json", json_path, "--quiet"]


def run(quick: bool = True, json_path: str | None = None) -> dict:
    from repro.launch.cluster import failed_ranks, spawn_simulated

    rounds = 8 if quick else 24
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        for n in PROC_GRID:
            out = os.path.join(tmp, f"grid{n}.json")
            results = spawn_simulated(n, _worker_args(rounds, out))
            failed = failed_ranks(results)
            if failed:
                raise RuntimeError(
                    f"{n}-process grid failed:\n" +
                    "\n".join(report for _, report in failed))
            with open(out) as f:
                payload = json.load(f)
            rows.append({
                "n_processes": n,
                "clients_per_process": payload["clients_per_process"],
                "rounds_per_s": payload["rounds_per_s"],
                "us_per_round": round(1e6 / payload["rounds_per_s"], 1),
                "mix_allgather_bytes_per_round":
                    payload["mix_allgather_bytes_per_round"],
                "final_loss": payload["final_loss"],
            })

    base_rps = rows[0]["rounds_per_s"]
    for row in rows:
        row["scale_vs_1p"] = round(row["rounds_per_s"] / base_rps, 3)
    # every grid optimizes the same function from the same seed: the final
    # losses must agree across process counts (parity smoke; the bitwise
    # assertion lives in tests/test_multihost.py)
    losses = {row["final_loss"] for row in rows}
    parity = len(losses) == 1

    result = {
        "backend": "cpu",
        "m": M,
        "rounds": rounds,
        "preset": "classifier",
        "loss_parity_across_grids": parity,
        "rows": rows,
    }
    print("\n=== multi-process grids (simulated, gloo) ===")
    print("n_proc,clients/proc,rounds_per_s,scale_vs_1p,allgather_B/round")
    for row in rows:
        print(f"{row['n_processes']},{row['clients_per_process']},"
              f"{row['rounds_per_s']},{row['scale_vs_1p']},"
              f"{row['mix_allgather_bytes_per_round']}")
    print(f"loss parity across grids: {parity}")
    if json_path:
        # written BEFORE the parity check fails: on divergence the CI
        # artifact must carry the diverging run's rows, not a stale file
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {json_path}")
    if not parity:
        raise RuntimeError(f"process grids diverged: losses {sorted(losses)}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="more rounds")
    ap.add_argument("--json", default="BENCH_multihost.json")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
