"""Multi-process DFL throughput: dense vs topology-sparse vs overlapped
vs int8-quantized overlapped gossip.

Spawns ``repro.launch.cluster --simulate N`` for N in {1, 2, 4} local CPU
processes (gloo collectives) for each ``mix_comm`` lowering on one shared
`DFLConfig` (m = 8 clients on a static ring — the shape where sparse
gossip matters). Per (mode, grid) row: steady-state rounds/s (compile and
the first rounds excluded via ``--warmup``), the measured per-round
collective payload (`comm_bytes_per_round`, with the dense and sparse
figures side by side), and the final loss. ``scale_vs_1p`` is WITHIN-mode:
rounds/s of the N-process grid over the same mode's 1-process grid, so it
isolates the cost of running the real cross-process collective path
against identical arithmetic.

On a single CPU box the grids share the same silicon, so scale_vs_1p ≤ 1
by construction; the gap to 1.0 is pure multi-process overhead (gloo
exchange + per-process dispatch + cache pressure). The sparse/overlap
lowerings exist to shrink exactly that gap, and the CI regression gate
pins both rounds/s and scale_vs_1p per (mode, grid).

Parity columns: dense and sparse are bit-for-bit the SAME algorithm, so
their final losses must agree across every grid AND with each other
(`loss_parity_across_grids`); sparse_overlap is a different (one-round-
delayed) algorithm whose semantics are process-count independent, so its
losses must agree across grids but not with dense
(`overlap_parity_across_grids`).

``sparse_lowering`` probes the flat-vs-per-segment contraction choice of
the sparse path in-process. The suspicion was that the dense path's
TPU-only-flat heuristic is stale for sparse comm (the sparse path pays
the flat buffer anyway, making the fused dot look free) — the probe
measures the opposite on CPU, so `repro.core.mixing.sparse_use_flat`
keeps the dense heuristic (flat exactly on TPU meshes), pinned by
tests/test_comm.py::test_sparse_lowering_auto_pins_flat.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

PROC_GRID = (1, 2, 4)
# (mix_comm, mix_quant) per benched lowering; int8 rides the overlap
# halo — the bandwidth-bound configuration compression exists for
MODES = (("dense", "off"), ("sparse", "off"), ("sparse_overlap", "off"),
         ("sparse_overlap", "int8"))
M = 8
WARMUP = 2

# Heavy enough that a round's arithmetic dominates per-round dispatch
# (local_steps=1 folds the whole local batch into one scan step — many
# small steps quadruple the per-step dispatch cost at 4 processes).
CONFIG = dict(
    model="encoder", task="sst2",
    model_kw={"n_layers": 2, "d_model": 128, "n_heads": 4, "d_ff": 256,
              "vocab_size": 256},
    n_clients=M, topology="ring", scenario="static",
    local_steps=1, batch_size=64, p=0.5, T=2, lr=1e-3, seed=0,
)


def _run_grid(n: int, mode: str, quant: str, rounds: int, tmp: str) -> dict:
    from repro.launch.cluster import failed_ranks, spawn_simulated

    cfg_path = os.path.join(tmp, f"cfg_{mode}_{quant}_{n}.json")
    out_path = os.path.join(tmp, f"grid_{mode}_{quant}_{n}.json")
    with open(cfg_path, "w") as f:
        json.dump(dict(CONFIG, rounds=rounds, mix_comm=mode,
                       mix_quant=quant), f)
    results = spawn_simulated(n, [
        "--config", cfg_path, "--warmup", str(WARMUP),
        "--json", out_path, "--quiet"])
    failed = failed_ranks(results)
    if failed:
        raise RuntimeError(
            f"{mode} {n}-process grid failed:\n" +
            "\n".join(report for _, report in failed))
    with open(out_path) as f:
        return json.load(f)


def _probe_sparse_lowering(reps: int = 30) -> dict:
    """Time the sparse contraction's two lowerings in-process (1-shard
    degenerate path — the contraction is identical code under shard_map).
    Evidence for `sparse_use_flat`'s always-flat auto default."""
    import jax
    import jax.numpy as jnp
    from repro.core import mixing
    from repro.core.topology import metropolis_weights, ring_graph

    d, r = CONFIG["model_kw"]["d_model"], 4
    key = jax.random.PRNGKey(0)
    lora = {"layers": [
        {"q": {"a": jax.random.normal(jax.random.fold_in(key, 4 * j),
                                      (M, d, r)),
               "b": jax.random.normal(jax.random.fold_in(key, 4 * j + 1),
                                      (M, r, d))},
         "v": {"a": jax.random.normal(jax.random.fold_in(key, 4 * j + 2),
                                      (M, d, r)),
               "b": jax.random.normal(jax.random.fold_in(key, 4 * j + 3),
                                      (M, r, d))}}
        for j in range(CONFIG["model_kw"]["n_layers"])]}
    W = jnp.asarray(metropolis_weights(ring_graph(M)), jnp.float32)

    out = {}
    for lowering in ("flat", "per_segment"):
        fn = jax.jit(lambda W, lo: mixing.mix_tree_sparse(
            W, lo, 1.0, 1.0, comm_plan=None, flat_lowering=lowering))
        jax.block_until_ready(fn(W, lora))       # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = fn(W, lora)
        jax.block_until_ready(res)
        out[f"{lowering}_us"] = round(
            (time.perf_counter() - t0) / reps * 1e6, 1)
    out["winner"] = ("flat" if out["flat_us"] <= out["per_segment_us"]
                     else "per_segment")
    out["auto_resolves_to"] = ("flat" if mixing.sparse_use_flat("auto")
                               else "per_segment")
    return out


def run(quick: bool = True, json_path: str | None = None) -> dict:
    rounds = 8 if quick else 24
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        for mode, quant in MODES:
            for n in PROC_GRID:
                payload = _run_grid(n, mode, quant, rounds, tmp)
                rows.append({
                    "n_processes": n,
                    "mix_comm": mode,
                    "mix_quant": quant,
                    "clients_per_process": payload["clients_per_process"],
                    "rounds_per_s": payload["rounds_per_s"],
                    "us_per_round": round(1e6 / payload["rounds_per_s"], 1),
                    "comm_bytes_per_round":
                        payload["comm_bytes_per_round"],
                    "dense_comm_bytes_per_round":
                        payload["dense_comm_bytes_per_round"],
                    "sparse_comm_bytes_per_round":
                        payload["sparse_comm_bytes_per_round"],
                    "sparse_quant_comm_bytes_per_round":
                        payload["sparse_quant_comm_bytes_per_round"],
                    "final_loss": payload["final_loss"],
                })

    # within-mode scaling: N-process rounds/s over the SAME lowering at 1p
    base = {(row["mix_comm"], row["mix_quant"]): row["rounds_per_s"]
            for row in rows if row["n_processes"] == 1}
    for row in rows:
        row["scale_vs_1p"] = round(
            row["rounds_per_s"] / base[row["mix_comm"], row["mix_quant"]], 3)

    # dense == sparse is an algorithm identity: one loss across both modes
    # and every grid. sparse_overlap is delayed gossip: grid-invariant but
    # legitimately different from dense. Quantized overlap is yet another
    # algorithm (EF residual), also grid-invariant by per-row quantization.
    exact = {row["final_loss"] for row in rows
             if row["mix_comm"] in ("dense", "sparse")}
    overlap = {row["final_loss"] for row in rows
               if row["mix_comm"] == "sparse_overlap"
               and row["mix_quant"] == "off"}
    quant_losses = {row["final_loss"] for row in rows
                    if row["mix_quant"] != "off"}
    parity = len(exact) == 1
    overlap_parity = len(overlap) == 1
    quant_parity = len(quant_losses) == 1

    # compression headline at the multi-process grids: quantized halo
    # bytes over the fp32 sparse halo (1B payload + 4B row scale vs 4B/el)
    quant_rows = [r for r in rows
                  if r["mix_quant"] != "off" and r["n_processes"] > 1]
    quant_bytes_ratio = max(
        (r["comm_bytes_per_round"] / r["sparse_comm_bytes_per_round"]
         for r in quant_rows), default=0.0)
    scale_4p = {(r["mix_comm"], r["mix_quant"]): r["scale_vs_1p"]
                for r in rows if r["n_processes"] == PROC_GRID[-1]}
    quant_scale_ratio_4p = round(
        scale_4p.get(("sparse_overlap", "int8"), 0.0)
        / max(scale_4p.get(("sparse_overlap", "off"), 1.0), 1e-9), 3)

    result = {
        "backend": "cpu",
        "m": M,
        "rounds": rounds,
        "warmup": WARMUP,
        "topology": CONFIG["topology"],
        "scenario": CONFIG["scenario"],
        "config": dict(CONFIG, rounds=rounds),
        "loss_parity_across_grids": parity,
        "overlap_parity_across_grids": overlap_parity,
        "quant_parity_across_grids": quant_parity,
        "quant_bytes_ratio": round(quant_bytes_ratio, 4),
        "quant_scale_ratio_4p": quant_scale_ratio_4p,
        "sparse_lowering": _probe_sparse_lowering(),
        "rows": rows,
    }
    print("\n=== multi-process grids (simulated, gloo; static ring) ===")
    print("mode,quant,n_proc,rounds_per_s,scale_vs_1p,comm_B/round,"
          "dense_B/round")
    for row in rows:
        print(f"{row['mix_comm']},{row['mix_quant']},{row['n_processes']},"
              f"{row['rounds_per_s']},{row['scale_vs_1p']},"
              f"{row['comm_bytes_per_round']},"
              f"{row['dense_comm_bytes_per_round']}")
    sl = result["sparse_lowering"]
    print(f"sparse lowering probe: flat {sl['flat_us']}us vs per_segment "
          f"{sl['per_segment_us']}us -> winner {sl['winner']}")
    print(f"loss parity (dense==sparse, all grids): {parity}; "
          f"overlap parity (grids only): {overlap_parity}; "
          f"quant parity (grids only): {quant_parity}")
    print(f"int8 halo bytes / fp32 sparse halo bytes: "
          f"{result['quant_bytes_ratio']}; quant 4p scale_vs_1p over "
          f"uncompressed overlap: {quant_scale_ratio_4p}")
    if json_path:
        # written BEFORE the parity check fails: on divergence the CI
        # artifact must carry the diverging run's rows, not a stale file
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {json_path}")
    if not parity:
        raise RuntimeError(
            f"dense/sparse grids diverged: losses {sorted(exact)}")
    if not overlap_parity:
        raise RuntimeError(
            f"sparse_overlap grids diverged: losses {sorted(overlap)}")
    if not quant_parity:
        raise RuntimeError(
            f"quantized grids diverged: losses {sorted(quant_losses)}")
    if quant_bytes_ratio > 0.3:
        # byte accounting is deterministic — a breach means the quant
        # payload stopped being 1B/element + one row scale
        raise RuntimeError(
            f"quantized halo bytes ratio {quant_bytes_ratio:.3f} > 0.3")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="more rounds")
    ap.add_argument("--json", default="BENCH_multihost.json")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
