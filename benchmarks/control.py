"""Closed-loop control plane vs fixed-T / Metropolis baselines.

Two measurements feeding ``BENCH_control.json``:

1. *Weight policy*: FMMC (`fastest_mixing_weights`) vs Metropolis spectral
   gap 1−ρ on every `repro.core.topology.GRAPH_FAMILIES` member — pure
   numpy, deterministic, the structural "FMMC never loses" guarantee as a
   tracked number per family.

2. *Closed loop*: a `ControlConfig(t_policy="adaptive",
   weight_policy="fmmc", rho_estimator="gram")` session against the
   fixed-T grid on weak/moderate edge-activation topologies (small
   encoder/SST-2, one jitted round shared across every arm). Reports the
   oracle (hindsight-best fixed T) final loss, the closed-loop final loss,
   rounds-to-target for both, and the ``within_5pct`` acceptance bit: the
   closed loop must land within 5% of the oracle's final loss with no
   oracle access.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.api import DFLConfig, HistoryRecorder, Session
from repro.core.topology import (GRAPH_FAMILIES, fastest_mixing_weights,
                                 metropolis_weights, underlying_graph)

ENC_KW = dict(n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab_size=256)
M = 8


def fmmc_vs_metropolis(m: int = M) -> list:
    rows = []
    for family in GRAPH_FAMILIES:
        adj = underlying_graph(family, m, seed=0)
        J = np.ones((m, m)) / m
        gap_m = 1.0 - float(np.linalg.norm(metropolis_weights(adj) - J, 2))
        gap_f = 1.0 - float(np.linalg.norm(fastest_mixing_weights(adj) - J,
                                           2))
        rows.append({"family": family, "m": m,
                     "metropolis_gap": round(gap_m, 6),
                     "fmmc_gap": round(gap_f, 6),
                     "gain": round(gap_f - gap_m, 6)})
    return rows


def _config(topology: str, p: float, rounds: int, *, T: int = 2,
            control=None) -> DFLConfig:
    return DFLConfig(model="encoder", task="sst2", model_kw=ENC_KW,
                     n_clients=M, topology=topology, p=p,
                     scenario="edge_activation", method="tad", T=T,
                     rounds=rounds, local_steps=2, batch_size=8,
                     lr=2e-3, seed=0, control=control)


def _run(cfg: DFLConfig):
    hist = HistoryRecorder(every=1)
    session = Session(cfg, callbacks=[hist])
    session.run()
    losses = [row["loss"] for row in hist.history]
    return float(losses[-1]), losses, session


def _rounds_to(losses, target: float):
    for t, loss in enumerate(losses):
        if loss <= target:
            return t + 1
    return None


def closed_loop_vs_fixed(rounds: int, t_grid) -> list:
    rows = []
    for label, topology, p in (("weak", "ring", 0.2),
                               ("moderate", "complete", 0.5)):
        fixed = {}
        for T in t_grid:
            fixed[T], _, _ = _run(_config(topology, p, rounds, T=T))
        oracle_T = min(fixed, key=fixed.get)
        oracle_loss, oracle_curve, _ = _run(
            _config(topology, p, rounds, T=oracle_T))
        closed_loss, closed_curve, session = _run(
            _config(topology, p, rounds,
                    control=dict(t_policy="adaptive", weight_policy="fmmc",
                                 rho_estimator="gram", c=0.5,
                                 t_max=max(t_grid))))
        target = 1.02 * oracle_loss
        rows.append({
            "regime": label, "topology": topology, "p": p,
            "rounds": rounds,
            "fixed": {str(T): round(v, 6) for T, v in fixed.items()},
            "oracle_T": oracle_T,
            "oracle_final_loss": round(oracle_loss, 6),
            "closed_final_loss": round(closed_loss, 6),
            "closed_T_final": session.control.T,
            "closed_rho_hat": round(session.control.rho_hat, 4),
            "oracle_rounds_to_target": _rounds_to(oracle_curve, target),
            "closed_rounds_to_target": _rounds_to(closed_curve, target),
            "within_5pct": bool(closed_loss <= 1.05 * oracle_loss),
        })
        print(f"  {label:>9} ({topology}, p={p}): oracle T={oracle_T} "
              f"loss={oracle_loss:.4f} | closed loss={closed_loss:.4f} "
              f"(T->{session.control.T}, rho={session.control.rho_hat:.3f})"
              f" within_5pct={rows[-1]['within_5pct']}")
    return rows


def run(quick: bool = True, json_path: str | None = None) -> dict:
    rounds = 16 if quick else 40
    t_grid = (1, 2, 4) if quick else (1, 2, 3, 5, 8)

    print("=== FMMC vs Metropolis spectral gap per graph family ===")
    families = fmmc_vs_metropolis()
    for row in families:
        print(f"  {row['family']:>13}: metropolis {row['metropolis_gap']:.4f}"
              f" -> fmmc {row['fmmc_gap']:.4f} (+{row['gain']:.4f})")

    print("=== closed loop (fmmc + adaptive T) vs fixed-T oracle ===")
    closed = closed_loop_vs_fixed(rounds, t_grid)

    payload = {"families": families, "closed_loop": closed,
               "all_within_5pct": all(r["within_5pct"] for r in closed)}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full grid (slower); default is the quick CI pass")
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    run(quick=not args.paper, json_path=args.json or None)


if __name__ == "__main__":
    main()
