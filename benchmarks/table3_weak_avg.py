"""Paper Table III: weak-communication-regime (p <= 0.05) average accuracy
per method (uniform average of per-p task averages)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Setting, mean_over_seeds, sweep
from benchmarks.fig2_acc_vs_p import T_GRID, tad_hindsight_acc

P_WEAK = (0.02,)          # quick; paper uses {0.05, 0.02, 0.01}
P_WEAK_FULL = (0.05, 0.02)
TASKS = ("sst2", "mnli")
SEEDS = (0, 1)


def run(quick: bool = True):
    ps = P_WEAK if quick else P_WEAK_FULL
    seeds = list(SEEDS[:1] if quick else SEEDS)
    t_grid = (1, 3, 10) if quick else T_GRID
    settings = [Setting(method=m, task=t, p=p, T=1, seed=s)
                for m in ("lora", "ffa", "rolora") for p in ps
                for t in TASKS for s in seeds]
    settings += [Setting(method="tad", task=t, p=p, T=T, seed=s)
                 for p in ps for t in TASKS for T in t_grid for s in seeds]
    results = sweep(settings, verbose=False)

    print("\n=== Table III: weak-regime average (p ≤ 0.05) ===")
    out = {}
    for m in ("lora", "ffa", "rolora", "tad"):
        vals = []
        for p in ps:
            for t in TASKS:
                if m == "tad":
                    vals.append(tad_hindsight_acc(results, task=t, p=p,
                                                  seeds=seeds,
                                                  t_grid=t_grid))
                else:
                    vals.append(mean_over_seeds(results, seeds=seeds,
                                                method=m, task=t, p=p)[0])
        out[m] = float(np.mean(vals))
        print(f"  {m:8s} {out[m]:.4f}")
    best = max(out, key=out.get)
    print(f"  weak-regime best: {best} "
          f"({'matches' if best == 'tad' else 'DIFFERS from'} paper)")
    out["best"] = best
    return out


if __name__ == "__main__":
    run(quick=False)
