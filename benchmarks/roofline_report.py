"""Pretty-print the dry-run roofline table from results/dryrun.json
(EXPERIMENTS.md §Roofline source)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run(quick: bool = True, path: str = RESULTS):
    if not os.path.exists(path):
        print(f"(no dry-run results at {path} — run "
              f"`python -m repro.launch.dryrun --all --mesh both` first)")
        return {}
    with open(path) as f:
        results = json.load(f)

    print("\n=== Roofline (single-pod 16x16, per dry-run combo) ===")
    hdr = (f"{'arch':<22} {'shape':<12} {'status':<8} {'bottleneck':<11} "
           f"{'compute_s':>10} {'memory_s':>10} {'coll_s':>10} "
           f"{'MF/HLO':>7} {'temp_GB':>8}")
    print(hdr)
    rows = {}
    for key, rec in sorted(results.items()):
        if rec.get("mesh") == "multi":
            continue
        arch = rec.get("arch", key.split("|")[0])
        shape = rec.get("shape", "?")
        st = rec.get("status", "?")
        if st == "ok":
            r = rec["roofline"]
            tmp = rec.get("memory", {}).get("temp_bytes", 0) / 1e9
            print(f"{arch:<22} {shape:<12} {st:<8} {r['bottleneck']:<11} "
                  f"{r['compute_s']:>10.4f} {r['memory_s']:>10.4f} "
                  f"{r['collective_s']:>10.4f} "
                  f"{r['useful_compute_ratio']:>7.2f} {tmp:>8.2f}")
        else:
            reason = rec.get("reason", rec.get("error", ""))[:40]
            print(f"{arch:<22} {shape:<12} {st:<8} {reason}")
        rows[key] = st
    n_ok = sum(1 for v in rows.values() if v == "ok")
    n_skip = sum(1 for v in rows.values() if v == "skipped")
    print(f"\n{n_ok} ok / {n_skip} skipped / "
          f"{len(rows) - n_ok - n_skip} other")
    return rows


if __name__ == "__main__":
    run(quick=False)
