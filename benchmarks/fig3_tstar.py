"""Paper Fig. 3 + Table IV: empirically-selected optimal switching interval
T̂*(p) per task, with the median across tasks.

Claim validated: the median T̂*(p) shifts toward larger T as communication
weakens (Corollary A.11: T* ≍ 1/√(p·λ2)).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Setting, mean_over_seeds, sweep
from repro.core import (make_topology, optimal_switching_interval,
                        optimal_switching_interval_edge_activation)

T_GRID = (1, 2, 3, 5, 10, 15)   # divisors of paper's R=150 (§VI-A)
P_GRID = (0.5, 0.1, 0.02)
TASKS = ("sst2", "mnli")
SEEDS = (0, 1)


def run(quick: bool = True):
    # quick mode shares fig2's reduced TAD grid (same Settings -> same
    # cache keys), so the nightly quick-figs pass costs no extra sweeps
    tasks = TASKS
    seeds = SEEDS[:1] if quick else SEEDS
    t_grid = (1, 3, 10) if quick else T_GRID
    settings = [Setting(method="tad", task=t, p=p, T=T, seed=s)
                for p in P_GRID for T in t_grid for t in tasks
                for s in seeds]
    results = sweep(settings)

    print("\n=== Fig.3 / Table IV: empirical T̂*(p) ===")
    print(f"{'p':>6} " + " ".join(f"{t:>8}" for t in tasks) +
          f" {'median':>8} {'theory T*':>10}")
    rows = []
    for p in P_GRID:
        tstars = []
        for t in tasks:
            accs = {T: mean_over_seeds(results, seeds=list(seeds),
                                       method="tad", task=t, p=p, T=T)[0]
                    for T in t_grid}
            tstars.append(max(accs, key=accs.get))
        med = float(np.median(tstars))
        rho = make_topology("complete", 10, p, seed=0).rho_estimate(80)
        # theory anchor: Corollary A.11 (edge-activation form, λ2(K10)=10)
        theory = optimal_switching_interval_edge_activation(
            p, 10.0, c=2.0, c_mix=0.5)
        rows.append({"p": p, "tstar_by_task": dict(zip(tasks, tstars)),
                     "median": med, "rho": rho, "theory_T": theory})
        print(f"{p:>6} " + " ".join(f"{ts:>8}" for ts in tstars) +
              f" {med:>8} {theory:>10}")

    meds = [r["median"] for r in rows]
    monotone = all(meds[i] <= meds[i + 1] + 1e-9 for i in range(len(meds) - 1))
    print(f"\nmedian T̂* non-decreasing as p decreases: {monotone} "
          f"(paper: holds in the reliably convergent regime)")
    return {"rows": rows, "monotone_trend": monotone}


if __name__ == "__main__":
    run(quick=False)
