"""Combined fig2/fig3/fig4 pass on the streaming data layer, emitting the
gated ``BENCH_figs.json`` artifact.

The three figure benchmarks share one Setting sweep (the results cache
dedupes identical configs), so this pass costs one sweep plus assembly.
The artifact rows are ABSOLUTE per-(p, method) accuracies — the figures'
headline quantities are gains, but gains hover near zero in strong
regimes and a near-zero baseline can't anchor the ratio-based
`check_regression` gate. Directions live in
`benchmarks.check_regression._figs`.
"""
from __future__ import annotations

import json

from benchmarks import fig2_acc_vs_p, fig3_tstar, fig4_heatmap


def run(quick: bool = True, json_path: str = ""):
    f2 = fig2_acc_vs_p.run(quick=quick)
    f3 = fig3_tstar.run(quick=quick)
    f4 = fig4_heatmap.run(quick=quick)

    doc = {
        "quick": quick,
        "fig2_rows": f2["rows"],
        "fig2_tad_gain_vs_rolora_weak": f2["tad_gain_vs_rolora_weak"],
        "fig2_tad_gain_vs_lora_weak": f2["tad_gain_vs_lora_weak"],
        "fig3_rows": f3["rows"],
        "fig3_monotone_trend": bool(f3["monotone_trend"]),
        "fig4_grid": f4["grid"],
        "fig4_absolute": f4["absolute"],
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_path}")
    return doc


if __name__ == "__main__":
    run(quick=False, json_path="BENCH_figs.json")
