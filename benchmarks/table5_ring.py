"""Paper Table V: ring topology stress test (structured, slow-mixing)."""
from __future__ import annotations

from benchmarks.common import Setting, mean_over_seeds, sweep
from benchmarks.fig2_acc_vs_p import METHODS

T_BY_METHOD = {"lora": 1, "ffa": 1, "rolora": 1, "tad": 3}

TASKS = ("sst2", "mnli")
SEEDS = (0, 1)


def run(quick: bool = True):
    seeds = SEEDS[:1] if quick else SEEDS
    settings = [Setting(method=m, task=t, p=1.0, T=T_BY_METHOD[m], seed=s,
                        topology="ring")
                for m in METHODS for t in TASKS for s in seeds]
    results = sweep(settings)

    print("\n=== Table V: ring topology ===")
    print(f"{'method':>8} " + " ".join(f"{t:>10}" for t in TASKS) +
          f" {'avg':>8}")
    out = {}
    for m in METHODS:
        vals = [mean_over_seeds(results, seeds=list(seeds), method=m, task=t,
                                p=1.0, topology="ring")[0] for t in TASKS]
        avg = sum(vals) / len(vals)
        out[m] = {"per_task": dict(zip(TASKS, vals)), "avg": avg}
        print(f"{m:>8} " + " ".join(f"{v:10.4f}" for v in vals) +
              f" {avg:8.4f}")
    return out


if __name__ == "__main__":
    run(quick=False)
