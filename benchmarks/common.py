"""Shared DFL experiment harness for the paper-replication benchmarks.

Mirrors the paper's protocol (§VI-A) at CPU scale: m=10 clients, label-skew
partitions, Erdős–Rényi edge-activation gossip, R rounds × local steps,
AdamW, LoRA on Q/V with a frozen head; evaluation = mean accuracy across
all client models, averaged over seeds.

Results are cached in results/experiments.json keyed by the full setting,
so sweeps are resumable and benchmarks stay cheap on re-run.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_lora_tree, consensus_stats, make_dfl_round,
                        make_topology, round_masks)
from repro.data import federated_batches, label_skew_partitions, make_task
from repro.data.synthetic import eval_batch
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     encoder_config, init_classifier)
from repro.optim import AdamW

RESULTS = os.environ.get("REPRO_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "results"))
CACHE_PATH = os.path.join(RESULTS, "experiments.json")

# CPU-scale stand-in for RoBERTa-large (paper model) — see DESIGN.md §9.
# The *instability regime* matters: the paper's LoRA-vs-TAD gap only
# appears when clients' LoRA subspaces genuinely conflict. We operate with
# per-client feature dialects (feature_shift=2) on top of the paper's label
# skew, r=8/alpha=16 (paper values), lr=8e-3 (paper searches up to 5e-3 at
# 20 local steps; we run 10), which reproduces the paper's method ordering
# at p=0.02 (validated in EXPERIMENTS.md §Paper-validation).
MODEL_KW = dict(n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=512,
                lora_rank=8, lora_alpha=16.0)
N_CLIENTS = 10
DEFAULT_ROUNDS = 60          # paper: 150 (scaled for CPU budget)
DEFAULT_LOCAL_STEPS = 10     # paper: 20
FEATURE_SHIFT = 2
LR = 8e-3
BATCH = 16
EVAL_N = 384


@dataclass(frozen=True)
class Setting:
    method: str
    task: str
    p: float
    T: int
    seed: int = 0
    topology: str = "complete"
    rounds: int = DEFAULT_ROUNDS
    local_steps: int = DEFAULT_LOCAL_STEPS

    def key(self) -> str:
        blob = json.dumps(asdict(self), sort_keys=True)
        return hashlib.md5(blob.encode()).hexdigest()[:16]


_FN_CACHE: dict = {}


def _build_fns(task_name: str):
    if task_name in _FN_CACHE:
        return _FN_CACHE[task_name]
    task = make_task(task_name, feature_shift=FEATURE_SHIFT)
    cfg = encoder_config(**MODEL_KW)
    n_classes = task.n_classes
    key = jax.random.key(1234)
    base = init_classifier(key, cfg, n_classes=n_classes)
    lora0 = build_lora_tree(jax.random.key(99), base, cfg,
                            n_clients=N_CLIENTS)
    opt = AdamW(lr=LR)

    def loss_fn(bp, lo, micro):
        return classifier_loss(bp, cfg, micro["tokens"], micro["labels"],
                               lora=lo)

    round_fns = {}

    def get_round_fn(local_steps):
        if local_steps not in round_fns:
            round_fns[local_steps] = jax.jit(
                make_dfl_round(loss_fn, opt, local_steps=local_steps))
        return round_fns[local_steps]

    acc_fn = jax.jit(lambda bp, toks, labs, lo: classifier_accuracy(
        bp, cfg, toks, labs, lora=lo))
    _FN_CACHE[task_name] = (task, cfg, base, lora0, opt, get_round_fn, acc_fn)
    return _FN_CACHE[task_name]


def run_setting(s: Setting, *, collect_diagnostics: bool = False) -> dict:
    """One DFL run -> {"acc": mean-client accuracy, "loss": final, ...}."""
    task, cfg, base, lora0, opt, get_round_fn, acc_fn = _build_fns(s.task)
    parts = label_skew_partitions(task.n_classes, N_CLIENTS)
    topo = make_topology(s.topology, N_CLIENTS, s.p, seed=s.seed)
    round_fn = get_round_fn(s.local_steps)

    lora = lora0
    opt_state = opt.init(lora)
    diags = []
    t0 = time.time()
    for t, batch in enumerate(federated_batches(
            task, parts, BATCH, s.local_steps, s.rounds, seed=s.seed + 17)):
        W = jnp.asarray(topo.sample(), jnp.float32)
        masks = round_masks(s.method, t, s.T).as_array()
        lora, opt_state, metrics = round_fn(
            base, lora, opt_state, jax.tree.map(jnp.asarray, batch), W, masks)
        if collect_diagnostics:
            st = consensus_stats(lora)
            diags.append({"round": t,
                          "cross_norm": float(st["cross_norm"]),
                          "delta_a_sq": float(st["delta_a_sq"]),
                          "delta_b_sq": float(st["delta_b_sq"]),
                          "loss": float(metrics["loss"])})
    test = eval_batch(task, EVAL_N, seed=9999)
    toks = jnp.asarray(test["tokens"])
    labs = jnp.asarray(test["labels"])
    accs = [float(acc_fn(base, toks, labs,
                         jax.tree.map(lambda x: x[..., i, :, :], lora)))
            for i in range(N_CLIENTS)]
    out = {"acc": float(np.mean(accs)), "acc_std_clients": float(np.std(accs)),
           "loss": float(metrics["loss"]), "wall_s": round(time.time() - t0, 1),
           "rho": topo.rho_estimate(60)}
    if collect_diagnostics:
        out["diagnostics"] = diags
    return out


def _load_cache() -> dict:
    if os.path.exists(CACHE_PATH):
        with open(CACHE_PATH) as f:
            return json.load(f)
    return {}


def _save_cache(cache: dict) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(cache, f, indent=1)


def cached_run(s: Setting, **kw) -> dict:
    cache = _load_cache()
    k = s.key()
    if k in cache and not kw.get("collect_diagnostics"):
        return cache[k]["result"]
    res = run_setting(s, **kw)
    cache = _load_cache()   # re-read: parallel writers
    cache[k] = {"setting": asdict(s), "result":
                {kk: vv for kk, vv in res.items() if kk != "diagnostics"}}
    _save_cache(cache)
    return res


def sweep(settings: list[Setting], verbose: bool = True) -> dict:
    out = {}
    for s in settings:
        res = cached_run(s)
        out[s] = res
        if verbose:
            print(f"  {s.method:7s} {s.task:5s} p={s.p:<5} T={s.T:<3} "
                  f"seed={s.seed} -> acc={res['acc']:.4f} "
                  f"({res.get('wall_s', 0)}s)", flush=True)
    return out


def mean_over_seeds(results: dict, *, seeds: list[int], **fixed) -> tuple:
    vals = []
    for s, r in results.items():
        if all(getattr(s, k) == v for k, v in fixed.items()) \
                and s.seed in seeds:
            vals.append(r["acc"])
    return (float(np.mean(vals)), float(np.std(vals))) if vals else \
        (float("nan"), float("nan"))
