"""Shared DFL experiment harness for the paper-replication benchmarks.

Mirrors the paper's protocol (§VI-A) at CPU scale: m=10 clients, label-skew
partitions, Erdős–Rényi edge-activation gossip, R rounds × local steps,
AdamW, LoRA on Q/V with a frozen head; evaluation = mean accuracy across
all client models, averaged over seeds.

Since the `repro.api` redesign this module is exactly what it should be:
a `Setting -> DFLConfig` mapping plus a results-cache callback around
`Session`. Results are cached in results/experiments.json keyed by the
config's `cache_key()`, so sweeps are resumable and benchmarks stay cheap
on re-run (model init and the jitted round are shared across settings by
the Session build cache).
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass

import numpy as np

from repro.api import Callback, DFLConfig, HistoryRecorder, Session

RESULTS = os.environ.get("REPRO_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "results"))
CACHE_PATH = os.path.join(RESULTS, "experiments.json")

# CPU-scale stand-in for RoBERTa-large (paper model) — see DESIGN.md §9.
# The *instability regime* matters: the paper's LoRA-vs-TAD gap only
# appears when clients' LoRA subspaces genuinely conflict. We operate with
# per-client feature dialects (feature_shift=2) on top of the paper's label
# skew, r=8/alpha=16 (paper values), lr=8e-3 (paper searches up to 5e-3 at
# 20 local steps; we run 10), which reproduces the paper's method ordering
# at p=0.02 (validated in EXPERIMENTS.md §Paper-validation).
MODEL_KW = dict(n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=512,
                lora_rank=8, lora_alpha=16.0)
N_CLIENTS = 10

# Benchmarks run on the streaming data layer: a shard set per task written
# once under results/shards/ (same generator that drew the old in-memory
# batches — per-client dialect blocks + paper label skew, so the
# instability regime is unchanged), consumed through FederatedStream with
# the "domain" partitioner. `data_seed` still moves data across seeds (it
# permutes the dialect→client deal and every epoch order).
SHARDS_DIR = os.path.join(RESULTS, "shards")
N_PER_CLIENT = 400
N_VAL = 1024


def paper_shards_path(task: str) -> str:
    """Path to the benchmark shard set for `task`, writing it on first
    use (seeded — every regeneration is byte-identical)."""
    from repro.data import write_paper_task_shards
    path = os.path.join(SHARDS_DIR, task)
    if not os.path.exists(os.path.join(path, "meta.json")):
        write_paper_task_shards(
            path, task, n_clients=N_CLIENTS, n_per_client=N_PER_CLIENT,
            n_val=N_VAL, seed=0, vocab_size=MODEL_KW["vocab_size"],
            feature_shift=FEATURE_SHIFT)
    return path
DEFAULT_ROUNDS = 60          # paper: 150 (scaled for CPU budget)
DEFAULT_LOCAL_STEPS = 10     # paper: 20
FEATURE_SHIFT = 2
LR = 8e-3
BATCH = 16
EVAL_N = 384
INIT_SEED = 1234             # all seeds share one init (seed moves data/topo)


@dataclass(frozen=True)
class Setting:
    method: str
    task: str
    p: float
    T: int
    seed: int = 0
    topology: str = "complete"
    rounds: int = DEFAULT_ROUNDS
    local_steps: int = DEFAULT_LOCAL_STEPS

    def config(self) -> DFLConfig:
        return DFLConfig(
            model="encoder", task=self.task, model_kw=MODEL_KW,
            n_clients=N_CLIENTS, topology=self.topology, p=self.p,
            method=self.method, T=self.T, rounds=self.rounds,
            local_steps=self.local_steps, batch_size=BATCH, lr=LR,
            data_source="shards", data_path=paper_shards_path(self.task),
            partitioner="domain", seed=self.seed,
            data_seed=self.seed + 17, init_seed=INIT_SEED,
            eval_n=EVAL_N, eval_seed=9999)

    def key(self) -> str:
        return self.config().cache_key()


class ResultsCache(Callback):
    """on_run_end: evaluate the run and write it through to the shared
    results/experiments.json (keyed by the config's cache_key)."""

    def __init__(self, setting: Setting):
        self.setting = setting
        self.result: dict | None = None

    def on_run_end(self, session, result) -> None:
        ev = session.evaluate()
        self.result = {
            "acc": ev["acc"], "acc_std_clients": ev["acc_std_clients"],
            "loss": result.final_loss, "wall_s": round(result.wall_s, 1),
            "rho": session.topology.rho_estimate(60),
        }
        cache = _load_cache()   # re-read: parallel writers
        cache[self.setting.key()] = {"setting": asdict(self.setting),
                                     "result": self.result}
        _save_cache(cache)


def run_setting(s: Setting, *, collect_diagnostics: bool = False) -> dict:
    """One DFL run -> {"acc": mean-client accuracy, "loss": final, ...}."""
    cache_cb = ResultsCache(s)
    callbacks = [cache_cb]
    diag = None
    if collect_diagnostics:
        diag = HistoryRecorder(consensus=True)
        callbacks.append(diag)
    Session(s.config(), callbacks=callbacks).run()
    out = dict(cache_cb.result)
    if diag is not None:
        out["diagnostics"] = diag.history
    return out


def _load_cache() -> dict:
    if os.path.exists(CACHE_PATH):
        with open(CACHE_PATH) as f:
            return json.load(f)
    return {}


def _save_cache(cache: dict) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(cache, f, indent=1)


def cached_run(s: Setting, **kw) -> dict:
    cache = _load_cache()
    k = s.key()
    if k in cache and not kw.get("collect_diagnostics"):
        return cache[k]["result"]
    return run_setting(s, **kw)


def sweep(settings: list[Setting], verbose: bool = True) -> dict:
    out = {}
    for s in settings:
        res = cached_run(s)
        out[s] = res
        if verbose:
            print(f"  {s.method:7s} {s.task:5s} p={s.p:<5} T={s.T:<3} "
                  f"seed={s.seed} -> acc={res['acc']:.4f} "
                  f"({res.get('wall_s', 0)}s)", flush=True)
    return out


def mean_over_seeds(results: dict, *, seeds: list[int], **fixed) -> tuple:
    vals = []
    for s, r in results.items():
        if all(getattr(s, k) == v for k, v in fixed.items()) \
                and s.seed in seeds:
            vals.append(r["acc"])
    return (float(np.mean(vals)), float(np.std(vals))) if vals else \
        (float("nan"), float("nan"))
