"""Paper Table I: per-dataset accuracy under strong (p=0.5), moderate
(p=0.1), and weak (p=0.02) communication regimes, all four methods."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Setting, mean_over_seeds, sweep
from benchmarks.fig2_acc_vs_p import METHODS, T_BY_METHOD, tad_hindsight_acc

P_GRID = (0.5, 0.1, 0.02)
TASKS = ("sst2", "qqp", "qnli", "mnli")
SEEDS = (0, 1)
TAD_T_GRID = (1, 3, 5, 10)   # hindsight selection grid (paper §VI-D)


def run(quick: bool = True):
    tasks = TASKS[:2] if quick else TASKS
    seeds = list(SEEDS[:1] if quick else SEEDS)
    settings = [Setting(method=m, task=t, p=p, T=T_BY_METHOD[m], seed=s)
                for m in METHODS[:3] for p in P_GRID for t in tasks
                for s in seeds]
    settings += [Setting(method="tad", task=t, p=p, T=T, seed=s)
                 for p in P_GRID for t in tasks for T in TAD_T_GRID
                 for s in seeds]
    results = sweep(settings)

    table = {}
    print("\n=== Table I: accuracy (mean±std over seeds) ===")
    for p in P_GRID:
        print(f"\n-- p={p} --")
        print(f"{'method':>8} " + " ".join(f"{t:>14}" for t in tasks) +
              f" {'avg':>8}")
        for m in METHODS:
            vals = []
            cells = []
            for t in tasks:
                if m == "tad":
                    mu = tad_hindsight_acc(results, task=t, p=p,
                                           seeds=seeds, t_grid=TAD_T_GRID)
                    sd = 0.0
                else:
                    mu, sd = mean_over_seeds(results, seeds=seeds,
                                             method=m, task=t, p=p)
                vals.append(mu)
                cells.append(f"{mu:.4f}±{sd:.4f}")
            avg = sum(vals) / len(vals)
            table[(p, m)] = {"per_task": dict(zip(tasks, vals)), "avg": avg}
            print(f"{m:>8} " + " ".join(f"{c:>14}" for c in cells) +
                  f" {avg:8.4f}")
    # weak-regime ranking claim (paper: TAD best at p=0.02)
    weak = {m: table[(0.02, m)]["avg"] for m in METHODS}
    best = max(weak, key=weak.get)
    print(f"\nweak-regime best method: {best} "
          f"({'matches' if best == 'tad' else 'DIFFERS from'} paper)")
    return {"table": {f"{p}|{m}": v for (p, m), v in table.items()},
            "weak_best": best}


if __name__ == "__main__":
    run(quick=False)
