"""Direct validation of the convergence theory (Prop. A.5 / Lemma A.4):

  (1) cycle-averaged cross-term ‖C^t‖ decreases ~1/T   (fix p, sweep T)
  (2) cross-term grows as communication weakens        (fix T, sweep p)
  (3) frozen-block disagreement contracts geometrically within a phase
      (rate ≤ ρ² per round, Lemma A.4 Case 1)
"""
from __future__ import annotations

import contextlib

import numpy as np

import benchmarks.common as C
from benchmarks.common import Setting, run_setting
from repro.core import make_topology


@contextlib.contextmanager
def _small_eta():
    """Prop. A.5 / Lemma A.4 are small-stepsize statements (cross-term
    ~ η²/(T(1−ρ)) after a transient). The accuracy benchmarks run in the
    paper's *instability* regime (lr 8e-3); the theory checks run at
    lr 1e-3 where the asymptotics apply."""
    # the Session build cache keys on lr, so no cache clearing is needed
    old_lr = C.LR
    C.LR = 1e-3
    try:
        yield
    finally:
        C.LR = old_lr


def run(quick: bool = True):
    rounds = 24 if quick else 48
    out = {}

    with _small_eta():
        # (1) cross term vs T at fixed p
        print("\n=== Prop A.5(1): cycle-avg cross-term vs T (p=0.1, "
              "small-η regime) ===")
        xs = []
        t_grid = (1, 3, 10) if quick else (1, 2, 3, 5, 10, 15)
        for T in t_grid:
            res = run_setting(Setting(method="tad", task="sst2", p=0.1, T=T,
                                      rounds=rounds),
                              collect_diagnostics=True)
            tail = res["diagnostics"][rounds // 2:]
            avg_cross = float(np.mean([d["cross_norm"] for d in tail]))
            xs.append((T, avg_cross))
            print(f"  T={T:<3} avg‖C‖={avg_cross:.3e}")
        out["cross_vs_T"] = xs
        decreasing = xs[0][1] > xs[-1][1]
        print(f"  cross-term decreases with T: {decreasing}")
        out["cross_decreases_with_T"] = decreasing

        # (2) cross term vs p at fixed T
        print("\n=== Prop A.5(2): cross-term vs p (T=3) ===")
        xp = []
        for p in (0.5, 0.1, 0.02):
            res = run_setting(Setting(method="tad", task="sst2", p=p, T=3,
                                      rounds=rounds),
                              collect_diagnostics=True)
            tail = res["diagnostics"][rounds // 2:]
            avg_cross = float(np.mean([d["cross_norm"] for d in tail]))
            xp.append((p, avg_cross))
            print(f"  p={p:<5} avg‖C‖={avg_cross:.3e}")
        out["cross_vs_p"] = xp
        increasing = xp[0][1] < xp[-1][1]
        print(f"  cross-term grows as p shrinks: {increasing}")
        out["cross_grows_as_p_shrinks"] = increasing

    # (3) frozen-block gossip contraction (pure mixing, no updates)
    print("\n=== Lemma A.4: frozen-block consensus contraction ===")
    m = 10
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m, 32))           # per-client frozen block
    for p in (0.5, 0.1):
        topo = make_topology("complete", m, p, seed=1)
        rho2 = topo.rho_estimate(100) ** 2
        errs = []
        xi = x.copy()
        for _ in range(12):
            xi = topo.sample() @ xi
            err = float(np.mean(np.sum((xi - xi.mean(0)) ** 2, -1)))
            errs.append(err)
        rate = float(np.mean([errs[i + 1] / errs[i]
                              for i in range(len(errs) - 1) if errs[i] > 0]))
        holds = rate <= rho2 + 0.05
        print(f"  p={p:<5} empirical rate={rate:.4f}  ρ²={rho2:.4f} "
              f" rate≤ρ²: {holds}")
        out[f"contraction_p{p}"] = {"rate": rate, "rho_sq": rho2,
                                    "holds": holds}
    return out


if __name__ == "__main__":
    run(quick=False)
